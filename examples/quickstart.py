"""Quickstart: reconstruct a procedural scene with Instant-3D in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.core import Field, FieldConfig, Instant3DTrainer, TrainerConfig, occupancy
from repro.core.rendering import RenderConfig
from repro.data import build_dataset, RaySampler


def main():
    print("== Instant-3D quickstart (paper config, scaled to CPU) ==")
    render = RenderConfig(n_samples=24)
    t0 = time.time()
    scene, ds = build_dataset(seed=0, n_views=10, h=40, w=40, cfg=render, gt_samples=96)
    print(f"built procedural scene + {ds.images.shape[0]} GT views in {time.time()-t0:.1f}s")

    # Instant-3D: decomposed grids, S_D:S_C = 1:0.25, F_D:F_C = 1:0.5 (paper §5.1)
    field = Field(FieldConfig(
        n_levels=6, max_resolution=96,
        log2_table_density=13, log2_table_color=11,   # S_D : S_C = 1 : 0.25
    ))
    trainer = Instant3DTrainer(field, TrainerConfig(
        n_rays=512, iters=200, f_density=1.0, f_color=0.5, render=render,
        occ=occupancy.OccupancyConfig(update_interval=16, warmup_steps=32),
    ))
    state = trainer.init(jax.random.PRNGKey(0))
    print("params:", {k: f"{v:,}" for k, v in field.param_counts(state.params).items()})

    t0 = time.time()
    state, hist = trainer.train(state, RaySampler(ds), log_every=50,
                                callback=lambda i, p, h: print(
                                    f"  iter {i:4d}  loss {h['loss'][-1]:.5f}  "
                                    f"live {h['live_fraction'][-1]:.0%}"))
    print(f"trained {trainer.cfg.iters} iters in {time.time()-t0:.1f}s")

    ev = trainer.evaluate(state.params, ds, views=[0, 1])
    print(f"PSNR: rgb={ev['psnr_rgb']:.2f} dB  depth={ev['psnr_depth']:.2f} dB "
          f"(paper's instant target: >25 dB rgb)")


if __name__ == "__main__":
    main()
