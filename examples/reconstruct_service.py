"""Multi-scene reconstruction service demo (Instant-3D as a service primitive).

    PYTHONPATH=src python examples/reconstruct_service.py \
        --scenes 4 --iters 96 --slice 8

Four procedural scenes train *concurrently in one process*: a round-robin
scheduler time-slices the device across their sessions, each slice publishes
an atomic parameter snapshot, and novel-view render requests are answered
mid-training from the latest snapshot — coalesced across sessions into
batched jitted renders.  Served views are scored against the scene's
analytic ground truth, so you can watch per-scene PSNR climb while all
scenes are still training.

Fleet mode (docs/SERVING.md): --devices N shards the sessions across a
device mesh (on CPU, run with
XLA_FLAGS=--xla_force_host_platform_device_count=N), --snapshot-levels k
streams cheap h>>k previews before each scene's first full snapshot, and
--async-serving serves renders from a dedicated thread.
"""
import argparse

import numpy as np

from repro.core import FieldConfig, TrainerConfig, losses, occupancy
from repro.core.rendering import RenderConfig
from repro.data import build_dataset
from repro.obs import export as obs_export, metrics as obs_metrics, trace as obs_trace
from repro.serve3d import ReconstructionService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenes", type=int, default=4)
    ap.add_argument("--iters", type=int, default=96, help="per-scene iterations")
    ap.add_argument("--slice", type=int, default=8, help="iterations per time slice")
    ap.add_argument("--hw", type=int, default=24)
    ap.add_argument("--max-resident", type=int, default=None)
    ap.add_argument("--max-cohort", type=int, default=None,
                    help="train-cohort cap (default unlimited; 1 = pure time-slicing)")
    ap.add_argument("--dense-render", action="store_true",
                    help="serve views dense instead of redistributed")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard sessions across the first N local devices "
                         "(on CPU: XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--snapshot-levels", type=int, default=0,
                    help="publish h>>k preview snapshots until a scene's "
                         "first full snapshot (0 = off)")
    ap.add_argument("--async-serving", action="store_true",
                    help="serve renders from a dedicated thread")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace JSON of the demo run")
    args = ap.parse_args()

    # the demo always runs instrumented: the progress lines below and the
    # final summary both read from the one obs metrics plane
    obs_trace.configure(enabled=True)

    render = RenderConfig(n_samples=16)
    field_cfg = FieldConfig(n_levels=4, max_resolution=64,
                            log2_table_density=12, log2_table_color=10)
    trainer_cfg = TrainerConfig(
        n_rays=256, render=render,
        occ=occupancy.OccupancyConfig(update_interval=8, warmup_steps=16),
        eval_chunk=args.hw * args.hw,
    )

    print(f"building {args.scenes} procedural scenes ({args.hw}x{args.hw})...")
    service = ReconstructionService(slice_iters=args.slice,
                                    max_resident=args.max_resident,
                                    max_cohort=args.max_cohort,
                                    redistributed_render=not args.dense_render,
                                    devices=args.devices,
                                    snapshot_levels=args.snapshot_levels,
                                    async_serving=args.async_serving)
    datasets = {}
    for i in range(args.scenes):
        _scene, ds = build_dataset(seed=i, n_views=6, h=args.hw, w=args.hw,
                                   cfg=render, gt_samples=48)
        sid = service.submit_scene(ds, field_cfg, trainer_cfg,
                                   target_iters=args.iters, seed=i)
        datasets[sid] = ds

    t0 = obs_trace.clock()
    held_out = 0  # every served render targets view 0, scored against its GT

    def hook(svc, event):
        # ask for a fresh view of every scene that just trained a slice
        # (one quantum advances a whole cohort when configs match)
        for sid in event["cohort"]:
            if svc.sessions[sid].step % (2 * args.slice) == 0:
                svc.request_render(sid, datasets[sid].poses[held_out])
        for r in event["results"]:
            gt = datasets[r.session_id].images[held_out]
            psnr = float(losses.psnr(np.asarray(r.rgb), gt))
            # served-view quality lands in the same metrics plane the final
            # summary prints from — one source for interactive and exported
            obs_metrics.gauge(f"demo.psnr_db.{r.session_id}").set(psnr)
            print(f"[{obs_trace.clock() - t0:6.1f}s] render {r.session_id} "
                  f"@step {r.snapshot_step:3d} (v{r.snapshot_version})  "
                  f"psnr {psnr:5.2f} dB  latency {r.latency_s * 1e3:5.0f} ms")

    tel = service.run(hook=hook)

    print("\nfinal state:")
    for p in tel["sessions"]:
        sess = service.sessions[p["session_id"]]
        ev = sess.evaluate(views=[0, 1])
        obs_metrics.gauge(f"demo.final_psnr_rgb_db.{p['session_id']}").set(
            ev["psnr_rgb"])
        obs_metrics.gauge(f"demo.final_psnr_depth_db.{p['session_id']}").set(
            ev["psnr_depth"])
        print(f"  {p['session_id']}: {p['step']}/{p['target_iters']} iters, "
              f"psnr rgb {ev['psnr_rgb']:.2f} dB  depth {ev['psnr_depth']:.2f} dB  "
              f"(train {p['train_wall_s']:.1f}s)")
    r = tel["render"]
    print(f"\n{tel['scenes_done']} scenes on {tel['devices']} device(s) "
          f"in {tel['wall_s']:.1f}s "
          f"({tel['scenes_per_sec']:.3f} scenes/sec)  "
          f"renders {r.get('count', 0)}: p50 {r.get('p50_ms', 0):.0f} ms, "
          f"p95 {r.get('p95_ms', 0):.0f} ms")
    print("\nmetrics snapshot:")
    print(obs_export.format_metrics(service.metrics()))
    if args.trace_out:
        print(f"\ntrace -> {service.dump_trace(args.trace_out)}")


if __name__ == "__main__":
    main()
