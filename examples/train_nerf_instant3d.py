"""End-to-end Instant-3D training driver: checkpointing, preemption safety,
auto-resume, straggler watchdog — the production loop around the paper's
algorithm.

    PYTHONPATH=src python examples/train_nerf_instant3d.py \
        --scene-seed 0 --iters 300 --ckpt-dir /tmp/i3d_ckpt --auto-resume

Kill it mid-run (Ctrl-C) and re-run with --auto-resume: it continues from the
last atomic checkpoint with the exact data stream.
"""
import argparse
import time

import jax
import numpy as np

from repro import kernels
from repro.checkpoint import CheckpointManager
from repro.core import Field, FieldConfig, Instant3DTrainer, TrainerConfig, occupancy
from repro.core.rendering import RenderConfig
from repro.data import build_dataset, RaySampler
from repro.obs import export as obs_export, trace as obs_trace
from repro.runtime import DriverConfig, StragglerStats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene-seed", type=int, default=0)
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/i3d_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--auto-resume", action="store_true")
    ap.add_argument("--sd-sc", default="1:0.25", help="grid size ratio S_D:S_C")
    ap.add_argument("--fd-fc", default="1:0.5", help="update freq ratio F_D:F_C")
    ap.add_argument("--backend", default=None,
                    help="kernel backend: auto | ref | pallas | pallas-interpret | "
                         "pallas-tpu (default: $REPRO_BACKEND, else auto)")
    ap.add_argument("--no-compact", action="store_true",
                    help="disable occupancy-compacted field queries (dense path)")
    ap.add_argument("--no-fused-path", action="store_true",
                    help="shade the compacted batch with the per-grid encode "
                         "path instead of the fused kernel (debug/timing; "
                         "compaction stays Morton-ordered either way)")
    ap.add_argument("--redistribute", action="store_true",
                    help="occupancy-guided sample redistribution (pipeline "
                         "stage 2b): re-spend each ray's freed sample budget "
                         "on its live segments via inverse-CDF placement — "
                         "finer live-region stratification at <= the same "
                         "compacted point budget")
    ap.add_argument("--redistribute-v3", action="store_true",
                    help="density-weighted, workload-balanced redistribution "
                         "(stage 2b v3): strata weighted by occupancy EMA "
                         "density, per-ray variable S' from one global "
                         "inverse-CDF, sum(S') <= budget by construction; "
                         "supersedes --redistribute when both are given")
    ap.add_argument("--max-budget", type=int, default=None,
                    help="hard per-step point ceiling (on-device regime; "
                         "see trainer.autotune_max_budget to derive one "
                         "from a memory/latency envelope)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace JSON of the run (enables obs)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final metrics snapshot JSON (enables obs)")
    args = ap.parse_args()

    if args.trace_out or args.metrics_out:
        obs_trace.configure(enabled=True)

    # explicit flag wins; otherwise the registry default ($REPRO_BACKEND / auto)
    be = kernels.set_backend(args.backend) if args.backend else kernels.get_backend()
    print(f"kernel backend: {be.name} (available: {', '.join(kernels.available_backends())})")

    render = RenderConfig(n_samples=24)
    scene, ds = build_dataset(seed=args.scene_seed, n_views=12, h=48, w=48,
                              cfg=render, gt_samples=128)

    sc = float(args.sd_sc.split(":")[1])
    fc = float(args.fd_fc.split(":")[1])
    log2_c = 13 + round(np.log2(sc) / 3 * 3)  # 1:0.25 -> -2 levels
    field = Field(FieldConfig(n_levels=6, max_resolution=96,
                              log2_table_density=13,
                              log2_table_color=int(13 + np.log2(sc))))
    trainer = Instant3DTrainer(field, TrainerConfig(
        n_rays=768, iters=args.iters, f_color=fc, render=render,
        occ=occupancy.OccupancyConfig(update_interval=16, warmup_steps=32),
        compact=not args.no_compact,
        fused_path=not args.no_fused_path,
        redistribute=args.redistribute,
        redistribute_v3=args.redistribute_v3,
        max_budget=args.max_budget,
    ))

    ckpt = CheckpointManager(args.ckpt_dir, keep_last=2)
    state = trainer.init(jax.random.PRNGKey(0))
    start = 0
    if args.auto_resume and ckpt.latest_step() is not None:
        tmpl = {"params": state.params, "opt": state.opt_state,
                "occ": state.occ_state.density_ema,
                "occ_step": state.occ_state.step}
        try:
            restored, meta = ckpt.restore(tmpl)
            occ_step = jax.numpy.asarray(restored["occ_step"], jax.numpy.int32)
        except KeyError:  # checkpoint predates the occ_step leaf
            del tmpl["occ_step"]
            restored, meta = ckpt.restore(tmpl)
            occ_step = jax.numpy.zeros((), jax.numpy.int32)
        # occ_step matters on resume: the trainer keeps rendering dense until
        # the occupancy EMA has folded at least one real update
        state = state._replace(
            params=restored["params"], opt_state=restored["opt"],
            occ_state=occupancy.OccupancyState(
                jax.numpy.asarray(restored["occ"]), occ_step),
            step=int(meta["step"]),
        )
        start = int(meta["step"])
        print(f"resumed from step {start}")

    watchdog = StragglerStats()
    done = start
    while done < args.iters:
        chunk = min(args.ckpt_every, args.iters - done)
        t0 = time.perf_counter()
        state, hist = trainer.train(state, RaySampler(ds), iters=chunk, log_every=chunk)
        dt = (time.perf_counter() - t0) / chunk
        if watchdog.update(dt, sigma=4.0, alpha=0.1):
            print(f"[straggler] step time {dt:.3f}s vs ewma {watchdog.ewma:.3f}s")
        done += chunk
        ckpt.save(done, {"params": state.params, "opt": state.opt_state,
                         "occ": state.occ_state.density_ema,
                         "occ_step": state.occ_state.step})
        print(f"step {done:5d}  loss {hist['loss'][-1]:.5f}  ({dt:.3f}s/iter)  ckpt saved")

    ckpt.wait()
    ev = trainer.evaluate(state.params, ds, views=[0, 1, 2])
    print(f"final PSNR rgb={ev['psnr_rgb']:.2f} depth={ev['psnr_depth']:.2f}")
    if args.trace_out:
        print(f"trace -> {obs_export.dump_trace(args.trace_out, process_name='repro.train')}")
    if args.metrics_out:
        print(f"metrics -> {obs_export.dump_metrics(args.metrics_out, extra={'iters': done})}")


if __name__ == "__main__":
    main()
