"""Batched LM serving: continuous-batching decode loop on a reduced config.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-8b --batch 4 --steps 32

Uses the smoke config of the chosen architecture (full configs need a pod).
Demonstrates the serve path the decode_32k / long_500k dry-run cells lower:
prefill -> KV/SSM caches -> batched greedy decode, with per-step tokens/s.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config, list_archs
from repro.models.lm import LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, p = args.batch, args.prompt_len
    max_seq = p + args.steps + 1

    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (b, p)), jnp.int32)
    kw = {}
    if cfg.frontend == "audio_stub":
        kw["encoder_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    t0 = time.time()
    logits, caches, enc_out = model.prefill(params, tokens=prompts, max_seq=max_seq, **kw)
    print(f"[{cfg.name}] prefill {b}x{p} in {time.time()-t0:.2f}s")

    decode = jax.jit(lambda pr, c, t, pos: model.decode_step(pr, c, t, pos,
                                                             encoder_out=enc_out))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for step in range(args.steps):
        pos = jnp.full((b, 1), p + step, jnp.int32)
        logits, caches = decode(params, caches, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"decoded {args.steps} steps x {b} seqs in {dt:.2f}s "
          f"({args.steps * b / dt:.1f} tok/s)")
    print("sample token ids:", np.asarray(gen[0, :16]))


if __name__ == "__main__":
    main()
