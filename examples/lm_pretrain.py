"""Small-LM pretraining loop: sharded synthetic data, AdamW, fault-tolerant
driver with checkpoints + auto-resume.  Loss visibly decreases (the stream
has learnable bigram structure).

    PYTHONPATH=src python examples/lm_pretrain.py --arch qwen1.5-0.5b --steps 60
"""
import argparse

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.data import SyntheticLMStream, LMStreamConfig
from repro.models.lm import LM
from repro.optim import AdamW, schedule
from repro.runtime import TrainDriver, DriverConfig, resume_or_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/lm_ckpt")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = LM(cfg)
    opt = AdamW(lr=schedule.warmup_cosine(3e-3, 10, args.steps), clip_norm=1.0,
                weight_decay=0.01)

    stream = SyntheticLMStream(LMStreamConfig(cfg.vocab, args.seq, args.batch))

    @jax.jit
    def train_step(state, batch):
        params, opt_state = state
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state = opt.apply(params, grads, opt_state)
        return (params, opt_state), loss

    def step_fn(state, batch):
        batch = {"tokens": jax.numpy.asarray(batch["tokens"])}
        state, loss = train_step(state, batch)
        return state, {"loss": float(loss)}

    ckpt = CheckpointManager(args.ckpt_dir, keep_last=2, async_save=False)
    params0 = model.init(jax.random.PRNGKey(0))
    template = (params0, opt.init(params0))
    state, start = resume_or_init(ckpt, template, lambda: template)
    if start:
        print(f"auto-resumed at step {start}")

    drv = TrainDriver(DriverConfig(total_steps=args.steps, checkpoint_every=25,
                                   log_every=10), ckpt)
    losses = []

    def wrapped(state, batch):
        state, m = step_fn(state, batch)
        losses.append(m["loss"])
        if len(losses) % 10 == 0:
            print(f"step {start + len(losses):4d}  loss {m['loss']:.4f}")
        return state, m

    state, summary = drv.run(state, wrapped, stream.iterator(start_step=start),
                             start_step=start)
    print(f"done: {summary}; loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss must decrease on structured data"


if __name__ == "__main__":
    main()
