"""Paper Table 1: PSNR vs training time for density:color grid-size ratios.

S_D:S_C in {1:1 (Instant-NGP), 0.25:1, 1:0.25 (Instant-3D)} — the paper's
finding is that shrinking the COLOR grid 4x keeps PSNR while shrinking the
density grid loses it."""
from dataclasses import replace

from . import common


ROWS = [
    ("1:1", 0, 0),        # log2 deltas applied to (density, color)
    ("0.25:1", -2, 0),    # density table / 4
    ("1:0.25", 0, -2),    # color table / 4  (paper's winning row)
]


def run():
    results = []
    for name, d_delta, c_delta in ROWS:
        fcfg = replace(
            common.BASE_FIELD,
            log2_table_density=common.BASE_FIELD.log2_table_density + d_delta,
            log2_table_color=common.BASE_FIELD.log2_table_color + c_delta,
        )
        out = common.train_and_eval(fcfg, common.BASE_TRAIN)
        results.append((name, out))
        common.emit(
            f"table1_grid_sizes[{name}]",
            out["runtime_s"] * 1e6 / common.BASE_TRAIN.iters,
            f"psnr={out['psnr_rgb']:.2f};depth_psnr={out['psnr_depth']:.2f};runtime_s={out['runtime_s']:.1f}",
        )
    return results


if __name__ == "__main__":
    run()
