"""serve3d service benchmark -> BENCH_serve3d.json.

Measures the reconstruction service end to end: N procedural scenes train
concurrently — scene-parallel by default, the scheduler advancing every
config-matched session through one member-axis compiled train step per
quantum — while novel-view renders of a held-out pose are requested after
every slice and served through the redistributed render path.  Records

* scenes/sec (completed reconstructions per wall-clock second) for train
  cohort caps {1, 2, 4} over the same scene set, with `speedup_4v1`
  (cohort=4 over cohort=1, pure time-slicing) as the headline,
* cohort bit-identity: the cohort-trained params must equal sequential
  single-scene training bit-for-bit (not just to PSNR tolerance),
* p50/p95 render latency (request submit -> result, mid-training) plus a
  steady-state dense-vs-redistributed comparison: `p50_ratio`
  (redistributed over dense) and `psnr_cost_db` at the served views,
* time-to-first-usable-view per scene (first served render whose PSNR
  against ground truth crosses the threshold),
* PSNR parity: the interleaved scheduler must reach the same PSNR per scene
  as sequential single-scene training at equal per-scene iteration counts,
* scale-out (`scale_out`): a child process forced to a 4-device host
  topology (``--xla_force_host_platform_device_count=4``) sweeps the
  session-sharded service over device counts {1, 2, 4} at saturating
  residency and a fixed cohort cap — scenes/sec must be monotone in device
  count, the N=1 placement must be bit-identical to the placement-free
  path, and render p95 is measured under mixed train+render load on the
  full mesh with the async serving plane.

    PYTHONPATH=src python -m benchmarks.bench_serve3d [--smoke]

CI gates these fields against the committed baseline via tools/bench_gate.py.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.core import Field, FieldConfig, Instant3DTrainer, TrainerConfig, losses, occupancy
from repro.core.rendering import RenderConfig
from repro.data import build_dataset, RaySampler
from repro.serve3d import ReconstructionService, RenderService

from . import common

COHORT_SIZES = (1, 2, 4)
DEVICE_COUNTS = (1, 2, 4)


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def run_scale_out(smoke: bool = False) -> dict:
    """The scale-out sweep body; runs inside the forced-4-device child
    (`--scale-child`).  One process measures every device count so compile
    caches and machine drift hit each count alike.

    The workload is a deliberately dispatch-lean regime (8-sample ladder,
    small field, 64 rays): on a host where the forced devices share one
    core, XLA execution time cannot shrink with device count — the honest
    scale-out win is overlapping per-device Python dispatch and blocking
    host syncs (occ-cadence live-fraction measures, snapshot transfers,
    guard reductions) with XLA's GIL-released execution on the other
    devices, plus amortizing per-quantum scheduler fixed costs over one
    cohort per device.  Moderate steps are the sweet spot (probed): fat
    compute-bound steps drown the overlap, and tiny steps drown in
    thread-switch overhead.  The cohort cap is fixed across device counts
    — cohort efficiency is constant, device count is the only variable."""
    assert jax.device_count() >= 4, (
        f"scale-out child needs 4 devices, got {jax.device_count()} "
        "(run under XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    scenes = 8
    iters = 16 if smoke else 64
    slice_iters = 8
    hw = 24
    render = RenderConfig(n_samples=8)
    occ_cfg = occupancy.OccupancyConfig(resolution=16, update_interval=8,
                                        warmup_steps=8)
    field_cfg = FieldConfig(n_levels=2, max_resolution=32,
                            log2_table_density=10, log2_table_color=8)
    cfg = TrainerConfig(n_rays=64, render=render, occ=occ_cfg,
                        eval_chunk=hw * hw)
    datasets = {
        f"scene-{i:03d}": build_dataset(seed=i, n_views=2, h=hw, w=hw,
                                        cfg=render, gt_samples=32)[1]
        for i in range(scenes)
    }

    def make(devices, async_serving=False) -> ReconstructionService:
        svc = ReconstructionService(
            slice_iters=slice_iters, max_cohort=2, devices=devices,
            async_serving=async_serving,
        )
        for i, (sid, ds) in enumerate(datasets.items()):
            svc.submit_scene(ds, field_cfg, cfg, target_iters=iters,
                             seed=i, session_id=sid)
        return svc

    # device-count sweep: warm each count's per-device executables, then
    # interleave timed reps.  The headline estimator is the MEAN over reps:
    # per-rep spread on a shared-core host (~±5-7%) exceeds the true 1->2
    # gap, and best-of-N amplifies exactly that upper-tail noise — probed
    # distributions showed monotone means under a non-monotone best-of.
    hist = {str(c): [] for c in DEVICE_COUNTS}
    for c in DEVICE_COUNTS:
        make(c).run()
    for _rep in range(1 if smoke else 5):
        for c in DEVICE_COUNTS:
            tel = make(c).run()
            hist[str(c)].append(tel["scenes_per_sec"])
    mean = {k: sum(v) / len(v) for k, v in hist.items()}
    monotone = int(mean["1"] < mean["2"] < mean["4"])

    # N=1 degeneration: a one-device placement must be bit-identical to the
    # placement-free (pre-mesh) service
    placed, free = make(1), make(None)
    placed.run(), free.run()
    n1_bit = all(
        _leaves_equal(placed.store.latest(sid).params,
                      free.store.latest(sid).params)
        for sid in datasets
    )

    # mixed train+render load on the full mesh, async serving plane: one
    # held-out render per advanced session per quantum.  The warmup pass
    # runs the same schedule first (placement is deterministic, so sessions
    # land on the same devices) so every device's render executable is
    # already traced — p95 measures steady-state serving latency, not the
    # per-device first-contact trace.
    def hook(svc, event):
        for sid in event["cohort"]:
            svc.request_render(sid, datasets[sid].poses[0])

    make(4, async_serving=True).run(hook=hook)
    mixed = make(4, async_serving=True)
    mixed_tel = mixed.run(hook=hook)
    lat = mixed_tel["render"]
    return {
        "config": {"smoke": smoke, "scenes": scenes, "iters": iters,
                   "slice_iters": slice_iters, "hw": hw,
                   "n_rays": cfg.n_rays, "n_samples": render.n_samples,
                   "max_cohort": 2, "device_counts": list(DEVICE_COUNTS)},
        "scenes_per_sec": mean,
        "scenes_per_sec_reps": hist,
        "scenes_per_s_monotone": monotone,
        "speedup_4v1": mean["4"] / mean["1"] if mean["1"] > 0 else 0.0,
        "n1_bit_identical": bool(n1_bit),
        "render_p95_ms_mixed": lat.get("p95_ms"),
        "render_count_mixed": lat.get("count", 0),
    }


def _scale_out_subprocess(smoke: bool) -> dict:
    """Spawn the forced-topology child and collect its JSON payload."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    cmd = [sys.executable, "-m", "benchmarks.bench_serve3d", "--scale-child"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(
            f"scale-out child failed:\n{proc.stderr[-4000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith("SCALE_OUT_JSON:"):
            return json.loads(line[len("SCALE_OUT_JSON:"):])
    raise RuntimeError(f"scale-out child emitted no payload:\n{proc.stdout}")


def run(smoke: bool = False):
    scenes = 4
    iters = 16 if smoke else 96
    slice_iters = 8
    hw = 32
    views = 3 if smoke else 6
    psnr_threshold = 10.0 if smoke else 15.0

    # Two configs, one per subsystem's design regime (both recorded below):
    #
    # * serving/render (16-sample dense ladder, 32x32 views = one 1024-ray
    #   chunk): shading dominates a render, so the redistributed path's 4x
    #   point saving shows up as p50 latency.
    # * cohort sweep (8-sample ladder): the paper's on-device training
    #   regime — modest per-step compute, where per-quantum fixed costs
    #   (step dispatch, ray sampling, PRNG folds, occupancy re-query) are a
    #   real fraction of a slice and member-axis batching pays.  At fat
    #   compute-bound steps the cohort is a wash (the member axis is a scan,
    #   not SIMD) — that regime needs the ROADMAP vmap-on-TPU follow-up.
    #
    # Smoke scales down the *service* (iters, views), never the per-step or
    # per-render shapes, so smoke and full gate the same two regimes.
    field_cfg = FieldConfig(n_levels=4, max_resolution=64,
                            log2_table_density=12, log2_table_color=10)
    occ_cfg = occupancy.OccupancyConfig(update_interval=8, warmup_steps=8)
    render = RenderConfig(n_samples=16)
    trainer_cfg = TrainerConfig(n_rays=128, render=render, occ=occ_cfg,
                                eval_chunk=hw * hw)
    cohort_render = RenderConfig(n_samples=8)
    cohort_cfg = TrainerConfig(n_rays=128, render=cohort_render, occ=occ_cfg,
                               eval_chunk=hw * hw)

    datasets = {}
    for i in range(scenes):
        _scene, ds = build_dataset(seed=i, n_views=views, h=hw, w=hw,
                                   cfg=render, gt_samples=48)
        datasets[f"scene-{i:03d}"] = ds

    def make_service(max_cohort, cfg=trainer_cfg, redistributed=True
                     ) -> ReconstructionService:
        service = ReconstructionService(
            slice_iters=slice_iters, max_cohort=max_cohort,
            redistributed_render=redistributed,
        )
        for i, (sid, ds) in enumerate(datasets.items()):
            service.submit_scene(ds, field_cfg, cfg,
                                 target_iters=iters, seed=i, session_id=sid)
        return service

    # ---- headline serving run: cohort training + mid-training renders ----

    service = make_service(max_cohort=None)
    t_start = time.perf_counter()
    ttfuv: dict[str, float | None] = {sid: None for sid in datasets}
    psnr_trace: dict[str, list] = {sid: [] for sid in datasets}

    def hook(svc, event):
        for sid in event["cohort"]:  # one render request per slice, per session
            svc.request_render(sid, datasets[sid].poses[0])
        for r in event["results"]:
            psnr = float(losses.psnr(np.asarray(r.rgb),
                                     datasets[r.session_id].images[0]))
            psnr_trace[r.session_id].append((r.snapshot_step, psnr))
            if ttfuv[r.session_id] is None and psnr >= psnr_threshold:
                ttfuv[r.session_id] = time.perf_counter() - t_start

    tel = service.run(hook=hook)

    # ---- steady-state guard overhead (faults off) ----
    # the divergence guard is on by default, so the headline run already
    # paid for every inspect (loss checks, jitted finiteness reductions,
    # periodic last-good host snapshots); its share of training wall time
    # is the overhead a fault-free service pays for fault tolerance
    train_wall = sum(s.train_wall_s for s in service.sessions.values())
    g = tel["guard"]
    guard_overhead = (g["inspect_wall_s"] / train_wall) if train_wall else 0.0

    # ---- parity + bit-identity vs sequential single-scene training ----

    psnr_interleaved, psnr_sequential = {}, {}
    sequential_params = {}
    for i, (sid, ds) in enumerate(datasets.items()):
        psnr_interleaved[sid] = service.sessions[sid].evaluate(views=[0])["psnr_rgb"]
        tr = Instant3DTrainer(Field(field_cfg), trainer_cfg)
        st = tr.init(jax.random.PRNGKey(i))
        st, _ = tr.train(st, RaySampler(ds), iters=iters, log_every=iters)
        sequential_params[sid] = st.params
        # evaluate the reference under the SAME serving quadrature the
        # session's evaluate routes through (eval == served since PR 10) —
        # a dense reference here would measure the redistribute-vs-dense
        # quadrature delta, not scheduler drift
        psnr_sequential[sid] = tr.evaluate(
            st.params, ds, views=[0],
            occ=(np.asarray(st.occ_state.density_ema), int(st.occ_state.step)),
            samples_per_ray=service.sessions[sid].render_spr,
        )["psnr_rgb"]
    parity = max(abs(psnr_interleaved[s] - psnr_sequential[s]) for s in datasets)
    cohort_bit_identical = all(
        _leaves_equal(sequential_params[sid],
                      service.sessions[sid]._current_params())
        for sid in datasets
    )

    # ---- cohort sweep: scenes/sec at train-cohort caps {1, 2, 4} ----
    # (no render traffic — pure multi-scene training throughput; one warmup
    # pass per cap compiles its member-axis steps, then the caps are timed
    # INTERLEAVED over several reps and each cap keeps its best, so machine
    # drift hits every cap alike instead of whichever ran last)

    sweep = {str(cap): 0.0 for cap in COHORT_SIZES}
    sweep_params: dict[int, dict] = {}
    for cap in COHORT_SIZES:
        make_service(max_cohort=cap, cfg=cohort_cfg).run()  # warm compile
    for rep in range(3):
        for cap in COHORT_SIZES:
            svc = make_service(max_cohort=cap, cfg=cohort_cfg)
            t = svc.run()
            sweep[str(cap)] = max(sweep[str(cap)], t["scenes_per_sec"])
            sweep_params[cap] = {
                sid: svc.sessions[sid]._current_params() for sid in datasets
            }
    speedup_4v1 = sweep["4"] / sweep["1"] if sweep["1"] > 0 else 0.0
    sweep_bit_identical = all(
        _leaves_equal(sweep_params[1][sid], sweep_params[4][sid])
        for sid in datasets
    )

    # ---- render path: steady-state dense vs redistributed on one store ----

    spr = min(render.n_samples, max(4, render.n_samples // 4))  # service default
    dense_renderer = RenderService(service.store)
    for sid, ds in datasets.items():
        dense_renderer.register_session(
            sid, field_cfg, render, ds.h, ds.w, ds.focal, trainer_cfg.eval_chunk)

    def steady_latency(renderer):
        lats, psnrs = [], []
        for rep in range(6):
            for sid, ds in datasets.items():
                renderer.submit(sid, ds.poses[0])
            results = renderer.drain()
            if rep < 2:  # discard compile + cache-warm rounds
                continue
            lats += [r.latency_s for r in results]
            psnrs += [float(losses.psnr(np.asarray(r.rgb),
                                        datasets[r.session_id].images[0]))
                      for r in results]
        return float(np.median(lats) * 1e3), float(np.mean(psnrs))

    redist_p50, redist_psnr = steady_latency(service.renderer)
    dense_p50, dense_psnr = steady_latency(dense_renderer)
    p50_ratio = redist_p50 / dense_p50 if dense_p50 > 0 else float("inf")
    psnr_cost = dense_psnr - redist_psnr

    # ---- scale-out: the session-sharded service on a forced device mesh ----

    scale_out = _scale_out_subprocess(smoke)

    lat = tel["render"]
    out = {
        "config": {
            "smoke": smoke, "scenes": scenes, "iters_per_scene": iters,
            "slice_iters": slice_iters, "hw": hw, "views": views,
            "n_rays": trainer_cfg.n_rays, "n_samples": render.n_samples,
            "cohort_sweep_n_samples": cohort_render.n_samples,
            "psnr_threshold_db": psnr_threshold,
            "render_samples_per_ray": spr,
        },
        "wall_s": tel["wall_s"],
        "scenes_per_sec": tel["scenes_per_sec"],
        "render_latency_ms": {
            "count": lat.get("count", 0),
            "p50": lat.get("p50_ms"), "p95": lat.get("p95_ms"),
            "max": lat.get("max_ms"),
        },
        "time_to_first_usable_view_s": ttfuv,
        "psnr_trace": psnr_trace,
        "parity": {
            "interleaved_db": psnr_interleaved,
            "sequential_db": psnr_sequential,
            "max_abs_diff_db": parity,
        },
        "cohort": {
            "scenes_per_sec": sweep,
            "speedup_4v1": speedup_4v1,
            "bit_identical": bool(cohort_bit_identical and sweep_bit_identical),
        },
        "render_path": {
            "dense_p50_ms": dense_p50,
            "redistributed_p50_ms": redist_p50,
            "p50_ratio": p50_ratio,
            "psnr_dense_db": dense_psnr,
            "psnr_redistributed_db": redist_psnr,
            "psnr_cost_db": psnr_cost,
        },
        "guard": {
            "overhead_frac": guard_overhead,
            "inspect_wall_s": g["inspect_wall_s"],
            "train_wall_s": train_wall,
            "checkpoints": g["checkpoints"],
            "rollbacks": g["rollbacks"],
        },
        "scale_out": scale_out,
    }
    with open("BENCH_serve3d.json", "w") as f:
        json.dump(out, f, indent=2)

    common.emit(
        "serve3d_service",
        tel["wall_s"] * 1e6 / max(1, scenes * iters),
        f"scenes_per_sec={tel['scenes_per_sec']:.3f};"
        f"p50_ms={lat.get('p50_ms', 0):.0f};p95_ms={lat.get('p95_ms', 0):.0f};"
        f"parity_db={parity:.4f}",
    )
    common.emit(
        "serve3d_cohort",
        0.0,
        ";".join(f"sps[{c}]={sweep[str(c)]:.3f}" for c in COHORT_SIZES)
        + f";speedup_4v1={speedup_4v1:.3f};bit_identical={out['cohort']['bit_identical']}",
    )
    common.emit(
        "serve3d_render_path",
        redist_p50 * 1e3,
        f"p50_ratio={p50_ratio:.3f};psnr_cost_db={psnr_cost:.3f};spr={spr}",
    )
    common.emit(
        "serve3d_guard_overhead",
        guard_overhead * 1e6,  # fraction in micro-units for the CSV column
        f"overhead_frac={guard_overhead:.5f};checkpoints={g['checkpoints']};"
        f"rollbacks={g['rollbacks']}",
    )
    for sid, t in ttfuv.items():
        common.emit(f"serve3d_ttfuv[{sid}]", (t or 0.0) * 1e6,
                    f"ttfuv_s={'%.2f' % t if t is not None else 'n/a'};"
                    f"threshold_db={psnr_threshold}")
    common.emit(
        "serve3d_scale_out",
        0.0,
        ";".join(f"sps[{c}]={scale_out['scenes_per_sec'][str(c)]:.3f}"
                 for c in DEVICE_COUNTS)
        + f";monotone={scale_out['scenes_per_s_monotone']}"
        + f";n1_bit_identical={scale_out['n1_bit_identical']}"
        + f";p95_mixed_ms={scale_out['render_p95_ms_mixed']:.0f}",
    )
    assert scale_out["n1_bit_identical"], (
        "one-device placement diverged bitwise from the placement-free path")
    assert parity <= 0.1, (
        f"interleaved vs sequential PSNR drifted {parity:.3f} dB (> 0.1)")
    assert out["cohort"]["bit_identical"], (
        "cohort-batched training diverged from sequential time-slicing")
    assert psnr_cost <= 0.1, (
        f"redistributed render path costs {psnr_cost:.3f} dB (> 0.1)")
    assert g["rollbacks"] == 0, (
        f"guard rolled back {g['rollbacks']}x in a fault-free run "
        "(divergence heuristic misfiring)")
    assert guard_overhead <= 0.01, (
        f"steady-state guard overhead {guard_overhead:.4f} > 1%")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="4 sessions x few iters x 1 render/slice (CI gate)")
    ap.add_argument("--scale-child", action="store_true",
                    help="internal: run the scale-out sweep in this process "
                         "(expects a forced >=4-device topology) and print "
                         "its JSON payload instead of the full benchmark")
    args = ap.parse_args()
    if args.scale_child:
        payload = run_scale_out(smoke=args.smoke)
        print("SCALE_OUT_JSON:" + json.dumps(payload))
        return
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
