"""serve3d service benchmark -> BENCH_serve3d.json.

Measures the reconstruction service end to end: N procedural scenes train
concurrently under the round-robin scheduler while a novel-view render of a
held-out pose is requested after every slice.  Records

* scenes/sec (completed reconstructions per wall-clock second),
* p50/p95 render latency (request submit -> result, mid-training),
* time-to-first-usable-view per scene (first served render whose PSNR
  against ground truth crosses the threshold),
* PSNR parity: the interleaved scheduler must reach the same PSNR per scene
  as sequential single-scene training at equal per-scene iteration counts
  (the deterministic step-keyed streams make this exact, not just close).

    PYTHONPATH=src python -m benchmarks.bench_serve3d [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import Field, FieldConfig, Instant3DTrainer, TrainerConfig, losses, occupancy
from repro.core.rendering import RenderConfig
from repro.data import build_dataset, RaySampler
from repro.serve3d import ReconstructionService

from . import common


def run(smoke: bool = False):
    scenes = 2 if smoke else 4
    iters = 16 if smoke else 96
    slice_iters = 8
    hw = 16 if smoke else 24
    views = 3 if smoke else 6
    psnr_threshold = 10.0 if smoke else 15.0

    render = RenderConfig(n_samples=8 if smoke else 16)
    field_cfg = FieldConfig(n_levels=4, max_resolution=64,
                            log2_table_density=12, log2_table_color=10)
    trainer_cfg = TrainerConfig(
        n_rays=128 if smoke else 256, render=render,
        occ=occupancy.OccupancyConfig(update_interval=8, warmup_steps=8),
        eval_chunk=hw * hw,
    )

    service = ReconstructionService(slice_iters=slice_iters)
    datasets = {}
    for i in range(scenes):
        _scene, ds = build_dataset(seed=i, n_views=views, h=hw, w=hw,
                                   cfg=render, gt_samples=48)
        sid = service.submit_scene(ds, field_cfg, trainer_cfg,
                                   target_iters=iters, seed=i)
        datasets[sid] = ds

    t_start = time.perf_counter()
    ttfuv: dict[str, float | None] = {sid: None for sid in datasets}
    psnr_trace: dict[str, list] = {sid: [] for sid in datasets}

    def hook(svc, event):
        sid = event["trained"]
        if sid is not None:  # one render request per slice, per session
            svc.request_render(sid, datasets[sid].poses[0])
        for r in event["results"]:
            psnr = float(losses.psnr(np.asarray(r.rgb),
                                     datasets[r.session_id].images[0]))
            psnr_trace[r.session_id].append((r.snapshot_step, psnr))
            if ttfuv[r.session_id] is None and psnr >= psnr_threshold:
                ttfuv[r.session_id] = time.perf_counter() - t_start

    tel = service.run(hook=hook)

    # parity: sequential single-scene training at equal iteration counts
    psnr_interleaved, psnr_sequential = {}, {}
    for i, (sid, ds) in enumerate(datasets.items()):
        psnr_interleaved[sid] = service.sessions[sid].evaluate(views=[0])["psnr_rgb"]
        tr = Instant3DTrainer(Field(field_cfg), trainer_cfg)
        st = tr.init(jax.random.PRNGKey(i))
        st, _ = tr.train(st, RaySampler(ds), iters=iters, log_every=iters)
        psnr_sequential[sid] = tr.evaluate(st.params, ds, views=[0])["psnr_rgb"]
    parity = max(abs(psnr_interleaved[s] - psnr_sequential[s]) for s in datasets)

    lat = tel["render"]
    out = {
        "config": {
            "smoke": smoke, "scenes": scenes, "iters_per_scene": iters,
            "slice_iters": slice_iters, "hw": hw, "views": views,
            "n_rays": trainer_cfg.n_rays, "n_samples": render.n_samples,
            "psnr_threshold_db": psnr_threshold,
        },
        "wall_s": tel["wall_s"],
        "scenes_per_sec": tel["scenes_per_sec"],
        "render_latency_ms": {
            "count": lat.get("count", 0),
            "p50": lat.get("p50_ms"), "p95": lat.get("p95_ms"),
            "max": lat.get("max_ms"),
        },
        "time_to_first_usable_view_s": ttfuv,
        "psnr_trace": psnr_trace,
        "parity": {
            "interleaved_db": psnr_interleaved,
            "sequential_db": psnr_sequential,
            "max_abs_diff_db": parity,
        },
    }
    with open("BENCH_serve3d.json", "w") as f:
        json.dump(out, f, indent=2)

    common.emit(
        "serve3d_service",
        tel["wall_s"] * 1e6 / max(1, scenes * iters),
        f"scenes_per_sec={tel['scenes_per_sec']:.3f};"
        f"p50_ms={lat.get('p50_ms', 0):.0f};p95_ms={lat.get('p95_ms', 0):.0f};"
        f"parity_db={parity:.4f}",
    )
    for sid, t in ttfuv.items():
        common.emit(f"serve3d_ttfuv[{sid}]", (t or 0.0) * 1e6,
                    f"ttfuv_s={'%.2f' % t if t is not None else 'n/a'};"
                    f"threshold_db={psnr_threshold}")
    assert parity <= 0.1, (
        f"interleaved vs sequential PSNR drifted {parity:.3f} dB (> 0.1)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="2 sessions x few iters x 1 render/slice (CI gate)")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
