"""Chaos benchmark -> BENCH_robustness.json.

Runs the serve3d service twice over the same scene set with live render
traffic: once under injected faults (a NaN-params slice, a snapshot publish
failure, a straggler slice — `repro.testing.faults`) with overload shedding
armed, and once fault-free as the control.  Records the recovery contract:

* every session finishes despite the faults, with >= 1 guard rollback,
* uninjected sessions end *bit-identical* to the control run (0.0 dB PSNR
  parity — a fault in one cohort member never perturbs survivors),
* the injected session also re-converges bit-identically (rollback +
  absolute-step-keyed retraining reproduces the fault-free stream),
* recovery latency p50/p95 (divergence detected -> last-good restored),
* degradation telemetry: publish retries, shed fraction, stragglers.

    PYTHONPATH=src python -m benchmarks.bench_robustness [--smoke]

CI's chaos-smoke leg runs this with --smoke and gates the artifact via
tools/bench_gate.py.  Steady-state guard *overhead* is measured in
bench_serve3d (its fault-free headline run), not here.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core import FieldConfig, TrainerConfig, occupancy
from repro.core.rendering import RenderConfig
from repro.data import build_dataset
from repro.serve3d import DONE, ReconstructionService
from repro.testing import faults

from . import common

INJECTED = "scene-001"           # takes the NaN slice (the divergence fault)


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def run(smoke: bool = False):
    scenes = 4
    iters = 16 if smoke else 48
    slice_iters = 4
    hw = 24
    views = 2 if smoke else 3

    field_cfg = FieldConfig(n_levels=4, max_resolution=64,
                            log2_table_density=12, log2_table_color=10)
    occ_cfg = occupancy.OccupancyConfig(update_interval=8, warmup_steps=8)
    render = RenderConfig(n_samples=8)
    trainer_cfg = TrainerConfig(n_rays=128, render=render, occ=occ_cfg,
                                eval_chunk=hw * hw)

    datasets = {}
    for i in range(scenes):
        _scene, ds = build_dataset(seed=i, n_views=views, h=hw, w=hw,
                                   cfg=render, gt_samples=48)
        datasets[f"scene-{i:03d}"] = ds

    def make_service() -> ReconstructionService:
        # shed_threshold below the per-quantum request count so the chaos
        # run exercises the quality-shedding rung of the degradation ladder
        svc = ReconstructionService(slice_iters=slice_iters,
                                    shed_threshold=scenes - 1,
                                    render_deadline_s=60.0)
        for i, (sid, ds) in enumerate(datasets.items()):
            svc.submit_scene(ds, field_cfg, trainer_cfg,
                             target_iters=iters, seed=i, session_id=sid)
        return svc

    def hook(svc, event):
        for sid in event["cohort"]:   # one render per advanced session
            svc.request_render(sid, datasets[sid].poses[0])

    # ---- chaos run ----
    faults.reset()
    faults.configure(enabled=True)
    faults.inject("serve3d.slice", "nan_params", session=INJECTED,
                  at_step=iters // 2)
    faults.inject("serve3d.snapshot_publish", "snapshot_fail",
                  session="scene-002", at_step=iters // 4)
    faults.inject("serve3d.slice", "slow", session="scene-003",
                  at_step=iters // 2, seconds=0.5)
    svc_f = make_service()
    tel_f = svc_f.run(hook=hook)
    fired = {k: faults.fired_count(k)
             for k in ("nan_params", "snapshot_fail", "slow")}
    faults.reset()
    faults.configure(enabled=False)

    # ---- fault-free control over the same scenes ----
    svc_c = make_service()
    svc_c.run(hook=hook)

    all_done = all(s.status == DONE for s in svc_f.sessions.values())
    bit_identical = {
        sid: bool(_leaves_equal(svc_f.sessions[sid]._current_params(),
                                svc_c.sessions[sid]._current_params()))
        for sid in datasets
    }
    uninjected = [sid for sid in datasets if sid != INJECTED]
    # PSNR parity over uninjected sessions: bit-identical params render
    # bit-identical pixels, so this is exactly 0.0 when recovery held
    parity_db = max(
        abs(svc_f.sessions[sid].evaluate(views=[0])["psnr_rgb"]
            - svc_c.sessions[sid].evaluate(views=[0])["psnr_rgb"])
        for sid in uninjected
    )

    guard = tel_f["guard"]
    degraded = svc_f.renderer.latency_stats().get("degraded", {})
    out = {
        "config": {
            "smoke": smoke, "scenes": scenes, "iters_per_scene": iters,
            "slice_iters": slice_iters, "hw": hw, "views": views,
            "injected_session": INJECTED,
            "faults": ["nan_params", "snapshot_fail", "slow"],
        },
        "faults_fired": fired,
        "all_sessions_done": bool(all_done),
        "rollbacks": guard["rollbacks"],
        "quarantined": guard["quarantined"],
        "divergences": guard["divergences"],
        "recovery_ms": guard["recovery_ms"],
        "uninjected_parity_db": float(parity_db),
        "uninjected_bit_identical": bool(all(bit_identical[s]
                                             for s in uninjected)),
        "injected_bit_identical": bit_identical[INJECTED],
        "bit_identical": bit_identical,
        "publish_failures": svc_f.publish_failures,
        "stragglers_flagged": tel_f["stragglers_flagged"],
        "render": {
            "served": tel_f["render"].get("count", 0),
            "expired": degraded.get("expired", 0),
            "failed": degraded.get("failed", 0),
            "shed_fraction": degraded.get("shed_fraction", 0.0),
        },
    }
    with open("BENCH_robustness.json", "w") as f:
        json.dump(out, f, indent=2)

    common.emit(
        "serve3d_chaos",
        float(guard["recovery_ms"]["p95"] or 0.0) * 1e3,  # ms -> us
        f"rollbacks={guard['rollbacks']};"
        f"recovery_p50_ms={guard['recovery_ms']['p50']};"
        f"parity_db={parity_db:.4f};"
        f"shed_fraction={out['render']['shed_fraction']:.3f};"
        f"publish_failures={svc_f.publish_failures}",
    )

    assert fired["nan_params"] == 1 and fired["snapshot_fail"] == 1, fired
    assert all_done, "a session failed to finish under injected faults"
    assert guard["rollbacks"] >= 1, "NaN slice produced no rollback"
    assert out["uninjected_bit_identical"], (
        "an uninjected session diverged from the fault-free run")
    assert parity_db == 0.0, (
        f"uninjected PSNR parity {parity_db} dB != 0.0")
    assert svc_f.publish_failures >= 1, "publish fault did not register"
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="4 sessions x 16 iters chaos run (CI chaos-smoke leg)")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
