"""Paper Figs. 8-10: hash-address locality + unique-address windows.

Fig. 8/9: the 8 interpolation corners form 4 groups (pairs differing only in
x); intra-group address distances are tiny (90% within +-5) because pi1 = 1,
inter-group distances are huge (pi2, pi3 amplification).
Fig. 10: backward-pass update streams revisit addresses (~5x duplication in
a 1000-access window); forward streams of distinct points do not merge.

FMU tracking (ISSUE 3): corner-read dedup ratio for Morton-sorted vs
unsorted compacted batches at several occupancy levels — the fraction of a
kernel block's corner reads the FMU can coalesce away grows as occupancy
shrinks (the live set concentrates) and as the batch is spatially ordered.
"""
import numpy as np
import jax.numpy as jnp

from . import common
from repro.kernels.hash_encode import ref
from repro.kernels.grid_update import ref as gu_ref
from repro.kernels.fused_path import ref as fp_ref


def run():
    rng = np.random.default_rng(0)
    t = 1 << 19
    res = 128  # hashed level: (129)^3 >> 2^19
    pts = jnp.asarray(rng.uniform(0, 1, size=(4096, 3)).astype(np.float32))
    corners, _ = ref._level_corners(pts, res)
    idx = np.asarray(ref.corner_index(corners, res, t, dense=False))  # (N, 8)

    # groups: corners pairs (c, c+1) differ only in x (corner id bit 0)
    intra = np.abs(idx[:, 1::2].astype(np.int64) - idx[:, 0::2].astype(np.int64))
    frac_small = float((intra <= 5).mean())
    inter = np.abs(idx[:, [0, 2, 4, 6]].astype(np.int64)
                   - idx[:, [2, 4, 6, 0]].astype(np.int64)).mean()
    common.emit("fig9_intra_group_locality", 0.0,
                f"frac_dist_le_5={frac_small:.2%};paper_claims=~90%")
    common.emit("fig8_inter_group_distance", 0.0, f"mean={inter:.0f};paper_claims=~60000")

    # Fig. 10: unique addresses per 1000-access window, fwd vs bwd
    fwd_stream = idx.reshape(-1)  # forward visit order
    uniq_fwd = float(gu_ref.unique_fraction(jnp.asarray(fwd_stream), 1000))
    # backward: all 8 corners of each point write; duplication comes from
    # nearby points sharing cube corners — simulate a ray-ordered batch
    ray_pts = jnp.asarray(np.cumsum(rng.normal(scale=0.002, size=(4096, 3)), 0) % 1.0,
                          jnp.float32)
    rcorners, _ = ref._level_corners(ray_pts, res)
    ridx = np.asarray(ref.corner_index(rcorners, res, t, dense=False)).reshape(-1)
    uniq_bwd = float(gu_ref.unique_fraction(jnp.asarray(ridx), 1000))
    common.emit("fig10_unique_window", 0.0,
                f"fwd_unique={uniq_fwd:.2f};bwd_unique={uniq_bwd:.2f};paper=~1.0_vs_~0.2")

    # FMU dedup tracking: compacted batches at several occupancy levels.
    # Live points concentrate in an occupied sub-box of the unit cube; the
    # compacted batch is the same point set in flat (ray) order vs Morton
    # order.  Block ratio = unique reads per (256-point block, level) —
    # what the fused kernel's in-block dedup sees.
    levels, t6 = 6, 1 << 13  # bench-scale density grid (common.BASE_FIELD)
    res6 = ref.level_resolutions(levels, 16, 96)
    dense6 = tuple(bool(x) for x in ref.level_is_dense(res6, t6))
    n_batch = 2048
    dedup_sweep = {}
    for occ_frac in (1.0, 0.5, 0.25, 0.1):
        side = occ_frac ** (1.0 / 3.0)  # occupied region: corner sub-box
        pts = jnp.asarray(
            (rng.uniform(0, 1, size=(n_batch, 3)) * side).astype(np.float32))
        srt = pts[jnp.argsort(fp_ref.morton_key(pts))]
        s_flat = fp_ref.dedup_stats(pts, res6, dense6, t6, block_points=256)
        s_mort = fp_ref.dedup_stats(srt, res6, dense6, t6, block_points=256)
        dedup_sweep[occ_frac] = (s_mort["unique_ratio_block"],
                                 s_flat["unique_ratio_block"])
        common.emit(f"fmu_dedup[occ={occ_frac}]", 0.0,
                    f"block_unique_morton={s_mort['unique_ratio_block']:.3f};"
                    f"block_unique_flat={s_flat['unique_ratio_block']:.3f};"
                    f"global_unique={s_mort['unique_ratio_global']:.3f}")
    return {"frac_small": frac_small, "uniq_fwd": uniq_fwd, "uniq_bwd": uniq_bwd,
            "dedup_sweep": dedup_sweep}


if __name__ == "__main__":
    run()
