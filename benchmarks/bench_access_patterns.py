"""Paper Figs. 8-10: hash-address locality + unique-address windows.

Fig. 8/9: the 8 interpolation corners form 4 groups (pairs differing only in
x); intra-group address distances are tiny (90% within +-5) because pi1 = 1,
inter-group distances are huge (pi2, pi3 amplification).
Fig. 10: backward-pass update streams revisit addresses (~5x duplication in
a 1000-access window); forward streams of distinct points do not merge.
"""
import numpy as np
import jax.numpy as jnp

from . import common
from repro.kernels.hash_encode import ref
from repro.kernels.grid_update import ref as gu_ref


def run():
    rng = np.random.default_rng(0)
    t = 1 << 19
    res = 128  # hashed level: (129)^3 >> 2^19
    pts = jnp.asarray(rng.uniform(0, 1, size=(4096, 3)).astype(np.float32))
    corners, _ = ref._level_corners(pts, res)
    idx = np.asarray(ref.corner_index(corners, res, t, dense=False))  # (N, 8)

    # groups: corners pairs (c, c+1) differ only in x (corner id bit 0)
    intra = np.abs(idx[:, 1::2].astype(np.int64) - idx[:, 0::2].astype(np.int64))
    frac_small = float((intra <= 5).mean())
    inter = np.abs(idx[:, [0, 2, 4, 6]].astype(np.int64)
                   - idx[:, [2, 4, 6, 0]].astype(np.int64)).mean()
    common.emit("fig9_intra_group_locality", 0.0,
                f"frac_dist_le_5={frac_small:.2%};paper_claims=~90%")
    common.emit("fig8_inter_group_distance", 0.0, f"mean={inter:.0f};paper_claims=~60000")

    # Fig. 10: unique addresses per 1000-access window, fwd vs bwd
    fwd_stream = idx.reshape(-1)  # forward visit order
    uniq_fwd = float(gu_ref.unique_fraction(jnp.asarray(fwd_stream), 1000))
    # backward: all 8 corners of each point write; duplication comes from
    # nearby points sharing cube corners — simulate a ray-ordered batch
    ray_pts = jnp.asarray(np.cumsum(rng.normal(scale=0.002, size=(4096, 3)), 0) % 1.0,
                          jnp.float32)
    rcorners, _ = ref._level_corners(ray_pts, res)
    ridx = np.asarray(ref.corner_index(rcorners, res, t, dense=False)).reshape(-1)
    uniq_bwd = float(gu_ref.unique_fraction(jnp.asarray(ridx), 1000))
    common.emit("fig10_unique_window", 0.0,
                f"fwd_unique={uniq_fwd:.2f};bwd_unique={uniq_bwd:.2f};paper=~1.0_vs_~0.2")
    return {"frac_small": frac_small, "uniq_fwd": uniq_fwd, "uniq_bwd": uniq_bwd}


if __name__ == "__main__":
    run()
