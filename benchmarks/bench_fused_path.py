"""Fused training-step kernels vs the PR 1 compacted baseline.

Trains the same scene three times — `fused_path=False` (PR 1: per-grid
encode + merged backward with its own argsort), `fused_path=True` (PR 3:
one encode pass over all grids on the Morton-ordered budget batch,
pre-sorted BUM backward), and `fused_step=True` (PR 6: the whole
encode->MLP chain inside ONE differentiable op with the recompute residual
policy) — and emits `BENCH_fused_path.json` with:

* `unique_corner_reads`: FMU accounting at steady-state occupancy — the
  fraction of corner reads hitting distinct addresses per kernel block (and
  globally), for the Morton-sorted batch vs the PR 1 flat-order batch.
  Every duplicate inside a block is a read the FMU serves from one access.
* `us_per_step` for both variants: the jitted step functions (full step and
  freeze_color step, weighted per the F_D:F_C = 1:0.5 schedule) timed on a
  fixed steady-state batch, interleaved across variants, best-of-reps;
  `time_ratio` = median of per-rep *paired* fused/compacted ratios (machine
  drift cancels within a rep) and must stay <= 1.0 (CI gate).
* `params_bit_identical` + `psnr_rgb_delta`: the fused path is the same
  math, so after identical training runs the parameters must match bit for
  bit and the PSNR delta must be exactly 0.0.
* `fused_step`: the same three report legs for the one-kernel step —
  paired time ratios vs the compacted baseline (schedule-weighted and
  full-step-only, the latter gated against the committed PR 3 fused-path
  trajectory), bit-identity of a full training run against the PR 3 fused
  variant, and the static residual-bytes accounting for both residual
  policies at the steady-state budget (the recompute-vs-stash memory win).
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Field, Instant3DTrainer, occupancy
from repro.core.rendering import sample_ts
from repro.data import RaySampler
from repro.kernels.fused_path import ref as fp_ref
from repro.kernels.fused_step import ref as fs_ref

from .common import BASE_FIELD, BASE_TRAIN, dataset, emit

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_fused_path.json"


def _train_variant(fused: bool, iters: int, fused_step: bool = False):
    scene, ds = dataset()
    tr = Instant3DTrainer(
        Field(BASE_FIELD),
        replace(BASE_TRAIN, fused_path=fused, fused_step=fused_step),
    )
    state = tr.init(jax.random.PRNGKey(0))
    sampler = RaySampler(ds)
    state, hist = tr.train(state, sampler, iters=iters, log_every=max(iters // 4, 1))
    # settle one occupancy interval so the budget bucket is warm/compiled
    state, _ = tr.train(state, sampler, iters=tr.cfg.occ.update_interval,
                        log_every=tr.cfg.occ.update_interval)
    return tr, state, sampler, ds, hist


def _time_step(tr, state, batch, ts, budget, freeze_color: bool, iters: int) -> float:
    """ms per jitted training step on a fixed batch (pure kernel time, no
    sampler/occupancy-loop overhead — that part is identical across
    variants and an order noisier than the difference under test)."""
    step = tr.step_fn(freeze_color, False, budget, True)
    # step donates params/opt_state: chain copies, keep `state` intact
    p = jax.tree.map(jnp.copy, state.params)
    o = jax.tree.map(jnp.copy, state.opt_state)
    for _ in range(2):
        p, o, loss, _ = step(p, o, batch, ts, state.occ_state.density_ema)
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(iters):
        p, o, loss, _ = step(p, o, batch, ts, state.occ_state.density_ema)
    jax.block_until_ready(loss)
    return (time.perf_counter() - t0) / iters * 1e3


def _dedup_stats(tr, state, sampler):
    """FMU read accounting on a real steady-state budget batch."""
    cfg = tr.cfg
    pipe = tr.pipeline
    field = tr.field
    key = jax.random.PRNGKey(123)
    kb, kt = jax.random.split(key)
    batch = sampler.sample(kb, cfg.n_rays)
    ts = sample_ts(kt, cfg.n_rays, cfg.render)
    bits = occupancy.bitfield(state.occ_state, cfg.occ)
    flat_pts, flat_dirs, unit = pipe.generate_samples(batch.origins, batch.dirs, ts)
    live = pipe.cull(flat_pts, unit, bitfield=bits)
    budget = tr._current_budget(True) or unit.shape[0]

    res = field.density_enc.resolutions
    grids = [("density", field.density_enc)]
    if field.color_enc is not None:
        grids.append(("color", field.color_enc))

    out = {"budget": int(budget), "live_fraction": float(np.mean(np.asarray(live)))}
    for order_name, plan in (
        ("morton", pipe.compact(live, budget, unit)),
        ("flat", pipe.compact(live, budget)),
    ):
        pts = unit[plan.idx]
        total, uniq, block_ratios = 0, 0, []
        per_grid = {}
        for gname, enc in grids:
            s = fp_ref.dedup_stats(pts, res, enc.dense_flags, enc.cfg.table_size)
            total += s["total_reads"]
            uniq += s["unique_reads_global"]
            block_ratios.append((s["unique_ratio_block"], s["n_blocks"]))
            per_grid[gname] = {
                "unique_ratio_global": s["unique_ratio_global"],
                "unique_ratio_block": s["unique_ratio_block"],
            }
        blk = sum(r * n for r, n in block_ratios) / sum(n for _, n in block_ratios)
        out[order_name] = {
            "total_reads": total,
            "unique_ratio_global": uniq / total,
            "unique_ratio_block": blk,
            "per_grid": per_grid,
        }
    return out


def run(smoke: bool = False) -> None:
    # smoke still needs occupancy to converge (warmup 32 + a few updates),
    # else the timing runs at ramp-phase budgets where the fused path isn't
    # engaged yet
    iters = 100 if smoke else BASE_TRAIN.iters
    # timing is cheap next to the training runs; extra reps buy noise
    # immunity for the CI time-ratio gate
    reps, timed_iters = (5, 10) if smoke else (5, 20)

    tr_f, st_f, sam_f, ds, hist_f = _train_variant(True, iters)
    tr_u, st_u, sam_u, _, hist_u = _train_variant(False, iters)
    tr_s, st_s, sam_s, _, hist_s = _train_variant(True, iters, fused_step=True)

    # Time the two jitted step flavors the F_D:F_C = 1:0.5 schedule runs
    # (full step, freeze_color step) on a fixed steady-state batch.
    # Interleave variants across reps and keep the per-flavor minimum —
    # robust against this container's scheduler noise.
    budget = tr_f._current_budget(True)
    kb, kt = jax.random.split(jax.random.PRNGKey(7))
    batch = sam_f.sample(kb, BASE_TRAIN.n_rays)
    ts = sample_ts(kt, BASE_TRAIN.n_rays, BASE_TRAIN.render)
    best = {}
    rep_ratios, step_ratios, step_full_ratios = [], [], []
    legs = {
        "fused_step": (tr_s, st_s),
        "fused": (tr_f, st_f),
        "compacted": (tr_u, st_u),
    }
    for _ in range(reps):
        totals = {}
        rep_ms = {}
        # palindromic order within a rep: linear machine drift across the
        # rep hits every variant equally and cancels out of the paired ratios
        for name in ("fused_step", "fused", "compacted",
                     "compacted", "fused", "fused_step"):
            tr, st = legs[name]
            for fc in (False, True):
                ms = _time_step(tr, st, batch, ts, budget, fc, timed_iters)
                key = (name, fc)
                best[key] = min(best.get(key, np.inf), ms)
                rep_ms[key] = min(rep_ms.get(key, np.inf), ms)
                totals[name] = totals.get(name, 0.0) + ms
        rep_ratios.append(totals["fused"] / totals["compacted"])
        step_ratios.append(totals["fused_step"] / totals["compacted"])
        step_full_ratios.append(
            rep_ms[("fused_step", False)] / rep_ms[("compacted", False)])
    # schedule-weighted us/step: half the iterations freeze the color branch
    us_fused = (best[("fused", False)] + best[("fused", True)]) / 2 * 1e3
    us_compacted = (best[("compacted", False)] + best[("compacted", True)]) / 2 * 1e3
    us_step = (best[("fused_step", False)] + best[("fused_step", True)]) / 2 * 1e3
    time_ratio = float(np.median(rep_ratios))

    # identical-math check: same seeds, same stream -> params must match bits
    leaves_f = jax.tree_util.tree_leaves(st_f.params)
    leaves_u = jax.tree_util.tree_leaves(st_u.params)
    leaves_s = jax.tree_util.tree_leaves(st_s.params)
    bit_identical = all(bool(np.array_equal(np.asarray(a), np.asarray(b)))
                        for a, b in zip(leaves_f, leaves_u))
    step_bit_identical = all(bool(np.array_equal(np.asarray(a), np.asarray(b)))
                             for a, b in zip(leaves_s, leaves_f))
    ev_f = tr_f.evaluate(st_f.params, ds, views=[0, 1])
    ev_u = tr_u.evaluate(st_u.params, ds, views=[0, 1])
    ev_s = tr_s.evaluate(st_s.params, ds, views=[0, 1])

    # residual footprint at the steady-state budget: static accounting from
    # the oracle (nothing allocated), both policies of the one-kernel step
    sizes = (tr_s.field.density_enc.cfg.table_size,
             tr_s.field.color_enc.cfg.table_size)
    counts = tr_s.field.param_counts(st_s.params)
    rb = {pol: fs_ref.residual_bytes(
        pol, int(budget or BASE_TRAIN.n_rays), BASE_FIELD.n_levels,
        BASE_FIELD.n_features, sizes, tr_s.field.sh_dim,
        counts["density_mlp"], counts["color_mlp"])
        for pol in ("stash", "recompute")}

    dedup = _dedup_stats(tr_f, st_f, sam_f)

    result = {
        "smoke": smoke,
        "iters": iters,
        "unique_corner_reads": dedup,
        "budget": int(budget) if budget else None,
        "fused": {"us_per_step": us_fused,
                  "us_full_step": best[("fused", False)] * 1e3,
                  "us_freeze_color_step": best[("fused", True)] * 1e3,
                  "psnr_rgb": ev_f["psnr_rgb"],
                  "overflow_total": hist_f["overflow_total"]},
        "compacted": {"us_per_step": us_compacted,
                      "us_full_step": best[("compacted", False)] * 1e3,
                      "us_freeze_color_step": best[("compacted", True)] * 1e3,
                      "psnr_rgb": ev_u["psnr_rgb"],
                      "overflow_total": hist_u["overflow_total"]},
        "time_ratio": time_ratio,
        "time_ratio_per_rep": [round(r, 4) for r in rep_ratios],
        "time_ratio_best": us_fused / us_compacted,
        "params_bit_identical": bit_identical,
        "psnr_rgb_delta": ev_f["psnr_rgb"] - ev_u["psnr_rgb"],
        "fused_step": {
            "us_per_step": us_step,
            "us_full_step": best[("fused_step", False)] * 1e3,
            "us_freeze_color_step": best[("fused_step", True)] * 1e3,
            "psnr_rgb": ev_s["psnr_rgb"],
            "overflow_total": hist_s["overflow_total"],
            "time_ratio": float(np.median(step_ratios)),
            "time_ratio_per_rep": [round(r, 4) for r in step_ratios],
            "time_ratio_full_step": float(np.median(step_full_ratios)),
            "params_bit_identical": step_bit_identical,
            "psnr_rgb_delta": ev_s["psnr_rgb"] - ev_u["psnr_rgb"],
            "residual_bytes": {
                "n_points": int(budget or BASE_TRAIN.n_rays),
                "stash": rb["stash"],
                "recompute": rb["recompute"],
                "ratio": rb["recompute"] / rb["stash"],
            },
        },
    }
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")

    m, f = dedup["morton"], dedup["flat"]
    emit("fused_path[fused]", us_fused, f"psnr={ev_f['psnr_rgb']:.2f}")
    emit("fused_path[compacted_pr1]", us_compacted, f"psnr={ev_u['psnr_rgb']:.2f}")
    emit("fused_path[fused_step]", us_step,
         f"psnr={ev_s['psnr_rgb']:.2f};"
         f"time_ratio={result['fused_step']['time_ratio']:.3f};"
         f"full_step_ratio={result['fused_step']['time_ratio_full_step']:.3f};"
         f"bit_identical={step_bit_identical}")
    emit("fused_path[residual_bytes]", 0.0,
         f"stash={rb['stash']};recompute={rb['recompute']};"
         f"ratio={rb['recompute'] / rb['stash']:.3f} (policy=recompute default)")
    emit("fused_path[dedup]", 0.0,
         f"block_unique_morton={m['unique_ratio_block']:.3f};"
         f"block_unique_flat={f['unique_ratio_block']:.3f};"
         f"global_unique_morton={m['unique_ratio_global']:.3f}")
    emit("fused_path[parity]", 0.0,
         f"time_ratio={result['time_ratio']:.3f};bit_identical={bit_identical};"
         f"dpsnr={result['psnr_rgb_delta']:+.4f}dB -> {OUT_PATH.name}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short run for CI (fewer iters, fewer timing windows)")
    run(**vars(ap.parse_args()))
