"""Dense vs occupancy-compacted RenderPipeline (ISSUE 1 headline metric).

Trains the same scene twice — `compact=False` (query all B×S points, mask
sigma) and `compact=True` (argsort-compact to the live budget) — and emits
`BENCH_pipeline.json` with `points_queried_per_iter` and `us_per_step` for
both, plus PSNR parity.  With zero overflow the two runs follow the same
optimization trajectory, so PSNR must match to float noise; the win is the
paper's headline saving: fewer hash-grid interpolations issued.
"""
from __future__ import annotations

import json
import time
from dataclasses import replace
from pathlib import Path

import jax
import numpy as np

from repro.core import Field, Instant3DTrainer
from repro.data import RaySampler

from .common import BASE_FIELD, BASE_TRAIN, dataset, emit

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_pipeline.json"
WARMUP_DONE = BASE_TRAIN.occ.warmup_steps + BASE_TRAIN.occ.update_interval


def _run_variant(compact: bool) -> dict:
    scene, ds = dataset()
    field = Field(BASE_FIELD)
    tcfg = replace(BASE_TRAIN, compact=compact)
    tr = Instant3DTrainer(field, tcfg)
    state = tr.init(jax.random.PRNGKey(0))
    sampler = RaySampler(ds)

    # training run, logging densely enough to see the budget trajectory
    state, hist = tr.train(state, sampler, iters=tcfg.iters, log_every=10)

    # steady-state window: settle for one occupancy interval (absorbs any
    # fresh budget-bucket compile), then time; if a new step function was
    # still compiled inside the window, redo the timing once
    state, settle = tr.train(state, sampler, iters=tcfg.occ.update_interval,
                             log_every=tcfg.occ.update_interval)
    timed_iters = 30
    for _ in range(2):
        keys_before = tr.step_cache_keys()
        t0 = time.perf_counter()
        state, steady = tr.train(state, sampler, iters=timed_iters, log_every=10)
        us_per_step = (time.perf_counter() - t0) / timed_iters * 1e6
        if tr.step_cache_keys() == keys_before:
            break  # no compile polluted the window

    ramp = [p for s, p in zip(hist["step"], hist["points_queried"]) if s > WARMUP_DONE]
    ev = tr.evaluate(state.params, ds, views=[0, 1])
    return {
        "points_queried_per_iter": float(np.mean(steady["points_queried"])),
        "points_queried_ramp_mean": float(np.mean(ramp)),
        "us_per_step": us_per_step,
        "psnr_rgb": ev["psnr_rgb"],
        "psnr_depth": ev["psnr_depth"],
        "live_fraction_final": steady["live_fraction"][-1],
        # exhaustive (every-step) accounting from the trainer, not just the
        # steps sampled at log_every
        "overflow_steps": int(hist["overflow_steps"] + settle["overflow_steps"]
                              + steady["overflow_steps"]),
        "overflow_points_total": int(hist["overflow_total"] + settle["overflow_total"]
                                     + steady["overflow_total"]),
    }


def run() -> None:
    n_total = BASE_TRAIN.n_rays * BASE_TRAIN.render.n_samples
    dense = _run_variant(compact=False)
    compacted = _run_variant(compact=True)
    result = {
        "smoke": False,  # single-scale benchmark: CI runs it full
        "n_points_total": n_total,
        "post_warmup_step": WARMUP_DONE,
        "dense": dense,
        "compacted": compacted,
        "points_ratio": compacted["points_queried_per_iter"] / dense["points_queried_per_iter"],
        "time_ratio": compacted["us_per_step"] / dense["us_per_step"],
        "psnr_rgb_delta": compacted["psnr_rgb"] - dense["psnr_rgb"],
    }
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
    emit("pipeline_dense", dense["us_per_step"],
         f"points/iter={dense['points_queried_per_iter']:.0f} psnr={dense['psnr_rgb']:.2f}")
    emit("pipeline_compacted", compacted["us_per_step"],
         f"points/iter={compacted['points_queried_per_iter']:.0f} psnr={compacted['psnr_rgb']:.2f}")
    emit("pipeline_ratio", 0.0,
         f"points={result['points_ratio']:.3f} time={result['time_ratio']:.3f} "
         f"dpsnr={result['psnr_rgb_delta']:+.3f}dB -> {OUT_PATH.name}")


if __name__ == "__main__":
    run()
