"""Paper Figs. 16-18 + Table 5 analogues: FRM/BUM kernel ablations.

Architectural counts (device-independent, what the ASIC speedups derive
from) + CPU wall time for trend:
  * BUM: naive duplicate scatter-add vs sorted-merge scatter — unique-write
    reduction and time ratio (Fig. 18 'w/o BUM').
  * FRM: per-point python-loop gathers vs one vectorized block gather
    (Fig. 18 'w/o FRM' — the serial SRAM reads the FRM coalesces).
  * MLP fusion: 3 separate matmul calls vs the fused kernel (the multi-core
    fusion analogue at the MLP unit level).
"""
import numpy as np
import jax
import jax.numpy as jnp

from . import common
from repro.kernels.grid_update import ref as gu_ref, ops as gu_ops
from repro.kernels.hash_encode import ref as he_ref
from repro.kernels.fused_mlp import ref as mlp_ref, ops as mlp_ops


def run():
    rng = np.random.default_rng(0)
    out = {}

    # --- BUM ---
    t, f, m = 1 << 16, 2, 200_000  # paper-scale update stream (~200k queries)
    table = jnp.zeros((t, f), jnp.float32)
    idx = jnp.asarray((np.cumsum(rng.integers(0, 6, m)) % t).astype(np.int32))  # locality
    vals = jnp.asarray(rng.normal(size=(m, f)).astype(np.float32))
    naive = jax.jit(gu_ref.scatter_add)
    merged = jax.jit(lambda tb, i, v: gu_ops.merged_scatter_add(tb, i, v))
    us_naive = common.timeit(naive, table, idx, vals, iters=5)
    us_merged = common.timeit(merged, table, idx, vals, iters=5)
    uniq = int(gu_ops.num_unique_addresses(idx))
    common.emit("fig18_bum[naive_scatter]", us_naive, f"writes={m}")
    common.emit("fig18_bum[merged_scatter]", us_merged,
                f"writes={uniq};write_reduction={m/uniq:.1f}x;time_ratio={us_naive/us_merged:.2f}x")
    out["bum_write_reduction"] = m / uniq

    # --- FRM ---
    levels, tt = 4, 1 << 14
    tables = jnp.asarray(rng.normal(size=(levels, tt, 2)).astype(np.float32))
    res = he_ref.level_resolutions(levels, 16, 128)
    pts = jnp.asarray(rng.uniform(0, 1, size=(4096, 3)).astype(np.float32))

    vec = jax.jit(lambda p, tb: he_ref.hash_encode(p, tb, res))
    us_vec = common.timeit(vec, pts, tables, iters=5)

    def serial(p, tb):  # one gather per corner per level (un-coalesced reads)
        outs = []
        for l in range(levels):
            corners, w = he_ref._level_corners(p, int(res[l]))
            acc = 0.0
            for c in range(8):
                i = he_ref.corner_index(corners[:, c], int(res[l]), tt, False)
                acc = acc + w[:, c, None] * tb[l, i]
            outs.append(acc)
        return jnp.concatenate(outs, -1)
    us_serial = common.timeit(jax.jit(serial), pts, tables, iters=5)
    common.emit("fig18_frm[serial_gathers]", us_serial, "reads=8_per_point_per_level")
    common.emit("fig18_frm[vectorized_gather]", us_vec,
                f"reads=1_block_gather;time_ratio={us_serial/us_vec:.2f}x")

    # --- MLP fusion ---
    n, din, h = 8192, 32, 64
    x = jnp.asarray(rng.normal(size=(n, din)).astype(np.float32))
    mk = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32) * 0.1)
    w1, b1, w2, b2, w3, b3 = mk(din, h), mk(h), mk(h, h), mk(h), mk(h, 3), mk(3)
    unfused = jax.jit(lambda *a: mlp_ref.mlp3(*a))
    us_unfused = common.timeit(unfused, x, w1, b1, w2, b2, w3, b3, iters=10)
    fused = jax.jit(lambda *a: mlp_ops.mlp3(*a, backend="pallas"))
    us_fused = common.timeit(fused, x, w1, b1, w2, b2, w3, b3, iters=3)
    common.emit("mlp[unfused_xla]", us_unfused, "3 separate matmul dispatches")
    common.emit("mlp[fused_pallas_interpret]", us_fused,
                "fused kernel (interpret mode: CPU timing not indicative; "
                "VMEM-resident weights on TPU)")
    return out


if __name__ == "__main__":
    run()
