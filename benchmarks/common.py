"""Shared benchmark scaffolding: tiny scenes, trainers, timing, CSV emit.

Budget note: this container is a single CPU core, so benchmark configs are
scaled down (32x32 views, 8-12 views, <=200 iterations).  All comparisons are
*relative* — the paper's tables compare configurations against each other on
fixed hardware, and the same ratios are what we reproduce.
"""
from __future__ import annotations

import time
from dataclasses import replace

import jax
import numpy as np

from repro.core import Field, FieldConfig, Instant3DTrainer, TrainerConfig, occupancy
from repro.core.rendering import RenderConfig
from repro.data import build_dataset, RaySampler

RENDER = RenderConfig(n_samples=24)

BASE_FIELD = FieldConfig(
    n_levels=6, max_resolution=96, log2_table_density=13, log2_table_color=11
)

BASE_TRAIN = TrainerConfig(
    n_rays=512, iters=160, render=RENDER,
    occ=occupancy.OccupancyConfig(update_interval=16, warmup_steps=32),
)

_DATASETS = {}


def dataset(seed: int = 0, n_views: int = 8, hw: int = 32):
    key = (seed, n_views, hw)
    if key not in _DATASETS:
        _DATASETS[key] = build_dataset(seed=seed, n_views=n_views, h=hw, w=hw,
                                       cfg=RENDER, gt_samples=96)
    return _DATASETS[key]


def train_and_eval(field_cfg: FieldConfig, train_cfg: TrainerConfig, seed: int = 0):
    """Returns dict(runtime_s, psnr_rgb, psnr_depth, loss_curve)."""
    scene, ds = dataset(seed)
    field = Field(field_cfg)
    tr = Instant3DTrainer(field, train_cfg)
    state = tr.init(jax.random.PRNGKey(0))
    sampler = RaySampler(ds)
    # warm up compile outside the timed region
    state, _ = tr.train(state, sampler, iters=2, log_every=2)
    t0 = time.perf_counter()
    state, hist = tr.train(state, sampler, iters=train_cfg.iters, log_every=40)
    runtime = time.perf_counter() - t0
    ev = tr.evaluate(state.params, ds, views=[0, 1])
    return {
        "runtime_s": runtime,
        "psnr_rgb": ev["psnr_rgb"],
        "psnr_depth": ev["psnr_depth"],
        "loss": hist["loss"],
        # compaction telemetry (query budget interaction with the schedule)
        "points_queried_last": hist["points_queried"][-1],
        "points_queried_mean": float(np.mean(hist["points_queried"])),
        "live_fraction_last": hist["live_fraction"][-1],
        "overflow_total": hist["overflow_total"],
        "overflow_steps": hist["overflow_steps"],
    }


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us
