"""Paper Fig. 5: color features learn faster than density features.

Tracks RGB-PSNR vs depth-PSNR along the training trajectory; the paper's
claim (and the motivation for the whole decomposition) is that the color
curve leads the density curve."""
import jax

from . import common
from repro.core import Field, Instant3DTrainer
from repro.data import RaySampler


def run():
    scene, ds = common.dataset()
    field = Field(common.BASE_FIELD)
    tr = Instant3DTrainer(field, common.BASE_TRAIN)
    state = tr.init(jax.random.PRNGKey(0))
    sampler = RaySampler(ds)
    trace = []
    for chunk in range(4):
        state, _ = tr.train(state, sampler, iters=40, log_every=40)
        ev = tr.evaluate(state.params, ds, views=[0])
        trace.append((40 * (chunk + 1), ev["psnr_rgb"], ev["psnr_depth"]))
        common.emit(
            f"fig5_pace[iter{40*(chunk+1)}]", 0.0,
            f"psnr_rgb={ev['psnr_rgb']:.2f};psnr_depth={ev['psnr_depth']:.2f}",
        )
    leads = sum(1 for _, rgb, dep in trace if rgb >= dep)
    common.emit("fig5_pace[color_leads_density]", 0.0, f"{leads}/{len(trace)} checkpoints")
    return trace


if __name__ == "__main__":
    run()
