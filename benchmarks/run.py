"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Usage:

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --only fig18,table4
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("fig4_breakdown", "bench_breakdown"),
    ("fig5_pace", "bench_pace"),
    ("table1_grid_sizes", "bench_grid_sizes"),
    ("table2_update_freq", "bench_update_freq"),
    ("table4_algo", "bench_algo"),
    ("pipeline_compaction", "bench_pipeline"),
    ("fused_path_kernel", "bench_fused_path"),
    ("serve3d_service", "bench_serve3d"),
    ("fig8_10_access_patterns", "bench_access_patterns"),
    ("fig16_18_kernels", "bench_kernels"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated name substrings")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for name, module in SUITES:
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{module}", fromlist=["run"])
            mod.run()
            print(f"# {name} done in {time.time()-t0:.0f}s", file=sys.stderr)
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
