"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Usage:

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --only fig18,table4
    PYTHONPATH=src python -m benchmarks.run --list      # what exists, where
                                                        # each suite writes

See docs/BENCHMARKS.md for what each suite measures and the current numbers.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

# (name, module, output artifact or None) — artifacts land in the repo root
# and are what CI gates on; suites without one only emit CSV rows.
SUITES = [
    ("fig4_breakdown", "bench_breakdown", "BENCH_obs_overhead.json"),
    ("fig5_pace", "bench_pace", None),
    ("table1_grid_sizes", "bench_grid_sizes", None),
    ("table2_update_freq", "bench_update_freq", "BENCH_update_freq.json"),
    ("table4_algo", "bench_algo", None),
    ("pipeline_compaction", "bench_pipeline", "BENCH_pipeline.json"),
    ("fused_path_kernel", "bench_fused_path", "BENCH_fused_path.json"),
    ("adaptive_sampler", "bench_sampler", "BENCH_sampler.json"),
    ("serve3d_service", "bench_serve3d", "BENCH_serve3d.json"),
    ("serve3d_robustness", "bench_robustness", "BENCH_robustness.json"),
    ("fig8_10_access_patterns", "bench_access_patterns", None),
    ("fig16_18_kernels", "bench_kernels", None),
]


def list_suites() -> None:
    width = max(len(name) for name, _, _ in SUITES)
    mwidth = max(len(f"benchmarks.{m}") for _, m, _ in SUITES)
    print(f"{'name':<{width}}  {'module':<{mwidth}}  artifact")
    for name, module, artifact in SUITES:
        print(f"{name:<{width}}  {f'benchmarks.{module}':<{mwidth}}  {artifact or '-'}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated name substrings")
    ap.add_argument("--list", action="store_true",
                    help="print registered suites with their output artifacts "
                         "and exit (run nothing)")
    args = ap.parse_args()
    if args.list:
        list_suites()
        return
    only = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for name, module, _artifact in SUITES:
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{module}", fromlist=["run"])
            mod.run()
            print(f"# {name} done in {time.time()-t0:.0f}s", file=sys.stderr)
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
