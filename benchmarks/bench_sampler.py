"""Occupancy-guided sample redistribution vs the uniform compacted sampler.

Emits `BENCH_sampler.json` with the held-out views of the adaptive-sampling
lever (ISSUE 4 two-way sweep, extended to the ISSUE 9 three-way sweep):

Training draws rays from views 2..7 only; views 0-1 are held out and all
PSNR numbers are measured on them, so the deltas reflect reconstruction
quality, not train-pixel fit.

* **PSNR at equal compacted points** — THREE samplers trained under the
  same hard point ceiling (`max_budget` below the steady-state live count,
  the on-device regime): uniform (Morton-tail truncation, counted in
  `overflow_*`), v2 redistribution (even S' = budget // B split over live
  strata) and v3 redistribution (density-weighted CDF + per-ray variable
  S').  Full runs assert `psnr_rgb_delta_equal_points` >= +0.3 dB (v2 vs
  uniform, the PR 4 promise) and `psnr_rgb_delta_v3_vs_v2` >= 0 (v3 must
  not lose what workload balancing is supposed to win).
* **Points at equal PSNR** — held-out-view rendering from one trained model
  at equal queried points/ray: uniform-dense at S samples vs adaptive at S
  redistributed samples (placed from 24 jittered candidates).  The sweep
  yields the smallest adaptive budget matching the uniform S=24 quality.
* **Encoding reuse** (`reuse.*`) — the v3-trained model's compacted sample
  streams replayed through the cross-step `EncodingReuseCache` under the
  trainer's real invalidation schedule (density grid updates every step,
  color at f_color cadence, folds at the occupancy interval).  The hit
  rate must be nonzero: frozen-color steps and cross-step cell overlap are
  real, measurable reuse.
* **off_bit_identical** (asserted in every mode): with the knobs off
  neither redistribute stage is ever traced (the bench replaces both with
  raisers) and training is bit-identical to the config-default run.
"""
from __future__ import annotations

import argparse
import json
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Field, Instant3DTrainer, occupancy, losses
from repro.core.pipeline import RenderPipeline
from repro.core.rendering import sample_ts
from repro.core.trainer import _branch_update, image_rays
from repro.data import RaySampler
from repro.kernels.fused_path.reuse import EncodingReuseCache

from .common import BASE_FIELD, BASE_TRAIN, dataset, emit

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_sampler.json"
# below the ~1350-point steady-state live count at BASE_TRAIN scale
# (n_rays=512, S=24, live fraction ~0.11): the ceiling bites every step
MAX_BUDGET = 1024
TRAIN_VIEWS = range(2, 8)   # views 0-1 held out for every PSNR below
EVAL_VIEWS = [0, 1]


def _train(iters: int, forbid_stage: bool = False, **cfg_kw):
    scene, ds = dataset()
    tr = Instant3DTrainer(Field(BASE_FIELD), replace(BASE_TRAIN, **cfg_kw))
    if forbid_stage:
        def _boom(*a, **k):
            raise AssertionError("redistribute stage traced with the knob off")
        tr.pipeline.redistribute = _boom
        tr.pipeline.redistribute_v3 = _boom
    state = tr.init(jax.random.PRNGKey(0))
    sampler = RaySampler(ds, views=TRAIN_VIEWS)
    state, hist = tr.train(state, sampler, iters=iters, log_every=max(iters // 4, 1))
    return tr, state, ds, hist


def _bit_identical(pa, pb) -> bool:
    return all(bool(np.array_equal(np.asarray(a), np.asarray(b)))
               for a, b in zip(jax.tree_util.tree_leaves(pa),
                               jax.tree_util.tree_leaves(pb)))


def _render_view(tr, params, bits, ds, v: int, s_query: int, adaptive: bool) -> float:
    """PSNR of view v rendered at s_query queried points/ray."""
    s_cand = BASE_TRAIN.render.n_samples if adaptive else s_query
    cfg = replace(BASE_TRAIN.render, n_samples=s_cand, stratified=False)
    pipe = RenderPipeline(tr.field, cfg, redistribute=adaptive)
    o, d, n, chunk = image_rays(ds.poses[v], ds.h, ds.w, ds.focal, 4096)
    ts = sample_ts(None, chunk, cfg)
    outs = []
    for i in range(0, o.shape[0], chunk):
        out = pipe(params, o[i:i + chunk], d[i:i + chunk], ts,
                   bitfield=bits, budget=chunk * s_query if adaptive else None)
        outs.append(out["rgb"])
    rgb = jnp.concatenate(outs)[:n].reshape(ds.h, ds.w, 3)
    return float(losses.psnr(rgb, jnp.asarray(ds.images[v])))


def _reuse_replay(tr, state, ds, steps: int) -> dict:
    """Replay the trainer's per-step compacted sample streams through the
    cross-step EncodingReuseCache under the real invalidation schedule.

    The stream is exactly what training marches: the step-keyed ray batch
    and ts draw, cull against the trained bitfield, v3 redistribution, and
    Morton compaction to the budget.  Invalidation follows the trainer's
    update-frequency schedule — the density grid gets a conservative
    whole-grid invalidation every step, the color grid only on its
    f_color-cadence update steps, and occupancy folds clear the epoch — so
    the measured hit rate is what the schedule actually leaves on the
    table: frozen-color reuse plus cross-step cell overlap within a fold.
    """
    cfg = tr.cfg
    field = tr.field
    b, s = cfg.n_rays, cfg.render.n_samples
    bits = occupancy.bitfield(state.occ_state, cfg.occ)
    ema = state.occ_state.density_ema
    r = cfg.occ.resolution
    budget = MAX_BUDGET
    cache = EncodingReuseCache(
        field.density_enc.resolutions,
        {"density": field.cfg.grid_cfg("density").table_size,
         "color": field.cfg.grid_cfg("color").table_size},
    )
    sampler = RaySampler(ds, views=TRAIN_VIEWS)
    key = jax.random.PRNGKey(cfg.seed)
    pipe = tr.pipeline
    for i in range(int(state.step), int(state.step) + steps):
        key_batch, key_ts, _ = jax.random.split(jax.random.fold_in(key, i), 3)
        batch = sampler.sample(key_batch, b)
        ts = sample_ts(key_ts, b, cfg.render)
        flat_pts, _, unit = pipe.generate_samples(batch.origins, batch.dirs, ts)
        live = pipe.cull(flat_pts, unit, bitfield=bits)
        ema_vals = occupancy.point_density(ema, unit, r).reshape(b, s)
        ts2, _, valid = pipe.redistribute_v3(ts, live.reshape(b, s), ema_vals,
                                             budget)
        flat2, _, unit2 = pipe.generate_samples(batch.origins, batch.dirs, ts2)
        live2 = valid.reshape(-1) & pipe.cull(flat2, unit2, bitfield=bits)
        plan = pipe.compact(live2, budget, unit2)
        pts = np.asarray(unit2[plan.idx])[np.asarray(plan.keep)]
        for grid in ("density", "color"):
            cache.encode(grid, jnp.asarray(pts), state.params[f"{grid}_grid"])
        # invalidation AFTER the lookup: a training step encodes against
        # the tables its optimizer update then overwrites
        cache.note_table_update("density")
        if _branch_update(i, cfg.f_color):
            cache.note_table_update("color")
        if (i + 1) % cfg.occ.update_interval == 0:
            cache.note_fold()
    stats = cache.stats()
    stats["steps"] = steps
    return stats


def run(smoke: bool = False) -> None:
    train_iters = 96 if smoke else 200
    ident_iters = 48 if smoke else 96

    # ---- uniform-fallback bit-identity (knob off == stage absent) ----
    _, st_a, _, _ = _train(ident_iters, forbid_stage=True)
    _, st_b, _, _ = _train(ident_iters)
    off_bit_identical = _bit_identical(st_a.params, st_b.params)

    # ---- equal-points training under a hard budget ceiling ----
    tr_u, st_u, ds, hist_u = _train(train_iters, max_budget=MAX_BUDGET)
    tr_a, st_a2, _, hist_a = _train(train_iters, max_budget=MAX_BUDGET,
                                    redistribute=True)
    tr_v, st_v, _, hist_v = _train(train_iters, max_budget=MAX_BUDGET,
                                   redistribute_v3=True)
    assert hist_u["points_queried"][-1] == hist_a["points_queried"][-1] \
        == hist_v["points_queried"][-1] == MAX_BUDGET, \
        "equal-points comparison requires every variant to sit at the ceiling"
    ev_u = tr_u.evaluate(st_u.params, ds, views=EVAL_VIEWS)
    ev_a = tr_a.evaluate(st_a2.params, ds, views=EVAL_VIEWS)
    ev_v = tr_v.evaluate(st_v.params, ds, views=EVAL_VIEWS)
    d_rgb = ev_a["psnr_rgb"] - ev_u["psnr_rgb"]
    d_dep = ev_a["psnr_depth"] - ev_u["psnr_depth"]
    d_rgb_v3 = ev_v["psnr_rgb"] - ev_u["psnr_rgb"]
    d_dep_v3 = ev_v["psnr_depth"] - ev_u["psnr_depth"]
    d_v3_vs_v2 = ev_v["psnr_rgb"] - ev_a["psnr_rgb"]

    # ---- cross-step encoding reuse on the v3 sample stream ----
    reuse = _reuse_replay(tr_v, st_v, ds, steps=8 if smoke else 32)

    # ---- points at equal PSNR: novel-view renders from one model ----
    tr_r, st_r, ds_r, hist_r = _train(32 if smoke else 160)
    bits = occupancy.bitfield(st_r.occ_state, tr_r.cfg.occ)
    s_full = BASE_TRAIN.render.n_samples
    sweep_s = (4,) if smoke else (2, 3, 4, 6, 12)
    render = {}
    for s in (*sweep_s, s_full):
        render[s] = {
            "uniform": _render_view(tr_r, st_r.params, bits, ds_r, EVAL_VIEWS[0], s, False),
            "adaptive": _render_view(tr_r, st_r.params, bits, ds_r, EVAL_VIEWS[0], s, True),
        }
    ref_psnr = render[s_full]["uniform"]
    match = next((s for s in sorted(render)
                  if render[s]["adaptive"] >= ref_psnr - 0.1), s_full)

    result = {
        "smoke": smoke,
        "iters": train_iters,
        "n_rays": BASE_TRAIN.n_rays,
        "n_samples": s_full,
        "max_budget": MAX_BUDGET,
        "off_bit_identical": off_bit_identical,
        "equal_points_training": {
            "uniform": {"psnr_rgb": ev_u["psnr_rgb"], "psnr_depth": ev_u["psnr_depth"],
                        "points_per_step": hist_u["points_queried"][-1],
                        "overflow_steps": hist_u["overflow_steps"],
                        "overflow_points_total": hist_u["overflow_total"]},
            "adaptive": {"psnr_rgb": ev_a["psnr_rgb"], "psnr_depth": ev_a["psnr_depth"],
                         "points_per_step": hist_a["points_queried"][-1],
                         "overflow_steps": hist_a["overflow_steps"],
                         "overflow_points_total": hist_a["overflow_total"]},
            "v3": {"psnr_rgb": ev_v["psnr_rgb"], "psnr_depth": ev_v["psnr_depth"],
                   "points_per_step": hist_v["points_queried"][-1],
                   "overflow_steps": hist_v["overflow_steps"],
                   "overflow_points_total": hist_v["overflow_total"]},
        },
        "psnr_rgb_delta_equal_points": d_rgb,
        "psnr_depth_delta_equal_points": d_dep,
        "psnr_rgb_delta_v3_equal_points": d_rgb_v3,
        "psnr_depth_delta_v3_equal_points": d_dep_v3,
        "psnr_rgb_delta_v3_vs_v2": d_v3_vs_v2,
        "reuse": reuse,
        "render_equal_points": {
            str(s): {**v, "delta": v["adaptive"] - v["uniform"]}
            for s, v in sorted(render.items())
        },
        "points_at_equal_psnr": {
            "uniform_s": s_full,
            "uniform_psnr": ref_psnr,
            "adaptive_s_matching": match,
            "points_ratio": match / s_full,
        },
    }
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")

    emit("sampler[uniform@cap]", 0.0,
         f"psnr={ev_u['psnr_rgb']:.2f} overflow_steps={hist_u['overflow_steps']}")
    emit("sampler[adaptive@cap]", 0.0,
         f"psnr={ev_a['psnr_rgb']:.2f} overflow_steps={hist_a['overflow_steps']}")
    emit("sampler[v3@cap]", 0.0,
         f"psnr={ev_v['psnr_rgb']:.2f} dpsnr_v3_vs_v2={d_v3_vs_v2:+.3f}dB "
         f"overflow_steps={hist_v['overflow_steps']}")
    emit("sampler[reuse]", 0.0,
         f"hit_rate={reuse['hit_rate']:.3f} "
         f"corner_reads_saved={reuse['corner_reads_saved']} "
         f"steps={reuse['steps']}")
    emit("sampler[parity]", 0.0,
         f"dpsnr_equal_points={d_rgb:+.3f}dB;off_bit_identical={off_bit_identical};"
         f"points_at_equal_psnr={match}/{s_full} -> {OUT_PATH.name}")

    assert off_bit_identical, "redistribute=False diverged from the uniform baseline"
    assert reuse["hit_rate"] > 0.0, (
        "cross-step encoding reuse must be nonzero under the real "
        "invalidation schedule (frozen color steps alone guarantee hits)"
    )
    if not smoke:
        assert d_rgb >= 0.3, (
            f"adaptive sampler must beat uniform by >= 0.3 dB at equal points, "
            f"got {d_rgb:+.3f}"
        )
        assert d_v3_vs_v2 >= 0.0, (
            f"v3 redistribution must not lose to v2 at equal points, "
            f"got {d_v3_vs_v2:+.3f}"
        )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short run for CI (fewer iters, reduced render sweep; "
                         "the bit-identity assertion still runs)")
    run(**vars(ap.parse_args()))
