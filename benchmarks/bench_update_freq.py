"""Paper Table 2 on the compacted pipeline -> BENCH_update_freq.json.

F_D:F_C sweep {1:1 (Instant-NGP), 0.5:1, 1:0.5 (Instant-3D), 1:0.25} with
occupancy-compacted field queries enabled: halving COLOR updates keeps PSNR,
halving density updates loses it.  Post-compaction, the update frequency
interacts with the *query budget* — density updates drive occupancy (and
therefore the live fraction the budget is sized from), so each row records
points_queried/iter and overflow alongside PSNR/runtime.  A dense reference
run of the winning row quantifies what compaction contributes at the same
schedule.
"""
import json
from dataclasses import replace

from . import common


ROWS = [
    ("1:1", 1.0, 1.0),
    ("0.5:1", 0.5, 1.0),
    ("1:0.5", 1.0, 0.5),  # paper's winning row
    ("1:0.25", 1.0, 0.25),
]


def _row_result(name, out, compact):
    return {
        "fd_fc": name,
        "compact": compact,
        "psnr_rgb": out["psnr_rgb"],
        "psnr_depth": out["psnr_depth"],
        "runtime_s": out["runtime_s"],
        "points_queried_last": out["points_queried_last"],
        "points_queried_mean": out["points_queried_mean"],
        "live_fraction_last": out["live_fraction_last"],
        "overflow_total": out["overflow_total"],
        "overflow_steps": out["overflow_steps"],
    }


def run():
    dense_points = common.BASE_TRAIN.n_rays * common.RENDER.n_samples
    rows = []
    for name, fd, fc in ROWS:
        tcfg = replace(common.BASE_TRAIN, f_density=fd, f_color=fc)  # compact=True
        out = common.train_and_eval(common.BASE_FIELD, tcfg)
        rows.append(_row_result(name, out, compact=True))
        common.emit(
            f"table2_update_freq[{name}]",
            out["runtime_s"] * 1e6 / tcfg.iters,
            f"psnr={out['psnr_rgb']:.2f};depth_psnr={out['psnr_depth']:.2f};"
            f"runtime_s={out['runtime_s']:.1f};"
            f"points_per_iter={out['points_queried_last']};"
            f"overflow_steps={out['overflow_steps']}",
        )

    # dense reference at the paper's schedule: same math, no query compaction
    dense_cfg = replace(common.BASE_TRAIN, f_density=1.0, f_color=0.5, compact=False)
    dense = common.train_and_eval(common.BASE_FIELD, dense_cfg)
    rows.append(_row_result("1:0.5-dense", dense, compact=False))
    common.emit(
        "table2_update_freq[1:0.5-dense]",
        dense["runtime_s"] * 1e6 / dense_cfg.iters,
        f"psnr={dense['psnr_rgb']:.2f};runtime_s={dense['runtime_s']:.1f};"
        f"points_per_iter={dense['points_queried_last']}",
    )

    with open("BENCH_update_freq.json", "w") as f:
        json.dump({
            "config": {
                "n_rays": common.BASE_TRAIN.n_rays,
                "n_samples": common.RENDER.n_samples,
                "iters": common.BASE_TRAIN.iters,
                "dense_points_per_iter": dense_points,
            },
            "rows": rows,
        }, f, indent=2)
    return rows


if __name__ == "__main__":
    run()
