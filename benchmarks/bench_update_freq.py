"""Paper Table 2: PSNR vs training time for density:color update frequencies.

F_D:F_C in {1:1 (Instant-NGP), 0.5:1, 1:0.5 (Instant-3D)}.  Halving COLOR
updates keeps PSNR; halving density updates loses it."""
from dataclasses import replace

from . import common


ROWS = [
    ("1:1", 1.0, 1.0),
    ("0.5:1", 0.5, 1.0),
    ("1:0.5", 1.0, 0.5),  # paper's winning row
]


def run():
    results = []
    for name, fd, fc in ROWS:
        tcfg = replace(common.BASE_TRAIN, f_density=fd, f_color=fc)
        fcfg = common.BASE_FIELD
        if fd < 1.0:
            # density-frequency reduction needs the symmetric mechanism:
            # swap roles by freezing the density grid instead
            tcfg = replace(common.BASE_TRAIN, f_density=fd, f_color=fc)
        out = common.train_and_eval(fcfg, tcfg)
        results.append((name, out))
        common.emit(
            f"table2_update_freq[{name}]",
            out["runtime_s"] * 1e6 / tcfg.iters,
            f"psnr={out['psnr_rgb']:.2f};depth_psnr={out['psnr_depth']:.2f};runtime_s={out['runtime_s']:.1f}",
        )
    return results


if __name__ == "__main__":
    run()
