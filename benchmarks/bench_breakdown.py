"""Paper Figs. 4/7: training-step runtime breakdown by pipeline stage.

Times each step of the pipeline separately (sample rays / encode (Step 3-1)
/ MLP (Step 3-2) / composite (Step 4) / full fwd+bwd) and reports the
fraction attributable to grid interpolation + its backward — the paper's
~80% bottleneck claim.

Also the observability overhead budget: measures the disabled-mode cost of
one `repro.obs.trace.span` (the `REPRO_OBS`-off no-op path), scales it by
the spans a training step crosses, and emits ``BENCH_obs_overhead.json``
whose ``overhead_fraction`` tools/bench_gate.py caps at < 1% of a step —
the contract that lets instrumentation sit permanently on the hot paths.
"""
import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import common
from repro.core import Field
from repro.core.rendering import sample_ts
from repro.core import encoding
from repro.data import RaySampler
from repro.kernels.fused_step import ref as fs_ref
from repro.obs import trace

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_obs_overhead.json"

# spans the instrumented trainer loop crosses per iteration, counted
# generously: trainer step + occupancy update + fused fwd/bwd + the four
# pipeline stage spans (which actually only fire at trace time, i.e. on
# compiles — charging them per step keeps the budget conservative)
SPANS_PER_STEP = 8


def _span_cost_ns(n: int) -> float:
    t0 = trace.clock_ns()
    for _ in range(n):
        with trace.span("bench/overhead_probe", cat="bench"):
            pass
    return (trace.clock_ns() - t0) / n


def obs_overhead(step_us: float, smoke: bool) -> dict:
    """Micro-bench the span fast paths and write the gated artifact."""
    n = 50_000 if smoke else 200_000
    was_on = trace.enabled()
    trace.set_enabled(False)
    disabled_ns = _span_cost_ns(n)
    trace.set_enabled(True)
    enabled_ns = _span_cost_ns(n)       # for the report; not gated
    trace.set_enabled(was_on)
    trace.clear()
    result = {
        "smoke": smoke,
        "span_disabled_ns": disabled_ns,
        "span_enabled_ns": enabled_ns,
        "spans_per_step": SPANS_PER_STEP,
        "step_us": step_us,
        # what REPRO_OBS=off costs an instrumented training step
        "overhead_fraction": disabled_ns * SPANS_PER_STEP / (step_us * 1e3),
    }
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
    common.emit("obs_overhead[span]", disabled_ns / 1e3,
                f"disabled_ns={disabled_ns:.0f};enabled_ns={enabled_ns:.0f};"
                f"fraction_of_step={result['overhead_fraction']:.2e}"
                f" -> {OUT_PATH.name}")
    return result


def run(smoke: bool = False):
    scene, ds = common.dataset()
    field = Field(common.BASE_FIELD)
    params = field.init(jax.random.PRNGKey(0))
    sampler = RaySampler(ds)
    batch = sampler.sample(jax.random.PRNGKey(1), common.BASE_TRAIN.n_rays)
    ts = sample_ts(jax.random.PRNGKey(2), common.BASE_TRAIN.n_rays, common.RENDER)
    pts = (batch.origins[:, None] + ts[..., None] * batch.dirs[:, None]).reshape(-1, 3)
    pts = jnp.clip((pts + 1.5) / 3.0, 0, 1 - 1e-6)
    dirs = jnp.broadcast_to(batch.dirs[:, None], (ts.shape[0], ts.shape[1], 3)).reshape(-1, 3)

    us = {}

    def leg(name, fn, *args, iters):
        # per-leg timings ride through obs spans, so a traced bench run
        # (REPRO_OBS=1) exports the same breakdown as its CSV rows
        with trace.span(f"bench/breakdown/{name}", cat="bench",
                        args={"iters": iters}):
            us[name] = common.timeit(fn, *args, iters=iters)

    enc_fwd = jax.jit(lambda p, tb: field.density_enc(p, tb))
    leg("encode_fwd", enc_fwd, pts, params["density_grid"], iters=10)

    enc_bwd = jax.jit(jax.grad(lambda tb: field.density_enc(pts, tb).sum()))
    leg("encode_bwd", enc_bwd, params["density_grid"], iters=10)

    mlp = jax.jit(lambda p: field.query(p, pts, dirs))
    leg("full_field_query", mlp, params, iters=10)

    def full_loss(p):
        sigma, rgb = field.query(p, pts, dirs)
        return jnp.mean(sigma) + jnp.mean(rgb)
    leg("full_fwd_bwd", jax.jit(jax.grad(full_loss)), params, iters=5)

    # the two fused routes over the same batch: PR 3 (fused encode, split
    # MLPs) and PR 6 (whole encode->MLP chain in one custom-VJP op)
    def fused_loss(p):
        sigma, rgb = field.query_fused(p, pts, dirs)
        return jnp.mean(sigma) + jnp.mean(rgb)
    leg("fused_path_fwd_bwd", jax.jit(jax.grad(fused_loss)), params, iters=5)

    def step_loss(p):
        sigma, rgb = field.query_step(p, pts, dirs)
        return jnp.mean(sigma) + jnp.mean(rgb)
    leg("fused_step_fwd_bwd", jax.jit(jax.grad(step_loss)), params, iters=5)

    grid_us = us["encode_fwd"] + us["encode_bwd"]
    frac = grid_us / us["full_fwd_bwd"]
    for k, v in us.items():
        common.emit(f"fig4_breakdown[{k}]", v, "")
    common.emit("fig4_breakdown[grid_interp_fraction]", grid_us,
                f"fraction_of_step={frac:.1%};paper_claims=~80%")

    # residual bytes/step held live between forward and backward of the
    # one-kernel step, per policy — static accounting at this batch size
    cfg = common.BASE_FIELD
    sizes = (field.density_enc.cfg.table_size, field.color_enc.cfg.table_size)
    counts = field.param_counts(params)
    rb = {pol: fs_ref.residual_bytes(
        pol, pts.shape[0], cfg.n_levels, cfg.n_features, sizes,
        field.sh_dim, counts["density_mlp"], counts["color_mlp"])
        for pol in ("stash", "recompute")}
    common.emit("fig4_breakdown[fused_step_residual_bytes]", 0.0,
                f"n_points={pts.shape[0]};stash={rb['stash']};"
                f"recompute={rb['recompute']};"
                f"ratio={rb['recompute'] / rb['stash']:.3f}")

    obs_overhead(us["full_fwd_bwd"], smoke)
    return us


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short run for CI (fewer micro-bench iterations)")
    run(**vars(ap.parse_args()))
