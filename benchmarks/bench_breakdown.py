"""Paper Figs. 4/7: training-step runtime breakdown by pipeline stage.

Times each step of the pipeline separately (sample rays / encode (Step 3-1)
/ MLP (Step 3-2) / composite (Step 4) / full fwd+bwd) and reports the
fraction attributable to grid interpolation + its backward — the paper's
~80% bottleneck claim."""
import jax
import jax.numpy as jnp
import numpy as np

from . import common
from repro.core import Field
from repro.core.rendering import sample_ts
from repro.core import encoding
from repro.data import RaySampler
from repro.kernels.fused_step import ref as fs_ref


def run():
    scene, ds = common.dataset()
    field = Field(common.BASE_FIELD)
    params = field.init(jax.random.PRNGKey(0))
    sampler = RaySampler(ds)
    batch = sampler.sample(jax.random.PRNGKey(1), common.BASE_TRAIN.n_rays)
    ts = sample_ts(jax.random.PRNGKey(2), common.BASE_TRAIN.n_rays, common.RENDER)
    pts = (batch.origins[:, None] + ts[..., None] * batch.dirs[:, None]).reshape(-1, 3)
    pts = jnp.clip((pts + 1.5) / 3.0, 0, 1 - 1e-6)
    dirs = jnp.broadcast_to(batch.dirs[:, None], (ts.shape[0], ts.shape[1], 3)).reshape(-1, 3)

    us = {}
    enc_fwd = jax.jit(lambda p, tb: field.density_enc(p, tb))
    us["encode_fwd"] = common.timeit(enc_fwd, pts, params["density_grid"], iters=10)

    enc_bwd = jax.jit(jax.grad(lambda tb: field.density_enc(pts, tb).sum()))
    us["encode_bwd"] = common.timeit(enc_bwd, params["density_grid"], iters=10)

    mlp = jax.jit(lambda p: field.query(p, pts, dirs))
    us["full_field_query"] = common.timeit(mlp, params, iters=10)

    def full_loss(p):
        sigma, rgb = field.query(p, pts, dirs)
        return jnp.mean(sigma) + jnp.mean(rgb)
    us["full_fwd_bwd"] = common.timeit(jax.jit(jax.grad(full_loss)), params, iters=5)

    # the two fused routes over the same batch: PR 3 (fused encode, split
    # MLPs) and PR 6 (whole encode->MLP chain in one custom-VJP op)
    def fused_loss(p):
        sigma, rgb = field.query_fused(p, pts, dirs)
        return jnp.mean(sigma) + jnp.mean(rgb)
    us["fused_path_fwd_bwd"] = common.timeit(jax.jit(jax.grad(fused_loss)), params, iters=5)

    def step_loss(p):
        sigma, rgb = field.query_step(p, pts, dirs)
        return jnp.mean(sigma) + jnp.mean(rgb)
    us["fused_step_fwd_bwd"] = common.timeit(jax.jit(jax.grad(step_loss)), params, iters=5)

    grid_us = us["encode_fwd"] + us["encode_bwd"]
    frac = grid_us / us["full_fwd_bwd"]
    for k, v in us.items():
        common.emit(f"fig4_breakdown[{k}]", v, "")
    common.emit("fig4_breakdown[grid_interp_fraction]", grid_us,
                f"fraction_of_step={frac:.1%};paper_claims=~80%")

    # residual bytes/step held live between forward and backward of the
    # one-kernel step, per policy — static accounting at this batch size
    cfg = common.BASE_FIELD
    sizes = (field.density_enc.cfg.table_size, field.color_enc.cfg.table_size)
    counts = field.param_counts(params)
    rb = {pol: fs_ref.residual_bytes(
        pol, pts.shape[0], cfg.n_levels, cfg.n_features, sizes,
        field.sh_dim, counts["density_mlp"], counts["color_mlp"])
        for pol in ("stash", "recompute")}
    common.emit("fig4_breakdown[fused_step_residual_bytes]", 0.0,
                f"n_points={pts.shape[0]};stash={rb['stash']};"
                f"recompute={rb['recompute']};"
                f"ratio={rb['recompute'] / rb['stash']:.3f}")
    return us


if __name__ == "__main__":
    run()
