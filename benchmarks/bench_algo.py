"""Paper Table 4 + Fig. 7: Instant-NGP baseline vs the Instant-3D algorithm.

Instant-3D = decomposed grids with S_D:S_C = 1:0.25 and F_D:F_C = 1:0.5
(paper §5.1).  Reports runtime + PSNR for both, plus the runtime ratio
(paper: 60s vs 72s on Xavier NX = 0.83x)."""
from dataclasses import replace

from . import common


def run():
    # Instant-NGP baseline: single grid (decomposed=False), same total budget
    ngp_field = replace(common.BASE_FIELD, decomposed=False)
    ngp = common.train_and_eval(ngp_field, common.BASE_TRAIN)
    common.emit("table4_algo[instant-ngp]", ngp["runtime_s"] * 1e6 / common.BASE_TRAIN.iters,
                f"psnr={ngp['psnr_rgb']:.2f};runtime_s={ngp['runtime_s']:.1f}")

    # Instant-3D: S_D:S_C = 1:0.25 (log2 delta -2), F_D:F_C = 1:0.5
    i3d_field = replace(
        common.BASE_FIELD,
        log2_table_color=common.BASE_FIELD.log2_table_density - 2,
    )
    i3d_train = replace(common.BASE_TRAIN, f_color=0.5)
    i3d = common.train_and_eval(i3d_field, i3d_train)
    ratio = i3d["runtime_s"] / ngp["runtime_s"]
    common.emit("table4_algo[instant-3d]", i3d["runtime_s"] * 1e6 / i3d_train.iters,
                f"psnr={i3d['psnr_rgb']:.2f};runtime_s={i3d['runtime_s']:.1f};vs_ngp={ratio:.2f}x")
    return {"ngp": ngp, "i3d": i3d, "ratio": ratio}


if __name__ == "__main__":
    run()
