#!/usr/bin/env python
"""Perf-trajectory gate: diff fresh BENCH_*.json artifacts against the
committed baseline and fail CI on regressions.

Replaces the per-step inline `python -c` assertion blobs that used to live
in ci.yml with one declarative rule table.  Two kinds of checks run per
gated key:

* **absolute** — the fresh value must satisfy the rule's hard bound
  (`max=` / `min=` / `flag=True`), independent of any baseline.  These are
  the invariants a PR must never break (bit-identity flags, parity caps,
  fused-path time ratio <= 1).
* **trajectory** — the fresh value must not regress against the *committed*
  artifact (`git show <ref>:<artifact>`) beyond `rel_tol`/`abs_tol`.  The
  committed artifacts are the repo's perf history; the gate keeps the
  trajectory monotone-ish instead of letting slow drift hide inside a loose
  absolute bound.  Trajectory checks are skipped (with a note) when the
  fresh and baseline runs used different scales (`config.smoke` mismatch) —
  a smoke run regressing against a committed full run is noise, not signal.

Exit status is non-zero if any rule fails; every gated key prints one
report line either way.

    python tools/bench_gate.py                 # gate all known artifacts
    python tools/bench_gate.py BENCH_serve3d.json
    python tools/bench_gate.py --baseline-ref HEAD~1
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from dataclasses import dataclass
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


@dataclass(frozen=True)
class Rule:
    path: str                     # dotted key path into the artifact json
    # absolute bounds (always enforced on the fresh value)
    max: float | None = None
    min: float | None = None
    flag: bool = False            # fresh value must be truthy
    full_only: bool = False       # absolute bound applies only to full runs
    # trajectory tolerances vs the committed baseline (direction inferred:
    # keys with `max` must not grow, keys with `min` must not shrink)
    rel_tol: float | None = None
    abs_tol: float | None = None
    # trajectory baseline key, when it differs from `path` — lets a NEW key
    # gate against an OLD committed key (e.g. the one-kernel step's time
    # ratio against the PR 3 fused-path ratio the repo already banked)
    base_path: str | None = None


# Rule table: what each benchmark artifact promises.
SPECS: dict[str, list[Rule]] = {
    "BENCH_pipeline.json": [
        # compaction must keep querying fewer points than dense at parity
        Rule("points_ratio", max=1.0, rel_tol=0.15),
        Rule("psnr_rgb_delta", min=-0.1, abs_tol=0.1),
    ],
    "BENCH_fused_path.json": [
        Rule("time_ratio", max=1.0, rel_tol=0.10),
        Rule("params_bit_identical", flag=True),
        # one-kernel training step (PR 6): same promise as the fused path —
        # never slower than the compacted baseline.  On the ref backend the
        # bar is parity, not a win: XLA CSE compiles all three routes to the
        # same program (identical flop counts under compile().cost_analysis()),
        # so sub-1.0 medians are locality/noise; the structural speedup
        # (VMEM-resident epilogue, dedup'd gathers, no per-op dispatch) is a
        # Pallas-hardware claim, re-baselined when pallas-tpu runs compiled.
        Rule("fused_step.time_ratio", max=1.0, full_only=True, rel_tol=0.10),
        # the full-step ratio must also track the committed PR 3 fused-path
        # trajectory (the one-kernel route subsumes the fused path, so it
        # must not cost measurably more than what it replaced)
        Rule("fused_step.time_ratio_full_step", max=1.0, full_only=True,
             base_path="time_ratio", abs_tol=0.05),
        Rule("fused_step.params_bit_identical", flag=True),
        # recompute residual policy must halve (or better) what stays live
        # between forward and backward — static accounting at the run's
        # steady-state budget (full runs only: at smoke budgets the pinned
        # table aliases dominate both policies and the ratio is meaningless)
        Rule("fused_step.residual_bytes.ratio", max=0.5, full_only=True),
    ],
    "BENCH_sampler.json": [
        Rule("off_bit_identical", flag=True),
        # +0.3 dB at equal points is the full-run promise; smoke runs only
        # trajectory-compare against a smoke baseline
        Rule("psnr_rgb_delta_equal_points", min=0.3, full_only=True, abs_tol=0.5),
        # v3 must hold what v2 won: >= 0 dB vs v2 at the same ceiling on
        # full runs, with trajectory slack for seed-level wobble
        Rule("psnr_rgb_delta_v3_vs_v2", min=0.0, full_only=True, abs_tol=0.3),
        # cross-step encoding reuse must stay measurably nonzero; the
        # trajectory tolerance guards against the schedule silently
        # degrading to invalidate-everything
        Rule("reuse.hit_rate", min=0.01, rel_tol=0.3),
    ],
    "BENCH_obs_overhead.json": [
        # the REPRO_OBS=off no-op span path must stay under 1% of a
        # training step — the contract that keeps instrumentation resident
        # on the hot paths (micro-timings are noisy; the absolute cap is
        # the promise, so no trajectory tolerance)
        Rule("overhead_fraction", max=0.01),
    ],
    "BENCH_serve3d.json": [
        Rule("parity.max_abs_diff_db", max=0.1),
        Rule("cohort.bit_identical", flag=True),
        # scene-parallel training must beat pure time-slicing
        Rule("cohort.speedup_4v1", min=1.0, abs_tol=0.15),
        # redistributed serving must not cost latency or PSNR
        Rule("render_path.p50_ratio", max=1.0, rel_tol=0.20),
        Rule("render_path.psnr_cost_db", max=0.1, abs_tol=0.1),
        # the session guard must stay under 1% of training wall time and
        # must never roll back a fault-free run (false-positive detector)
        Rule("guard.overhead_frac", max=0.01),
        Rule("guard.rollbacks", max=0),
        # scale-out (device-mesh session sharding, forced 4-device child):
        # scenes/sec must be monotone non-decreasing in device count with a
        # strict 1 -> 4 win (full runs only — smoke slices are too short to
        # resolve the dispatch/compute overlap), and the N=1 placement must
        # degenerate bit-identically to the placement-free pre-mesh path
        Rule("scale_out.scenes_per_s_monotone", min=1, full_only=True),
        Rule("scale_out.n1_bit_identical", flag=True),
        # mixed train+render load on the full mesh, async plane, per-device
        # render executables pre-warmed: steady-state p95 stays interactive
        # and trajectory-tracks the committed baseline (measured ~0.8 s on
        # this container at smoke scale)
        Rule("scale_out.render_p95_ms_mixed", max=5_000.0, rel_tol=0.5),
    ],
    "BENCH_robustness.json": [
        # the chaos run's recovery contract: faults fire, every session
        # still finishes, the NaN slice forces >= 1 rollback, and fault
        # isolation holds — uninjected sessions end bit-identical to the
        # fault-free control run (0.0 dB parity, exactly)
        Rule("faults_fired.nan_params", min=1),
        Rule("all_sessions_done", flag=True),
        Rule("rollbacks", min=1),
        Rule("uninjected_bit_identical", flag=True),
        Rule("uninjected_parity_db", max=0.0),
        # publish-failure injection must be survived, not skipped
        Rule("publish_failures", min=1),
        # recovery latency: rollback-to-serving must stay interactive;
        # trajectory-track the committed baseline (host tree restore +
        # resume, measured ~10 ms on this container)
        Rule("recovery_ms.p95", max=1000.0, rel_tol=0.5),
    ],
}


def lookup(doc: dict, dotted: str):
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def committed(artifact: str, ref: str) -> dict | None:
    try:
        blob = subprocess.run(
            ["git", "show", f"{ref}:{artifact}"],
            cwd=REPO, capture_output=True, check=True,
        ).stdout
        return json.loads(blob)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


def is_smoke(doc: dict | None) -> bool | None:
    """Artifacts mark their scale either at the top level ("smoke") or in a
    "config" block; None means the artifact predates the marker."""
    if doc is None:
        return None
    if "smoke" in doc:
        return doc["smoke"]
    cfg = doc.get("config")
    return cfg.get("smoke") if isinstance(cfg, dict) else None


def gate_artifact(artifact: str, ref: str) -> list[str]:
    """Returns failure messages (empty == pass); prints per-key report."""
    fresh_path = REPO / artifact
    if not fresh_path.exists():
        print(f"[FAIL] {artifact}: missing (benchmark did not produce it)")
        return [f"{artifact}: missing"]
    fresh = json.loads(fresh_path.read_text())
    base = committed(artifact, ref)
    # trajectory comparisons need equal scale: the smoke marker must match,
    # and an unmarked legacy baseline (None) never matches a marked fresh run
    comparable = (base is not None and is_smoke(fresh) == is_smoke(base)
                  and is_smoke(fresh) is not None)
    failures = []

    for rule in SPECS[artifact]:
        val = lookup(fresh, rule.path)
        bval = lookup(base, rule.base_path or rule.path) if base is not None else None
        label = f"{artifact}:{rule.path}"
        problems = []
        notes = []

        if val is None:
            failures.append(f"{label}: key missing from fresh artifact")
            print(f"[FAIL] {label}: key missing")
            continue

        if rule.flag:
            if not val:
                problems.append("flag is false")
        else:
            full_run = is_smoke(fresh) is False
            enforce_abs = not rule.full_only or full_run
            if rule.max is not None and enforce_abs and val > rule.max:
                problems.append(f"{val:.4f} > max {rule.max}")
            if rule.min is not None and enforce_abs and val < rule.min:
                problems.append(f"{val:.4f} < min {rule.min}")
            if not enforce_abs:
                notes.append("absolute bound is full-run only")
            # trajectory vs committed baseline
            if comparable and isinstance(bval, (int, float)) and not isinstance(bval, bool):
                slack = 0.0
                if rule.rel_tol is not None:
                    slack = max(slack, abs(bval) * rule.rel_tol)
                if rule.abs_tol is not None:
                    slack = max(slack, rule.abs_tol)
                if rule.rel_tol is not None or rule.abs_tol is not None:
                    if rule.max is not None and val > bval + slack:
                        problems.append(
                            f"{val:.4f} regressed past baseline {bval:.4f} (+{slack:.4f} tol)")
                    if rule.min is not None and val < bval - slack:
                        problems.append(
                            f"{val:.4f} regressed below baseline {bval:.4f} (-{slack:.4f} tol)")
            elif base is None:
                notes.append("no committed baseline (new artifact)")
            elif not comparable:
                notes.append("baseline scale differs (smoke vs full) — trajectory skipped")

        shown = val if rule.flag else (f"{val:.4f}" if isinstance(val, float) else val)
        base_s = "" if bval is None else f" baseline={bval if rule.flag else round(float(bval), 4)}"
        note_s = f"  ({'; '.join(notes)})" if notes else ""
        if problems:
            print(f"[FAIL] {label}: {'; '.join(problems)} (fresh={shown}{base_s})")
            failures += [f"{label}: {p}" for p in problems]
        else:
            print(f"[ok]   {label}: {shown}{base_s}{note_s}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifacts", nargs="*", default=None,
                    help="artifact filenames to gate (default: all known)")
    ap.add_argument("--baseline-ref", default="HEAD",
                    help="git ref holding the committed baseline artifacts")
    args = ap.parse_args(argv)

    names = args.artifacts or sorted(SPECS)
    unknown = [n for n in names if n not in SPECS]
    if unknown:
        print(f"no gate rules for: {', '.join(unknown)}", file=sys.stderr)
        return 2
    failures = []
    for name in names:
        failures += gate_artifact(name, args.baseline_ref)
    if failures:
        print(f"\nbench gate FAILED ({len(failures)} violation(s))")
        return 1
    print(f"\nbench gate passed ({len(names)} artifact(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
