"""CI docs-check: fail on broken relative links in the repo's markdown.

Scans README.md, ROADMAP.md, and docs/*.md for [text](target) links and
verifies every relative target exists on disk (anchors are stripped;
http(s)/mailto links are out of scope).  Usage:

    python tools/check_links.py            # check the default set
    python tools/check_links.py FILE...    # check specific files
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def check(md: Path) -> list[str]:
    try:
        shown = md.relative_to(ROOT)
    except ValueError:  # explicit argument outside the repo root
        shown = md
    broken = []
    for n, line in enumerate(md.read_text().splitlines(), 1):
        for target in LINK.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if rel and not (md.parent / rel).exists():
                broken.append(f"{shown}:{n}: broken link -> {target}")
    return broken


def main(argv: list[str]) -> int:
    files = [Path(a).resolve() for a in argv] if argv else [
        ROOT / "README.md", ROOT / "ROADMAP.md", *sorted((ROOT / "docs").glob("*.md")),
    ]
    missing = [str(f) for f in files if not f.exists()]
    broken = [b for f in files if f.exists() for b in check(f)]
    for msg in missing:
        print(f"missing file: {msg}")
    for msg in broken:
        print(msg)
    print(f"checked {len(files) - len(missing)} files: "
          f"{len(broken)} broken links, {len(missing)} missing files")
    return 1 if broken or missing else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
