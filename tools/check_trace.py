#!/usr/bin/env python
"""Validate a Chrome-trace JSON file produced by repro.obs.export.

Checks the Trace Event Format contract that chrome://tracing and Perfetto
rely on — CI runs this over the traces the obs leg records so a malformed
exporter can never ship behind a green build:

* top level: ``{"traceEvents": [...]}`` (displayTimeUnit optional);
* every event has ``name``/``ph``/``pid``/``tid``/``ts``; complete events
  (``ph == "X"``) carry a non-negative ``dur``; instants (``ph == "i"``)
  carry a scope ``s``; metadata (``ph == "M"``) names the process and every
  thread that emitted an event;
* timestamps are finite numbers (µs), args JSON-serializable dicts.

``--require name`` (repeatable) additionally asserts that a span with that
name is present — the CI legs use it to pin the span taxonomy (pipeline
stages, trainer step compile/execute split, serve3d quanta).

    python tools/check_trace.py trace.json --require pipeline/shade \
        --require trainer/step
"""
from __future__ import annotations

import argparse
import json
import math
import sys

KNOWN_PHASES = {"X", "i", "I", "M", "B", "E", "b", "e", "n", "C"}


def check(doc, require=(), label="trace") -> list[str]:
    """Returns a list of problems (empty == valid)."""
    problems = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return [f"{label}: top level must be a dict with 'traceEvents'"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return [f"{label}: traceEvents must be a list"]

    names = set()
    spans = 0
    tids_seen = set()
    tids_named = set()
    process_named = False
    for i, e in enumerate(events):
        where = f"{label}: event[{i}]"
        if not isinstance(e, dict):
            problems.append(f"{where} is not an object")
            continue
        for field in ("name", "ph", "pid", "tid", "ts"):
            if field not in e:
                problems.append(f"{where} missing {field!r}")
        ph = e.get("ph")
        if ph not in KNOWN_PHASES:
            problems.append(f"{where} unknown phase {ph!r}")
        for field in ("ts", "dur"):
            v = e.get(field)
            if v is not None and (not isinstance(v, (int, float))
                                  or isinstance(v, bool)
                                  or not math.isfinite(v)):
                problems.append(f"{where} {field}={v!r} is not a finite number")
        if ph == "X":
            spans += 1
            names.add(e.get("name"))
            tids_seen.add(e.get("tid"))
            if "dur" not in e:
                problems.append(f"{where} complete event missing 'dur'")
            elif isinstance(e["dur"], (int, float)) and e["dur"] < 0:
                problems.append(f"{where} negative dur {e['dur']}")
        elif ph == "i":
            names.add(e.get("name"))
            tids_seen.add(e.get("tid"))
            if "s" not in e:
                problems.append(f"{where} instant event missing scope 's'")
        elif ph == "M":
            if e.get("name") == "process_name":
                process_named = True
            elif e.get("name") == "thread_name":
                tids_named.add(e.get("tid"))
        if "args" in e and not isinstance(e["args"], dict):
            problems.append(f"{where} args is not an object")

    if events and not process_named:
        problems.append(f"{label}: no process_name metadata event")
    unnamed = tids_seen - tids_named
    if unnamed:
        problems.append(f"{label}: threads without thread_name metadata: "
                        f"{sorted(unnamed)}")
    for name in require:
        if name not in names:
            problems.append(f"{label}: required span {name!r} absent "
                            f"(have {len(names)} distinct names)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", help="trace JSON files to validate")
    ap.add_argument("--require", action="append", default=[],
                    help="span name that must be present (repeatable)")
    args = ap.parse_args(argv)

    failures = []
    for path in args.paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            failures.append(f"{path}: unreadable ({e})")
            continue
        probs = check(doc, require=args.require, label=path)
        n = len(doc.get("traceEvents", [])) if isinstance(doc, dict) else 0
        if probs:
            failures += probs
            print(f"[FAIL] {path}: {len(probs)} problem(s) in {n} events")
            for p in probs[:20]:
                print(f"       {p}")
        else:
            spans = sum(1 for e in doc["traceEvents"]
                        if isinstance(e, dict) and e.get("ph") == "X")
            print(f"[ok]   {path}: {n} events, {spans} spans")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
