"""RenderService: batched novel-view rendering across concurrent sessions.

Requests target a *session* (scene), not a parameter blob: the service
resolves each request against the session's latest published snapshot at
drain time, so renders always see a consistent, fully-trained-up-to-step-N
view while training continues on the live buffers.

Coalescing: pending requests are grouped by (field config, render config,
image geometry); each group stacks the per-session snapshot params into one
leading batch axis and renders through a jitted ``vmap`` of the *same*
fixed-chunk dense-pipeline renderer that ``Instant3DTrainer.render_image``
uses (both are built by ``repro.core.trainer.make_render_chunk``; this
module's cache adds the padded group size to the per-(field config, render
config, chunk) key).  Group sizes are bucketed to powers of two (padding
repeats the last request) so the number of distinct compiled batch shapes
stays O(log N) per geometry.

A request whose session has not published a snapshot yet stays queued — the
train -> snapshot -> serve pipeline never renders from uninitialized or
half-written params.

Redistributed serving (``samples_per_ray``): sessions registered with a
per-ray sample budget are rendered through the RenderPipeline's
redistribute stage (2b) instead of dense — the snapshot's occupancy EMA
rebuilds the session's bitfield, the dense candidate liveness becomes each
ray's probe, and only S' = samples_per_ray redistributed samples per ray
are shaded.  At S' = S/4 the PR 4 render sweep shows equal PSNR, so p50
latency drops with the shaded point count; and because a redistributing
trainer marches the same quadrature, served views stop paying the
train/eval quadrature mismatch.  ``samples_per_ray=None`` keeps the dense
path (which remains the fallback for snapshots without occupancy).
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import rendering
from ..core.trainer import (
    image_rays, make_redistributed_render_chunk, make_render_chunk,
)
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .snapshot import SnapshotStore

# vmapped-over-sessions flavor of the trainer's eval renderer: same
# make_render_chunk construction, keyed the same way plus the padded group
# size, so sessions with different grid sizes can never share an entry
_BATCH_RENDER_CACHE: dict[tuple, Any] = {}


def batched_render_fn(field_cfg, render_cfg: rendering.RenderConfig,
                      chunk: int, group: int):
    """(params stacked over G, origins (G,chunk,3), dirs (G,chunk,3),
    ts (chunk,S)) -> (rgb (G,chunk,3), depth (G,chunk))."""
    key = (field_cfg, render_cfg, int(chunk), int(group))
    if key not in _BATCH_RENDER_CACHE:
        _BATCH_RENDER_CACHE[key] = jax.jit(
            jax.vmap(make_render_chunk(field_cfg, render_cfg),
                     in_axes=(0, 0, 0, None))
        )
    return _BATCH_RENDER_CACHE[key]


def batched_redistributed_render_fn(field_cfg, render_cfg: rendering.RenderConfig,
                                    occ_cfg, chunk: int, group: int,
                                    samples_per_ray: int):
    """Redistributed flavor of `batched_render_fn`: adds per-session
    occupancy (ema (G,R^3), fold count (G,)) inputs and shades only
    chunk·samples_per_ray points per session instead of chunk·S."""
    key = (field_cfg, render_cfg, occ_cfg, int(chunk), int(group),
           int(samples_per_ray))
    if key not in _BATCH_RENDER_CACHE:
        _BATCH_RENDER_CACHE[key] = jax.jit(
            jax.vmap(make_redistributed_render_chunk(
                field_cfg, render_cfg, occ_cfg,
                int(chunk) * int(samples_per_ray)),
                in_axes=(0, 0, 0, None, 0, 0))
        )
    return _BATCH_RENDER_CACHE[key]


def _pow2_bucket(n: int) -> int:
    return 1 << (n - 1).bit_length()


@dataclass
class _SessionGeom:
    field_cfg: Any
    render_cfg: rendering.RenderConfig
    h: int
    w: int
    focal: float
    eval_chunk: int
    occ_cfg: Any = None            # OccupancyConfig for bitfield reconstruction
    samples_per_ray: int | None = None  # None => dense serving


@dataclass
class RenderRequest:
    request_id: int
    session_id: str
    pose: np.ndarray
    submitted_at: float = dc_field(default_factory=obs_trace.clock)


class RenderResult(NamedTuple):
    request_id: int
    session_id: str
    rgb: np.ndarray       # (H, W, 3)
    depth: np.ndarray     # (H, W)
    snapshot_version: int
    snapshot_step: int
    latency_s: float


class RenderService:
    def __init__(self, store: SnapshotStore, latency_window: int = 4096):
        self.store = store
        self._geom: dict[str, _SessionGeom] = {}
        self._queue: list[RenderRequest] = []
        self._next_id = 0
        # per-session serving telemetry, backed by obs Histograms (bounded
        # window -> a long-lived service doesn't grow per-request forever;
        # percentiles come from the recent window, counts are lifetime).
        # These objects are always live — `latency_stats()` keeps working
        # with REPRO_OBS off; the knob only gates the *global-registry*
        # mirror recorded at drain time.  (The compile caches are keyed by
        # config/chunk/pow2-group, not by session, so their size is bounded
        # by config diversity.)
        self.latency_window = int(latency_window)
        self.latencies: dict[str, obs_metrics.Histogram] = {}
        self.served: dict[str, int] = {}
        # TTFUV: register -> first served view, per session.  (bench_serve3d
        # additionally defines a PSNR-thresholded, GT-based TTFUV; this is
        # the service-side analogue with "usable" = "first snapshot-backed
        # render delivered".)
        self._registered_at: dict[str, float] = {}
        self.ttfuv_s: dict[str, float] = {}

    # ---- registration / submission ----

    def register_session(self, session_id: str, field_cfg, render_cfg,
                         h: int, w: int, focal: float, eval_chunk: int = 4096,
                         occ_cfg=None, samples_per_ray: int | None = None):
        """samples_per_ray: serve this session through the redistributed
        render path at that per-ray point budget (requires occ_cfg so the
        snapshot's EMA can be thresholded into a bitfield); None serves
        dense."""
        if samples_per_ray is not None and occ_cfg is None:
            raise ValueError("samples_per_ray needs occ_cfg for the bitfield")
        self._geom[session_id] = _SessionGeom(
            field_cfg, render_cfg, int(h), int(w), float(focal), int(eval_chunk),
            occ_cfg=occ_cfg,
            samples_per_ray=None if samples_per_ray is None else int(samples_per_ray),
        )
        self._registered_at.setdefault(session_id, obs_trace.clock())

    def submit(self, session_id: str, pose: np.ndarray) -> int:
        if session_id not in self._geom:
            raise KeyError(f"unknown session {session_id!r}")
        req = RenderRequest(self._next_id, session_id, np.asarray(pose))
        self._next_id += 1
        self._queue.append(req)
        return req.request_id

    @property
    def pending(self) -> int:
        return len(self._queue)

    # ---- serving ----

    def drain(self) -> list[RenderResult]:
        """Serve every pending request whose session has a published
        snapshot; requests without one stay queued for the next drain."""
        with obs_trace.span("serve3d/render_drain", cat="serve3d",
                            args={"pending": len(self._queue)}):
            results = self._drain()
        if obs_trace.enabled():
            obs_metrics.gauge("serve3d.render.queue_depth").set(len(self._queue))
        return results

    def _drain(self) -> list[RenderResult]:
        ready: list[tuple[RenderRequest, Any]] = []
        waiting: list[RenderRequest] = []
        for req in self._queue:
            snap = self.store.latest(req.session_id)
            if snap is None:
                waiting.append(req)
            else:
                ready.append((req, snap))
        self._queue = waiting

        # coalesce by compiled geometry: same field/render config + image
        # dims + serving path (dense vs redistributed at a given budget)
        groups: dict[tuple, list[tuple[RenderRequest, Any]]] = {}
        for req, snap in ready:
            g = self._geom[req.session_id]
            key = (g.field_cfg, g.render_cfg, g.h, g.w, g.focal, g.eval_chunk,
                   g.occ_cfg, g.samples_per_ray)
            groups.setdefault(key, []).append((req, snap))

        results = []
        for key, items in groups.items():
            results.extend(self._render_group(*key, items))
        results.sort(key=lambda r: r.request_id)
        return results

    def _render_group(self, field_cfg, render_cfg, h, w, focal, eval_chunk,
                      occ_cfg, samples_per_ray, items) -> list[RenderResult]:
        with obs_trace.span("serve3d/render_group", cat="serve3d",
                            args={"group": len(items),
                                  "redistribute": samples_per_ray is not None}):
            return self._render_group_inner(
                field_cfg, render_cfg, h, w, focal, eval_chunk,
                occ_cfg, samples_per_ray, items)

    def _render_group_inner(self, field_cfg, render_cfg, h, w, focal,
                            eval_chunk, occ_cfg, samples_per_ray,
                            items) -> list[RenderResult]:
        g_real = len(items)
        g_pad = _pow2_bucket(g_real)
        padded = items + [items[-1]] * (g_pad - g_real)

        origins, dirs = [], []
        n = chunk = None
        for req, _snap in padded:
            o, d, n, chunk = image_rays(req.pose, h, w, focal, eval_chunk)
            origins.append(o)
            dirs.append(d)
        origins = jnp.stack(origins)   # (G, n_pad, 3)
        dirs = jnp.stack(dirs)
        params = jax.tree.map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
            *[snap.params for _req, snap in padded],
        )
        ts = rendering.sample_ts(None, chunk, render_cfg)

        # redistributed path needs every snapshot to carry occupancy; a
        # params-only snapshot (external publisher) falls back to dense
        redistribute = (samples_per_ray is not None
                        and all(snap.occ is not None for _req, snap in padded))
        if redistribute:
            occ_ema = jnp.stack([jnp.asarray(snap.occ[0]) for _req, snap in padded])
            occ_step = jnp.asarray([int(snap.occ[1]) for _req, snap in padded],
                                   jnp.int32)
            fn_r = batched_redistributed_render_fn(
                field_cfg, render_cfg, occ_cfg, chunk, g_pad, samples_per_ray)
            fn = lambda p, o, d, t: fn_r(p, o, d, t, occ_ema, occ_step)
        else:
            fn = batched_render_fn(field_cfg, render_cfg, chunk, g_pad)

        rgb_chunks, dep_chunks = [], []
        for i in range(0, origins.shape[1], chunk):
            rgb_c, dep_c = fn(params, origins[:, i:i + chunk], dirs[:, i:i + chunk], ts)
            rgb_chunks.append(rgb_c)
            dep_chunks.append(dep_c)
        rgb = np.asarray(jnp.concatenate(rgb_chunks, axis=1))[:, :n]
        dep = np.asarray(jnp.concatenate(dep_chunks, axis=1))[:, :n]

        now = obs_trace.clock()
        obs_on = obs_trace.enabled()
        out = []
        for gi, (req, snap) in enumerate(items):
            lat = now - req.submitted_at
            sid = req.session_id
            hist = self.latencies.get(sid)
            if hist is None:
                hist = self.latencies[sid] = obs_metrics.Histogram(
                    window=self.latency_window)
            hist.observe(lat)
            first = sid not in self.ttfuv_s
            if first and sid in self._registered_at:
                self.ttfuv_s[sid] = now - self._registered_at[sid]
            self.served[sid] = self.served.get(sid, 0) + 1
            if obs_on:
                obs_metrics.counter("serve3d.render.served").inc()
                obs_metrics.histogram("serve3d.render.latency_ms").observe(lat * 1e3)
                if first and sid in self.ttfuv_s:
                    obs_metrics.gauge(f"serve3d.render.ttfuv_s.{sid}").set(
                        self.ttfuv_s[sid])
            out.append(RenderResult(
                request_id=req.request_id,
                session_id=req.session_id,
                rgb=rgb[gi].reshape(h, w, 3),
                depth=dep[gi].reshape(h, w),
                snapshot_version=snap.version,
                snapshot_step=snap.step,
                latency_s=lat,
            ))
        return out

    # ---- telemetry ----

    def latency_stats(self) -> dict:
        """Percentiles over the recent latency window; counts are lifetime.

        Quantiles use the obs Histogram definition (numpy linear
        interpolation) over the union of the per-session windows."""
        merged = obs_metrics.Histogram(
            window=self.latency_window * max(1, len(self.latencies)))
        for h in self.latencies.values():
            for v in h.values():
                merged.observe(v)
        if merged.count == 0:
            return {"count": 0}
        return {
            "count": sum(self.served.values()),
            "p50_ms": merged.quantile(0.50) * 1e3,
            "p95_ms": merged.quantile(0.95) * 1e3,
            "p99_ms": merged.quantile(0.99) * 1e3,
            "max_ms": max(merged.values()) * 1e3,
            "per_session": dict(self.served),
            "ttfuv_s": dict(self.ttfuv_s),
        }
