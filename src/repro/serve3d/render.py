"""RenderService: batched novel-view rendering across concurrent sessions.

Requests target a *session* (scene), not a parameter blob: the service
resolves each request against the session's latest published snapshot at
drain time, so renders always see a consistent, fully-trained-up-to-step-N
view while training continues on the live buffers.

Coalescing: pending requests are grouped by (field config, render config,
image geometry); each group stacks the per-session snapshot params into one
leading batch axis and renders through a jitted ``vmap`` of the *same*
fixed-chunk dense-pipeline renderer that ``Instant3DTrainer.render_image``
uses (both are built by ``repro.core.trainer.make_render_chunk``; this
module's cache adds the padded group size to the per-(field config, render
config, chunk) key).  Group sizes are bucketed to powers of two (padding
repeats the last request) so the number of distinct compiled batch shapes
stays O(log N) per geometry.

A request whose session has not published a snapshot yet stays queued — the
train -> snapshot -> serve pipeline never renders from uninitialized or
half-written params.

Redistributed serving (``samples_per_ray``): sessions registered with a
per-ray sample budget are rendered through the RenderPipeline's
redistribute stage (2b) instead of dense — the snapshot's occupancy EMA
rebuilds the session's bitfield, the dense candidate liveness becomes each
ray's probe, and only S' = samples_per_ray redistributed samples per ray
are shaded.  At S' = S/4 the PR 4 render sweep shows equal PSNR, so p50
latency drops with the shaded point count; and because a redistributing
trainer marches the same quadrature, served views stop paying the
train/eval quadrature mismatch.  ``samples_per_ray=None`` keeps the dense
path (which remains the fallback for snapshots without occupancy).

Device routing (docs/SERVING.md): with a `DevicePlacement` attached, every
coalesced group is keyed by — and executed on — the device holding its
sessions' training state (`jax.default_device` around the batched call), so
serving load follows the session sharding instead of piling onto device 0.
Groups never straddle devices; snapshots are host-side, so routing changes
*where* pixels are computed, never their values.

Snapshot levels / progressive streaming: a render request carries a
``level`` — level 0 renders full resolution and waits for a full (level-0)
snapshot; level k > 0 renders at h>>k and is answerable by a *preview*
snapshot, which sessions publish early in life (see `SnapshotStore`).  The
level rides the group key (distinct compiled shapes) and the result.

Async serving plane (``start_async``): a dedicated daemon thread drives the
drain loop so render latency stops being gated by the in-flight training
slice — XLA releases the GIL while a slice executes, so the serving thread
coalesces, dispatches and collects groups concurrently with training.
Ordering contract: requests are still answered from atomically-published
snapshots (never live buffers), per-drain results stay request-id ordered,
and pixels are bit-identical to a synchronous drain against the same
snapshot version — only *when* a drain runs moves off the quantum loop.
Queue and result handoff are lock-protected; `poll_results` returns
everything the plane has finished since the last poll.

Degradation ladder (the fault-tolerance surface; see docs/ROBUSTNESS.md):

* **deadlines** — a request may carry ``deadline_s`` (or inherit
  ``default_deadline_s``); a request still queued past its deadline is
  answered with a typed `RenderError("deadline_expired")` at the next
  drain, never silently dropped and never left to hang.
* **overload shedding** — when the queue exceeds ``shed_threshold``, the
  drain halves every redistributed session's per-ray sample budget (floor
  2) for that drain: quality degrades *before* any request is dropped.
* **group-failure retry** — an exception inside a batched render (device
  fault, injected ``render_fail``) re-queues the group's requests for the
  next drain; after ``max_attempts`` a request gets a typed
  `RenderError("render_failed")`.
* **staleness** — results for sessions the guard rolled back or
  quarantined carry ``stale=True``: the pixels are real, from the last
  *good* published snapshot, but training is behind where a healthy
  session would be.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field as dc_field
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import rendering
# the batched render caches live with the trainer's eval renderers (one
# compiled entry serves both `Instant3DTrainer.evaluate` and this service —
# the bit-for-bit eval==served contract); re-exported here for existing
# importers of serve3d.render
from ..core.trainer import (  # noqa: F401
    batched_redistributed_render_fn, batched_render_fn, image_rays,
)
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..testing import faults
from .snapshot import SnapshotStore


def _pow2_bucket(n: int) -> int:
    return 1 << (n - 1).bit_length()


@dataclass
class _SessionGeom:
    field_cfg: Any
    render_cfg: rendering.RenderConfig
    h: int
    w: int
    focal: float
    eval_chunk: int
    occ_cfg: Any = None            # OccupancyConfig for bitfield reconstruction
    samples_per_ray: int | None = None  # None => dense serving
    redistribute_v3: bool = False  # density-weighted ragged serving (stage 2b v3)


@dataclass
class RenderRequest:
    request_id: int
    session_id: str
    pose: np.ndarray
    submitted_at: float = dc_field(default_factory=obs_trace.clock)
    deadline_s: float | None = None   # None = no per-request deadline
    attempts: int = 0                 # failed batched-render attempts so far
    level: int = 0                    # 0 = full res; k > 0 = preview at h>>k


class RenderResult(NamedTuple):
    request_id: int
    session_id: str
    rgb: np.ndarray       # (H, W, 3)
    depth: np.ndarray     # (H, W)
    snapshot_version: int
    snapshot_step: int
    latency_s: float
    # the snapshot is the last *good* one but training has fallen behind
    # (guard rollback/quarantine) — pixels are valid, freshness is not
    stale: bool = False
    level: int = 0        # resolution level the pixels were rendered at


class RenderError(NamedTuple):
    """Typed failure answer: a request that cannot be served errors out
    deterministically instead of hanging in the queue."""
    request_id: int
    session_id: str
    error: str            # "deadline_expired" | "render_failed"
    latency_s: float


class RenderService:
    def __init__(self, store: SnapshotStore, latency_window: int = 4096,
                 default_deadline_s: float | None = None,
                 shed_threshold: int | None = None,
                 max_attempts: int = 2,
                 placement=None):
        """default_deadline_s: deadline inherited by requests submitted
        without one (None = requests never expire, the prior behavior).
        shed_threshold: queue depth above which a drain halves every
        redistributed session's sample budget (None = never shed).
        max_attempts: batched-render tries per request before it errors.
        placement: a `DevicePlacement` — render groups then execute on the
        device holding their sessions' training state (resolved at drain
        time, so a device move re-routes automatically)."""
        self.store = store
        self.default_deadline_s = default_deadline_s
        self.shed_threshold = shed_threshold
        self.max_attempts = int(max_attempts)
        self.placement = placement
        self._geom: dict[str, _SessionGeom] = {}
        self._queue: list[RenderRequest] = []
        self._next_id = 0
        self._stale: set[str] = set()   # sessions the guard marked degraded
        # async serving plane: queue/results handoff is lock-protected; the
        # drain itself also serializes (one drain at a time, async or sync)
        self._lock = threading.RLock()
        self._drain_mutex = threading.Lock()   # one drain at a time
        self._async_thread: threading.Thread | None = None
        self._async_stop = threading.Event()
        self._async_wake = threading.Event()
        self._async_results: list = []
        self._draining = False
        # degradation telemetry (always live, like the latency histograms)
        self.expired = 0
        self.failed = 0
        self.shed_drains = 0
        self.drains = 0
        # per-session serving telemetry, backed by obs Histograms (bounded
        # window -> a long-lived service doesn't grow per-request forever;
        # percentiles come from the recent window, counts are lifetime).
        # These objects are always live — `latency_stats()` keeps working
        # with REPRO_OBS off; the knob only gates the *global-registry*
        # mirror recorded at drain time.  (The compile caches are keyed by
        # config/chunk/pow2-group, not by session, so their size is bounded
        # by config diversity.)
        self.latency_window = int(latency_window)
        self.latencies: dict[str, obs_metrics.Histogram] = {}
        self.served: dict[str, int] = {}
        # TTFUV: register -> first served view, per session.  (bench_serve3d
        # additionally defines a PSNR-thresholded, GT-based TTFUV; this is
        # the service-side analogue with "usable" = "first snapshot-backed
        # render delivered".)
        self._registered_at: dict[str, float] = {}
        self.ttfuv_s: dict[str, float] = {}

    # ---- registration / submission ----

    def register_session(self, session_id: str, field_cfg, render_cfg,
                         h: int, w: int, focal: float, eval_chunk: int = 4096,
                         occ_cfg=None, samples_per_ray: int | None = None,
                         redistribute_v3: bool = False):
        """samples_per_ray: serve this session through the redistributed
        render path at that per-ray point budget (requires occ_cfg so the
        snapshot's EMA can be thresholded into a bitfield); None serves
        dense.  redistribute_v3: spend that budget density-weighted and
        unevenly across each chunk's rays (stage 2b v3) instead of the
        fixed per-ray split."""
        if samples_per_ray is not None and occ_cfg is None:
            raise ValueError("samples_per_ray needs occ_cfg for the bitfield")
        self._geom[session_id] = _SessionGeom(
            field_cfg, render_cfg, int(h), int(w), float(focal), int(eval_chunk),
            occ_cfg=occ_cfg,
            samples_per_ray=None if samples_per_ray is None else int(samples_per_ray),
            redistribute_v3=bool(redistribute_v3),
        )
        self._registered_at.setdefault(session_id, obs_trace.clock())

    def submit(self, session_id: str, pose: np.ndarray,
               deadline_s: float | None = None, level: int = 0) -> int:
        """level 0 renders full resolution from a full snapshot; level k > 0
        renders the cheap h>>k preview and is answerable by a preview
        snapshot (progressive streaming)."""
        if session_id not in self._geom:
            raise KeyError(f"unknown session {session_id!r}")
        with self._lock:
            req = RenderRequest(self._next_id, session_id, np.asarray(pose),
                                deadline_s=(deadline_s if deadline_s is not None
                                            else self.default_deadline_s),
                                level=int(level))
            self._next_id += 1
            self._queue.append(req)
        self._async_wake.set()
        return req.request_id

    def mark_stale(self, session_id: str, stale: bool = True) -> None:
        """Guard hook: results for this session carry ``stale=True`` until a
        healthy publish clears it."""
        if stale:
            self._stale.add(session_id)
        else:
            self._stale.discard(session_id)

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    # ---- async serving plane ----

    @property
    def async_active(self) -> bool:
        return self._async_thread is not None and self._async_thread.is_alive()

    @property
    def idle(self) -> bool:
        """No drain in flight and no undelivered async results."""
        with self._lock:
            return not self._draining and not self._async_results

    def start_async(self, poll_s: float = 0.002) -> None:
        """Spawn the serving thread: it drains whenever work is pending
        (woken by `submit`/`notify`), accumulating results for
        `poll_results`.  Idempotent."""
        if self.async_active:
            return
        self._async_stop.clear()

        def _serve():
            while not self._async_stop.is_set():
                self._async_wake.wait(timeout=0.1)
                self._async_wake.clear()
                while self.pending and not self._async_stop.is_set():
                    results = self.drain()
                    if results:
                        with self._lock:
                            self._async_results.extend(results)
                    if self.pending:
                        # remaining requests await a publish; yield until
                        # the next notify instead of spinning
                        if not self._async_wake.wait(timeout=poll_s):
                            break
                        self._async_wake.clear()

        self._async_thread = threading.Thread(
            target=_serve, name="serve3d-render", daemon=True)
        self._async_thread.start()

    def notify(self) -> None:
        """Wake the serving thread (a snapshot landed, work may be ready)."""
        self._async_wake.set()

    def stop_async(self, wait: bool = True) -> None:
        if self._async_thread is None:
            return
        self._async_stop.set()
        self._async_wake.set()
        if wait:
            # generous: a first-contact drain may be tracing several
            # per-device renderers and must be allowed to finish cleanly
            self._async_thread.join(timeout=120.0)
        self._async_thread = None

    def poll_results(self) -> list:
        """Everything the async plane finished since the last poll."""
        with self._lock:
            out, self._async_results = self._async_results, []
        return out

    # ---- serving ----

    def drain(self) -> list:
        """Serve every pending request whose session has a published
        snapshot; requests without one stay queued for the next drain.
        Returns `RenderResult`s plus typed `RenderError`s for requests past
        their deadline or past ``max_attempts`` failed renders.  Thread-safe
        and serialized: the sync quantum loop and the async plane never
        drain concurrently."""
        with self._drain_mutex:
            with self._lock:
                self._draining = True
            try:
                with obs_trace.span("serve3d/render_drain", cat="serve3d",
                                    args={"pending": self.pending}):
                    results = self._drain()
            finally:
                with self._lock:
                    self._draining = False
        if obs_trace.enabled():
            obs_metrics.gauge("serve3d.render.queue_depth").set(self.pending)
        return results

    def _drain(self) -> list:
        self.drains += 1
        now = obs_trace.clock()
        results: list = []
        obs_on = obs_trace.enabled()
        with self._lock:
            queue, self._queue = self._queue, []

        # expiry first: a request past its deadline gets a typed error even
        # if its session never publishes — expiry is how waiting requests
        # are guaranteed to terminate
        keep: list[RenderRequest] = []
        for req in queue:
            if req.deadline_s is not None and \
                    now - req.submitted_at > req.deadline_s:
                self.expired += 1
                if obs_on:
                    obs_metrics.counter("serve3d.render.expired").inc()
                results.append(RenderError(req.request_id, req.session_id,
                                           "deadline_expired",
                                           now - req.submitted_at))
            else:
                keep.append(req)

        # full-res requests wait for a full (level-0) snapshot; preview
        # requests take the best snapshot available (preview or full)
        ready: list[tuple[RenderRequest, Any]] = []
        waiting: list[RenderRequest] = []
        for req in keep:
            snap = (self.store.latest(req.session_id, level=0) if req.level == 0
                    else self.store.latest(req.session_id))
            if snap is None:
                waiting.append(req)
            else:
                ready.append((req, snap))
        with self._lock:
            self._queue.extend(waiting)

        # overload shedding: past the threshold, degrade quality (halve the
        # redistributed sample budget this drain) before dropping anything
        shed = self.shed_threshold is not None and len(ready) > self.shed_threshold
        if shed:
            self.shed_drains += 1
            if obs_on:
                obs_metrics.counter("serve3d.render.shed_drains").inc()
                obs_trace.instant("serve3d/render_shed", cat="serve3d",
                                  args={"ready": len(ready)})

        # coalesce by compiled geometry + placement: same field/render
        # config + image dims + serving path (dense vs redistributed at a
        # given budget) + resolution level + the device the group runs on —
        # groups never straddle devices
        groups: dict[tuple, list[tuple[RenderRequest, Any]]] = {}
        for req, snap in ready:
            g = self._geom[req.session_id]
            spr = g.samples_per_ray
            if shed and spr is not None:
                spr = max(2, spr // 2)
            dev = (self.placement.device(req.session_id)
                   if self.placement is not None else None)
            key = (g.field_cfg, g.render_cfg, g.h, g.w, g.focal, g.eval_chunk,
                   g.occ_cfg, spr, g.redistribute_v3, req.level, dev)
            groups.setdefault(key, []).append((req, snap))

        for key, items in groups.items():
            try:
                results.extend(self._render_group(*key, items))
            except Exception:
                # batched render died (device fault / injected render_fail):
                # re-queue the group's requests for another attempt, then
                # answer the exhausted ones with a typed error
                requeue = []
                for req, _snap in items:
                    req.attempts += 1
                    if req.attempts < self.max_attempts:
                        requeue.append(req)
                        continue
                    self.failed += 1
                    if obs_on:
                        obs_metrics.counter("serve3d.render.failed").inc()
                    results.append(RenderError(
                        req.request_id, req.session_id, "render_failed",
                        obs_trace.clock() - req.submitted_at))
                with self._lock:
                    self._queue.extend(requeue)
        results.sort(key=lambda r: r.request_id)
        return results

    def _render_group(self, field_cfg, render_cfg, h, w, focal, eval_chunk,
                      occ_cfg, samples_per_ray, redistribute_v3, level,
                      device, items) -> list[RenderResult]:
        with obs_trace.span("serve3d/render_group", cat="serve3d",
                            args={"group": len(items),
                                  "redistribute": samples_per_ray is not None,
                                  "v3": bool(redistribute_v3),
                                  "level": int(level),
                                  "device": str(device) if device is not None
                                  else None}):
            return self._render_group_inner(
                field_cfg, render_cfg, h, w, focal, eval_chunk,
                occ_cfg, samples_per_ray, redistribute_v3, level, device, items)

    def _render_group_inner(self, field_cfg, render_cfg, h, w, focal,
                            eval_chunk, occ_cfg, samples_per_ray,
                            redistribute_v3, level, device,
                            items) -> list[RenderResult]:
        inj = faults.check("serve3d.render_group",
                           session=items[0][0].session_id)
        if inj is not None and inj.kind == "render_fail":
            raise faults.InjectedFault("injected batched-render failure")
        # preview levels render the same scene at h>>level — the cheap view
        # of the progressive-streaming ladder
        if level > 0:
            h = max(1, h >> level)
            w = max(1, w >> level)
        g_real = len(items)
        g_pad = _pow2_bucket(g_real)
        padded = items + [items[-1]] * (g_pad - g_real)

        # groups execute on the device that holds their sessions' training
        # state (render routing); None = process default, the N=1 path
        dev_ctx = (jax.default_device(device) if device is not None
                   else contextlib.nullcontext())
        with dev_ctx:
            origins, dirs = [], []
            n = chunk = None
            for req, _snap in padded:
                o, d, n, chunk = image_rays(req.pose, h, w, focal, eval_chunk)
                origins.append(o)
                dirs.append(d)
            origins = jnp.stack(origins)   # (G, n_pad, 3)
            dirs = jnp.stack(dirs)
            params = jax.tree.map(
                lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                *[snap.params for _req, snap in padded],
            )
            ts = rendering.sample_ts(None, chunk, render_cfg)

            # redistributed path needs every snapshot to carry occupancy; a
            # params-only snapshot (external publisher) falls back to dense
            redistribute = (samples_per_ray is not None
                            and all(snap.occ is not None for _req, snap in padded))
            if redistribute:
                occ_ema = jnp.stack(
                    [jnp.asarray(snap.occ[0]) for _req, snap in padded])
                occ_step = jnp.asarray(
                    [int(snap.occ[1]) for _req, snap in padded], jnp.int32)
                fn_r = batched_redistributed_render_fn(
                    field_cfg, render_cfg, occ_cfg, chunk, g_pad, samples_per_ray,
                    redistribute_v3=bool(redistribute_v3))
                fn = lambda p, o, d, t: fn_r(p, o, d, t, occ_ema, occ_step)
            else:
                fn = batched_render_fn(field_cfg, render_cfg, chunk, g_pad)

            rgb_chunks, dep_chunks = [], []
            for i in range(0, origins.shape[1], chunk):
                rgb_c, dep_c = fn(params, origins[:, i:i + chunk],
                                  dirs[:, i:i + chunk], ts)
                rgb_chunks.append(rgb_c)
                dep_chunks.append(dep_c)
            rgb = np.asarray(jnp.concatenate(rgb_chunks, axis=1))[:, :n]
            dep = np.asarray(jnp.concatenate(dep_chunks, axis=1))[:, :n]

        now = obs_trace.clock()
        obs_on = obs_trace.enabled()
        out = []
        for gi, (req, snap) in enumerate(items):
            lat = now - req.submitted_at
            sid = req.session_id
            hist = self.latencies.get(sid)
            if hist is None:
                hist = self.latencies[sid] = obs_metrics.Histogram(
                    window=self.latency_window)
            hist.observe(lat)
            first = sid not in self.ttfuv_s
            if first and sid in self._registered_at:
                self.ttfuv_s[sid] = now - self._registered_at[sid]
            self.served[sid] = self.served.get(sid, 0) + 1
            if obs_on:
                obs_metrics.counter("serve3d.render.served").inc()
                obs_metrics.histogram("serve3d.render.latency_ms").observe(lat * 1e3)
                if first and sid in self.ttfuv_s:
                    obs_metrics.gauge(f"serve3d.render.ttfuv_s.{sid}").set(
                        self.ttfuv_s[sid])
            out.append(RenderResult(
                request_id=req.request_id,
                session_id=req.session_id,
                rgb=rgb[gi].reshape(h, w, 3),
                depth=dep[gi].reshape(h, w),
                snapshot_version=snap.version,
                snapshot_step=snap.step,
                latency_s=lat,
                stale=sid in self._stale,
                level=int(level),
            ))
        return out

    # ---- telemetry ----

    def latency_stats(self) -> dict:
        """Percentiles over the recent latency window; counts are lifetime.

        Quantiles use the obs Histogram definition (numpy linear
        interpolation) over the union of the per-session windows."""
        merged = obs_metrics.Histogram(
            window=self.latency_window * max(1, len(self.latencies)))
        for h in self.latencies.values():
            for v in h.values():
                merged.observe(v)
        degraded = {
            "expired": self.expired,
            "failed": self.failed,
            "shed_fraction": self.shed_drains / self.drains if self.drains else 0.0,
            "stale_sessions": sorted(self._stale),
        }
        if merged.count == 0:
            return {"count": 0, "degraded": degraded}
        return {
            "count": sum(self.served.values()),
            "degraded": degraded,
            "p50_ms": merged.quantile(0.50) * 1e3,
            "p95_ms": merged.quantile(0.95) * 1e3,
            "p99_ms": merged.quantile(0.99) * 1e3,
            "max_ms": max(merged.values()) * 1e3,
            "per_session": dict(self.served),
            "ttfuv_s": dict(self.ttfuv_s),
        }
