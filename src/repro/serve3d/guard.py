"""SessionGuard: per-slice divergence detection with checkpoint rollback.

The fault-tolerance contract for the reconstruction service:

* **detect** — after every training slice the guard runs a cheap health
  check on each advanced session: the slice's reported loss must be finite,
  and a PSNR-collapse heuristic (the loss's dB proxy dropping more than
  ``collapse_db`` below the session's best) catches silent divergence.  At
  ``deep_check_every`` slices it additionally reduces the session's params
  and occupancy EMA to one finiteness bool (`trainer.tree_all_finite`), so
  NaN/Inf state that has not yet surfaced in the loss is still caught.
  Exceptions raised inside a slice (captured by the scheduler) count as
  failures for every cohort member — with donated buffers a mid-slice crash
  leaves no trustworthy state.

* **rollback** — on failure the session is restored to its last *good*
  periodic checkpoint: a host tree taken by `trainer.suspend` every
  ``checkpoint_every`` healthy slices (never from a state that failed its
  deep check), falling back to a reproducible fresh `init` when the session
  diverged before its first checkpoint.  Restore reuses the bit-exact
  suspend/resume round-trip, so a rolled-back session that re-trains the
  same step range reproduces the fault-free params bit for bit — training
  streams are keyed by absolute step, not wall history.

* **retry with backoff** — each rollback arms a hold-off
  (``backoff_base_s * 2^(failures-1)``) before the scheduler may pick the
  session again, and ``failures`` counts *consecutive* failures (reset by
  any healthy slice).  After ``max_retries`` consecutive failures the
  session is **quarantined**: its device state is dropped, its last-good
  params stay available for serving (stale-annotated snapshots), and the
  scheduler treats it as terminal — one sick scene can never wedge the
  service or perturb its cohort.

* **cohort ejection** — rollback moves the sick member to an earlier
  absolute step, so its cohort key stops matching and it re-trains solo
  until it catches back up; healthy members keep advancing with bit-
  identical streams (the PR 5 invariant — member states are independent
  along the stacked axis, so a NaN member never contaminates survivors).

Observability: always-live counters/histogram on the guard object back
`stats()` (bench + telemetry work with ``REPRO_OBS`` off); the global
registry mirror (``serve3d.guard.*``) and span/instant events are gated on
the obs knob like every other serve3d surface.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field as dc_field

import jax

from ..core.trainer import tree_all_finite
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .session import DONE, SceneSession


@dataclass(frozen=True)
class GuardConfig:
    # slices between last-good checkpoints (host-tree suspend snapshots);
    # also the rollback granularity — smaller = less retraining on recovery,
    # more host-copy traffic
    checkpoint_every: int = 4
    # slices between full params/occ-EMA finiteness reductions (1 = every
    # slice; the loss check always runs)
    deep_check_every: int = 1
    # consecutive failures tolerated before the session is quarantined
    max_retries: int = 3
    # hold-off before a rolled-back session is rescheduled; doubles per
    # consecutive failure (0 = immediate retry, the deterministic default)
    backoff_base_s: float = 0.0
    # PSNR-proxy collapse threshold: -10*log10(loss) dropping this many dB
    # below the session's best counts as divergence
    collapse_db: float = 20.0
    # healthy slices observed before the collapse heuristic engages (early
    # training is noisy and has no meaningful "best" yet)
    collapse_min_history: int = 3
    # persist each last-good tree through the session's CheckpointManager
    # (when the session was submitted with ckpt_dir) so a fresh process can
    # roll back too, not just this one
    persist: bool = True


@dataclass
class _SessionRecord:
    slices: int = 0                   # healthy+failed slices inspected
    last_good: dict | None = None     # host tree from trainer.suspend
    last_good_step: int = 0
    best_db: float = -math.inf        # best PSNR proxy seen
    history: int = 0                  # healthy slices feeding the heuristic
    failures: int = 0                 # consecutive failures
    rollbacks: int = 0
    events: list = dc_field(default_factory=list)


class SessionGuard:
    def __init__(self, cfg: GuardConfig | None = None):
        self.cfg = cfg or GuardConfig()
        self._rec: dict[str, _SessionRecord] = {}
        # always-live telemetry (mirrored into the global registry when the
        # obs knob is on)
        self.recovery_ms = obs_metrics.Histogram(window=1024)
        self.rollbacks = 0
        self.quarantined: list[str] = []
        self.divergences: dict[str, int] = {}
        self.checkpoints = 0
        self.inspect_wall_s = 0.0     # steady-state overhead observable

    # ---- inspection (called by the service after every quantum) ----

    def inspect(self, sessions: list[SceneSession],
                error: Exception | None = None,
                errors: dict[str, Exception] | None = None) -> dict[str, str]:
        """Health-check every session advanced this quantum.  Returns a
        verdict per session id: ``ok``, ``rolled_back`` or ``quarantined``.
        `error` is an exception captured from inside the slice — it fails
        every member (donated buffers make partial state untrustworthy).
        `errors` is the per-session form (multi-device quanta run one
        cohort per device, so a fault on one device fails only its own
        cohort's members); when given it takes precedence."""
        t0 = obs_trace.clock()
        verdicts = {}
        for s in sessions:
            e = errors.get(s.session_id, None) if errors is not None else error
            verdicts[s.session_id] = self._inspect_one(s, e)
        self.inspect_wall_s += obs_trace.clock() - t0
        return verdicts

    def _inspect_one(self, s: SceneSession, error: Exception | None) -> str:
        cfg = self.cfg
        rec = self._rec.setdefault(s.session_id, _SessionRecord())
        rec.slices += 1
        failure = self._failure_kind(s, rec, error)
        if failure is not None:
            return self._handle_failure(s, rec, failure)

        rec.failures = 0
        rec.history += 1
        if rec.slices % cfg.checkpoint_every == 0 or s.status == DONE:
            self._checkpoint(s, rec)
        return "ok"

    def _failure_kind(self, s: SceneSession, rec: _SessionRecord,
                      error: Exception | None) -> str | None:
        cfg = self.cfg
        if error is not None:
            return "exception"
        loss = s.telemetry["loss"][-1] if s.telemetry["loss"] else None
        if loss is not None and not math.isfinite(loss):
            return "nan_loss"
        if loss is not None:
            db = -10.0 * math.log10(max(float(loss), 1e-12))
            if rec.history >= cfg.collapse_min_history and \
                    rec.best_db - db > cfg.collapse_db:
                return "collapse"
            rec.best_db = max(rec.best_db, db)
        # deep check: params + occupancy EMA finiteness.  Forced on any
        # slice that would take a checkpoint, so a poisoned state can never
        # become "last good".
        due = rec.slices % cfg.deep_check_every == 0 or \
            rec.slices % cfg.checkpoint_every == 0 or s.status == DONE
        if due and s.state is not None and not tree_all_finite(
                s.state.params, s.state.occ_state.density_ema):
            return "non_finite_state"
        return None

    # ---- recovery ----

    def _handle_failure(self, s: SceneSession, rec: _SessionRecord,
                        kind: str) -> str:
        t0 = obs_trace.clock()
        rec.failures += 1
        self.divergences[kind] = self.divergences.get(kind, 0) + 1
        obs_on = obs_trace.enabled()
        if obs_on:
            obs_metrics.counter("serve3d.guard.divergence").inc()
            obs_metrics.counter(f"serve3d.guard.divergence.{kind}").inc()
        if rec.failures > self.cfg.max_retries:
            self._quarantine(s, rec, kind)
            return "quarantined"
        from_step = s.step
        tree = rec.last_good if rec.last_good is not None else self._init_tree(s)
        with obs_trace.span("serve3d/guard_rollback", cat="serve3d",
                            args={"session": s.session_id, "kind": kind,
                                  "from_step": int(from_step),
                                  "to_step": int(rec.last_good_step)}):
            s.rollback(tree)
        # bounded exponential backoff before the scheduler may retry it
        hold = self.cfg.backoff_base_s * (2.0 ** (rec.failures - 1))
        s.hold_until = obs_trace.clock() + hold
        rec.best_db = -math.inf      # the proxy baseline restarts with the state
        rec.history = 0
        rec.rollbacks += 1
        self.rollbacks += 1
        dt_ms = (obs_trace.clock() - t0) * 1e3
        self.recovery_ms.observe(dt_ms)
        rec.events.append({"event": "rollback", "kind": kind,
                           "from_step": int(from_step), "to_step": s.step,
                           "backoff_s": hold, "recovery_ms": dt_ms})
        if obs_on:
            obs_metrics.counter("serve3d.guard.rollbacks").inc()
            obs_metrics.histogram("serve3d.guard.recovery_ms").observe(dt_ms)
        return "rolled_back"

    def _quarantine(self, s: SceneSession, rec: _SessionRecord, kind: str):
        with obs_trace.span("serve3d/guard_quarantine", cat="serve3d",
                            args={"session": s.session_id, "kind": kind}):
            tree = rec.last_good if rec.last_good is not None else self._init_tree(s)
            s.quarantine(tree)
        self.quarantined.append(s.session_id)
        rec.events.append({"event": "quarantine", "kind": kind,
                           "step": int(rec.last_good_step)})
        if obs_trace.enabled():
            obs_metrics.counter("serve3d.guard.quarantined").inc()

    def _checkpoint(self, s: SceneSession, rec: _SessionRecord):
        """Take a last-good host snapshot (only reached after the slice
        passed its health checks, including the forced deep check)."""
        if s.state is None:           # already suspended (finished member)
            rec.last_good = s._host_tree
        else:
            rec.last_good = s.trainer.suspend(s.state)
        rec.last_good_step = s.step
        self.checkpoints += 1
        if self.cfg.persist and s.ckpt is not None and s.state is not None:
            s.ckpt.save(s.step, rec.last_good)
        if obs_trace.enabled():
            obs_metrics.counter("serve3d.guard.checkpoints").inc()

    @staticmethod
    def _init_tree(s: SceneSession) -> dict:
        """Reproducible step-0 fallback when a session diverges before its
        first periodic checkpoint: `init` from the session's own seed is
        bit-identical to the state the session started from."""
        return s.trainer.suspend(s.trainer.init(jax.random.PRNGKey(s.seed)))

    # ---- telemetry ----

    def session_events(self, session_id: str) -> list[dict]:
        rec = self._rec.get(session_id)
        return list(rec.events) if rec else []

    def stats(self) -> dict:
        return {
            "rollbacks": self.rollbacks,
            "quarantined": list(self.quarantined),
            "divergences": dict(self.divergences),
            "checkpoints": self.checkpoints,
            "recovery_ms": {
                "count": self.recovery_ms.count,
                "p50": self.recovery_ms.quantile(0.50),
                "p95": self.recovery_ms.quantile(0.95),
            },
            "inspect_wall_s": self.inspect_wall_s,
        }
