"""ReconstructionService: the serve3d facade.

One object owns the train -> snapshot -> serve loop:

    service = ReconstructionService(slice_iters=16)
    sid = service.submit_scene(dataset, field_cfg, trainer_cfg, target_iters=256)
    service.request_render(sid, pose)            # answered mid-training
    telemetry = service.run()

Each `step()` is one scheduling quantum: the scheduler picks a primary live
session (round-robin or EDF), forms its train cohort — every other active
session with matching configs at the same step, advanced together through
one member-axis compiled train step (scene-parallel by default; cap or
disable with ``max_cohort``) — trains one slice, publishes each advanced
session's params + occupancy to the snapshot store (atomic swap), then the
render service drains every answerable request, coalescing same-geometry
requests across sessions into batched jitted renders.  Renders observe a
consistent published snapshot while training keeps mutating the live
(donated) buffers, and by default are served through the redistributed
render path (pipeline stage 2b at ``samples_per_ray`` points per ray)
instead of dense.

Fault tolerance (on by default; see docs/ROBUSTNESS.md): a `SessionGuard`
inspects every advanced session *before* its snapshot publishes — a
diverged slice (NaN loss/params, PSNR collapse, slice exception) is rolled
back to the last good checkpoint and never published, so the store always
serves healthy params; after ``max_retries`` consecutive failures the
session is quarantined and its last-good snapshot keeps being served,
annotated stale.  A failed publish (the store raised before its atomic
swap) is retried on the next quantum.  Pass ``guard=None``/``False`` for
the fail-fast PR 5 behavior where any slice error unwinds `run`.

Fleet scale (docs/SERVING.md): ``devices=N`` shards sessions across the
first N local devices through a `DevicePlacement` — per-device residency
caps, one train cohort per device per quantum (concurrent driver threads),
render groups routed to the device holding their sessions' state.  Faults
stay per-device: the scheduler's per-session error capture means one
device's crashed slice rolls back only that device's cohort.
``snapshot_levels=k`` publishes cheap level-k *previews* every healthy
slice until a session's first full snapshot lands (progressive streaming);
``async_serving=True`` moves the render drain onto a dedicated serving
thread so render latency stops being gated by the in-flight training
slice.  All three default off; N=1 with everything off is bit-identical to
the pre-mesh service.
"""
from __future__ import annotations

import time

from ..obs import export as obs_export
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .guard import GuardConfig, SessionGuard
from .placement import DevicePlacement
from .render import RenderService
from .scheduler import SessionScheduler
from .session import DONE, QUARANTINED, SceneSession
from .snapshot import SnapshotStore


class ReconstructionService:
    def __init__(
        self,
        slice_iters: int = 16,
        policy: str = "round_robin",
        max_resident: int | None = None,
        persist_dir: str | None = None,
        snapshot_every: int = 1,
        max_cohort: int | None = None,
        redistributed_render: bool = True,
        render_samples_per_ray: int | None = None,
        guard: GuardConfig | bool | None = True,
        render_deadline_s: float | None = None,
        shed_threshold: int | None = None,
        devices=None,
        snapshot_levels: int = 0,
        async_serving: bool = False,
    ):
        """snapshot_every: publish a session's snapshot every k-th slice it
        trains (its final slice always publishes).

        max_cohort: largest train cohort the scheduler forms per quantum
        (None = unlimited — the scene-parallel default; 1 = pure
        time-slicing, the PR 2 behavior).  Cohort training is bit-identical
        to time-slicing at equal per-scene iteration counts.

        redistributed_render / render_samples_per_ray: serve novel views
        through the occupancy-redistributed render path at S' samples per
        ray instead of rendering dense.  Default S' = max(4, n_samples//4),
        capped at n_samples: the PR 4 render sweep puts the equal-PSNR
        point at ~4 redistributed samples/ray, so dividing by 4 only once
        the dense ladder is past 16 keeps the ≤ 0.1 dB serving contract at
        small S too.

        guard: fault tolerance — True (default) runs a `SessionGuard` with
        default `GuardConfig`, a `GuardConfig` customizes it, None/False
        disables it (slice errors then unwind `run`, the PR 5 behavior).

        render_deadline_s / shed_threshold: per-request render deadline
        inherited by `request_render` and the queue depth that triggers
        quality shedding — both forwarded to `RenderService`.

        devices: shard sessions over a device mesh — an int (first n local
        devices), a device list, or None (single-device service, no
        placement).  With a placement, ``max_resident`` caps residency *per
        device*.

        snapshot_levels: 0 disables previews; k > 0 publishes a level-k
        preview snapshot after every healthy slice of a session that has no
        full snapshot yet, so level-k render requests are answerable before
        the first ``snapshot_every``-gated full publish.

        async_serving: `run` drives renders from a dedicated serving thread
        (`RenderService.start_async`) instead of draining synchronously at
        the end of each quantum."""
        self.placement = (DevicePlacement(devices)
                          if devices is not None else None)
        self.store = SnapshotStore(persist_dir=persist_dir)
        self.renderer = RenderService(self.store,
                                      default_deadline_s=render_deadline_s,
                                      shed_threshold=shed_threshold,
                                      placement=self.placement)
        self.scheduler = SessionScheduler(
            slice_iters=slice_iters, policy=policy, max_resident=max_resident,
            max_cohort=max_cohort, placement=self.placement,
        )
        if guard is True:
            guard = GuardConfig()
        self.guard = SessionGuard(guard) if guard else None
        # with a guard, slice exceptions become rollbacks instead of
        # unwinding the quantum loop
        self.scheduler.capture_errors = self.guard is not None
        self.publish_failures = 0
        self._publish_retry: set[str] = set()
        self.sessions: dict[str, SceneSession] = {}
        self.snapshot_every = max(1, int(snapshot_every))
        self.snapshot_levels = max(0, int(snapshot_levels))
        self.async_serving = bool(async_serving)
        self.redistributed_render = bool(redistributed_render)
        self.render_samples_per_ray = render_samples_per_ray
        # serving clock starts at the first quantum, not construction, so
        # dataset/scene setup between submit and run is not billed as
        # service time in scenes_per_sec
        self._started_at: float | None = None

    # ---- job submission ----

    def submit_scene(
        self,
        dataset,
        field_cfg,
        trainer_cfg,
        target_iters: int,
        *,
        session_id: str | None = None,
        seed: int = 0,
        deadline: float | None = None,
        ckpt_dir: str | None = None,
    ) -> str:
        sid = session_id if session_id is not None else f"scene-{len(self.sessions):03d}"
        if sid in self.sessions:
            raise ValueError(f"duplicate session id {sid!r}")
        sess = SceneSession(
            sid, dataset, field_cfg, trainer_cfg, target_iters,
            seed=seed, ckpt_dir=ckpt_dir, deadline=deadline,
        )
        self.sessions[sid] = sess
        self.scheduler.add(sess)
        # redistribution leans on the session's occupancy bitfield; a
        # trainer that never updates occupancy would be served all-occupied
        # forever — a permanent uniform-S' preview, not a <=0.1 dB path —
        # so occupancy-less sessions stay on the dense renderer
        spr = None
        if self.redistributed_render and trainer_cfg.use_occupancy:
            s = trainer_cfg.render.n_samples
            spr = (self.render_samples_per_ray
                   if self.render_samples_per_ray is not None
                   else min(s, max(4, s // 4)))
        # the session's offline `evaluate` marches the same serving path at
        # the same budget, so eval and served renders agree bit for bit
        sess.render_spr = spr
        self.renderer.register_session(
            sid, field_cfg, trainer_cfg.render,
            dataset.h, dataset.w, dataset.focal, trainer_cfg.eval_chunk,
            occ_cfg=trainer_cfg.occ, samples_per_ray=spr,
            # served views march whatever stage-2b variant the trainer
            # trains with, so the quadrature-mismatch annealing holds for
            # v3 sessions too
            redistribute_v3=trainer_cfg.redistribute_v3,
        )
        return sid

    def request_render(self, session_id: str, pose,
                       deadline_s: float | None = None, level: int = 0) -> int:
        """level 0 = full resolution (waits for a full snapshot); k > 0 =
        the h>>k preview, answerable by a preview snapshot."""
        return self.renderer.submit(session_id, pose,
                                    deadline_s=deadline_s, level=level)

    # ---- the serving loop ----

    def step(self) -> dict:
        """One quantum: train one cohort slice, guard-inspect every advanced
        session, publish the healthy ones, drain renders.  Ordering matters:
        the guard runs *before* publish, so a diverged slice's params can
        never reach the snapshot store — a failed member skips its publish
        and the store keeps serving the last good snapshot."""
        if self._started_at is None:
            self._started_at = obs_trace.clock()
        with obs_trace.span("serve3d/quantum", cat="serve3d",
                            args={"pending_renders": self.renderer.pending}):
            sess = self.scheduler.step()
            verdicts: dict[str, str] = {}
            if self.guard is not None and self.scheduler.last_trained:
                verdicts = self.guard.inspect(
                    self.scheduler.last_trained,
                    error=self.scheduler.last_error,
                    errors=self.scheduler.last_errors or None)
            for member in self.scheduler.last_trained:
                verdict = verdicts.get(member.session_id, "ok")
                if verdict != "ok":
                    self.renderer.mark_stale(member.session_id)
                    if verdict == "quarantined":
                        # publish the restored last-good tree once so the
                        # scene's renders terminate (served stale) even if
                        # the session never published before
                        self._publish(member)
                        self._retire(member.session_id)
                    continue
                slices = len(member.telemetry["step"])
                # a finished session may already be suspended (bounded
                # residency) — publish still works from its host tree
                if (member.status == DONE
                        or slices % self.snapshot_every == 0
                        or member.session_id in self._publish_retry):
                    self._publish(member)
                elif (self.snapshot_levels > 0
                      and self.store.latest(member.session_id, level=0) is None):
                    # progressive streaming: until the first full snapshot
                    # lands, every healthy slice publishes a cheap preview so
                    # early level-k render requests have something to serve
                    self._publish(member, level=self.snapshot_levels)
                if member.status == DONE:
                    # previews did their job; the full snapshot keeps serving
                    self.store.gc_previews(member.session_id)
            if self.renderer.async_active:
                # the serving thread owns the drain; hand it fresh snapshots
                # and collect what it finished since last quantum
                self.renderer.notify()
                results = self.renderer.poll_results()
            else:
                results = self.renderer.drain()
        if obs_trace.enabled():
            obs_metrics.counter("serve3d.quanta").inc()
            obs_metrics.gauge("serve3d.sessions_active").set(sum(
                1 for s in self.sessions.values()
                if s.status not in (DONE, QUARANTINED)))
        return {
            "trained": sess.session_id if sess is not None else None,
            "cohort": [m.session_id for m in self.scheduler.last_trained],
            "step": sess.step if sess is not None else None,
            "guard": verdicts,
            "results": results,
        }

    def _publish(self, member: SceneSession, level: int = 0) -> None:
        """Publish with retry-on-failure: the store's swap is atomic, so a
        raise means the previous snapshot is still the latest — remember the
        session and try again next quantum instead of unwinding the loop.
        (Only full publishes arm the retry — a lost preview is re-attempted
        by the next healthy slice anyway.)"""
        try:
            member.publish(self.store, level=level)
        except Exception:
            if self.guard is None:
                raise
            self.publish_failures += 1
            if level == 0:
                self._publish_retry.add(member.session_id)
            if obs_trace.enabled():
                obs_metrics.counter("serve3d.snapshot.publish_failures").inc()
        else:
            if level == 0:
                self._publish_retry.discard(member.session_id)
            if self.guard is None or member.session_id not in \
                    self.guard.quarantined:
                self.renderer.mark_stale(member.session_id, False)

    def _retire(self, session_id: str) -> None:
        """A terminal (quarantined) session stops holding mesh capacity and
        preview snapshots; its full snapshot keeps being served."""
        self.store.gc_previews(session_id)
        if self.placement is not None:
            self.placement.release(session_id)

    def run(self, hook=None, max_quanta: int = 100_000) -> dict:
        """Drive quanta until every session is done, the render queue is
        empty and (async serving) the serving thread has gone idle.
        `hook(service, event)` runs after each quantum — the place to
        submit mid-training render requests or stream telemetry."""
        if self.async_serving and not self.renderer.async_active:
            self.renderer.start_async()
        try:
            for _ in range(max_quanta):
                if self.scheduler.all_done and self.renderer.pending == 0 \
                        and self.renderer.idle:
                    break
                # step() drains even once training is done, so straggler
                # requests still flow through the hook as ordinary events
                event = self.step()
                if hook is not None:
                    hook(self, event)
                if event["trained"] is None and self.renderer.async_active:
                    # nothing left to train: we are only waiting on the
                    # serving thread — yield the GIL instead of busy-spinning
                    # it into starvation (first-contact drains trace per-device
                    # renderers, which is pure Python work)
                    time.sleep(0.002)
        finally:
            if self.renderer.async_active:
                # flush: join the serving thread, then deliver anything it
                # finished after the last quantum as one final event
                self.renderer.stop_async()
                final = self.renderer.poll_results()
                if final and hook is not None:
                    hook(self, {"trained": None, "cohort": [], "step": None,
                                "guard": {}, "results": final})
        self.store.wait()
        return self.telemetry()

    # ---- telemetry ----

    def progress(self) -> list[dict]:
        return [s.progress() for s in self.sessions.values()]

    def telemetry(self) -> dict:
        done = [s for s in self.sessions.values() if s.status == DONE]
        now = obs_trace.clock()
        wall = now - (self._started_at if self._started_at is not None else now)
        return {
            "wall_s": wall,
            "scenes_done": len(done),
            "scenes_per_sec": len(done) / wall if wall > 0 else 0.0,
            "sessions": self.progress(),
            "render": self.renderer.latency_stats(),
            "guard": self.guard.stats() if self.guard is not None else None,
            "publish_failures": self.publish_failures,
            "stragglers_flagged": self.scheduler.stragglers_flagged,
            "devices": self.placement.n if self.placement is not None else 1,
            "placement": (self.placement.stats()
                          if self.placement is not None else None),
            "async_serving": self.async_serving,
        }

    def metrics(self) -> dict:
        """The service's exportable metrics document: the global obs
        registry snapshot (trainer/pipeline/serve3d counters and histograms,
        populated when ``REPRO_OBS`` is on) under ``metrics``, plus the
        always-on service plane (per-session progress, published snapshot
        versions, render latency percentiles and per-session TTFUV) under
        ``meta.service`` — same shape `repro.obs.export.dump_metrics`
        writes and `format_metrics` renders."""
        return obs_export.metrics_snapshot(extra={"service": {
            "telemetry": self.telemetry(),
            "snapshots": {sid: self.store.latest(sid).version
                          for sid in self.store.sessions()},
        }})

    def dump_trace(self, path: str) -> str:
        """Write the span buffer as Chrome-trace JSON (Perfetto-loadable)."""
        return obs_export.dump_trace(path, process_name="repro.serve3d")
