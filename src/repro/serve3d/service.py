"""ReconstructionService: the serve3d facade.

One object owns the train -> snapshot -> serve loop:

    service = ReconstructionService(slice_iters=16)
    sid = service.submit_scene(dataset, field_cfg, trainer_cfg, target_iters=256)
    service.request_render(sid, pose)            # answered mid-training
    telemetry = service.run()

Each `step()` is one scheduling quantum: the scheduler picks a live session
(round-robin or EDF), trains one slice, publishes its params to the snapshot
store (atomic swap), then the render service drains every answerable request
— coalescing same-geometry requests across sessions into batched jitted
renders.  Renders therefore always observe a consistent published snapshot
while training keeps mutating the live (donated) buffers.
"""
from __future__ import annotations

import time

from .render import RenderService
from .scheduler import SessionScheduler
from .session import DONE, SceneSession
from .snapshot import SnapshotStore


class ReconstructionService:
    def __init__(
        self,
        slice_iters: int = 16,
        policy: str = "round_robin",
        max_resident: int | None = None,
        persist_dir: str | None = None,
        snapshot_every: int = 1,
    ):
        """snapshot_every: publish a session's snapshot every k-th slice it
        trains (its final slice always publishes)."""
        self.store = SnapshotStore(persist_dir=persist_dir)
        self.renderer = RenderService(self.store)
        self.scheduler = SessionScheduler(
            slice_iters=slice_iters, policy=policy, max_resident=max_resident
        )
        self.sessions: dict[str, SceneSession] = {}
        self.snapshot_every = max(1, int(snapshot_every))
        # serving clock starts at the first quantum, not construction, so
        # dataset/scene setup between submit and run is not billed as
        # service time in scenes_per_sec
        self._started_at: float | None = None

    # ---- job submission ----

    def submit_scene(
        self,
        dataset,
        field_cfg,
        trainer_cfg,
        target_iters: int,
        *,
        session_id: str | None = None,
        seed: int = 0,
        deadline: float | None = None,
        ckpt_dir: str | None = None,
    ) -> str:
        sid = session_id if session_id is not None else f"scene-{len(self.sessions):03d}"
        if sid in self.sessions:
            raise ValueError(f"duplicate session id {sid!r}")
        sess = SceneSession(
            sid, dataset, field_cfg, trainer_cfg, target_iters,
            seed=seed, ckpt_dir=ckpt_dir, deadline=deadline,
        )
        self.sessions[sid] = sess
        self.scheduler.add(sess)
        self.renderer.register_session(
            sid, field_cfg, trainer_cfg.render,
            dataset.h, dataset.w, dataset.focal, trainer_cfg.eval_chunk,
        )
        return sid

    def request_render(self, session_id: str, pose) -> int:
        return self.renderer.submit(session_id, pose)

    # ---- the serving loop ----

    def step(self) -> dict:
        """One quantum: train one slice, publish, drain renders."""
        if self._started_at is None:
            self._started_at = time.perf_counter()
        sess = self.scheduler.step()
        if sess is not None:
            slices = len(sess.telemetry["step"])
            # a finished session may already be suspended (bounded residency)
            # — publish still works from its host tree
            if sess.status == DONE or slices % self.snapshot_every == 0:
                sess.publish(self.store)
        results = self.renderer.drain()
        return {
            "trained": sess.session_id if sess is not None else None,
            "step": sess.step if sess is not None else None,
            "results": results,
        }

    def run(self, hook=None, max_quanta: int = 100_000) -> dict:
        """Drive quanta until every session is done and the render queue is
        empty.  `hook(service, event)` runs after each quantum — the place to
        submit mid-training render requests or stream telemetry."""
        for _ in range(max_quanta):
            if self.scheduler.all_done and self.renderer.pending == 0:
                break
            # step() drains even once training is done, so straggler requests
            # still flow through the hook as ordinary events
            event = self.step()
            if hook is not None:
                hook(self, event)
        self.store.wait()
        return self.telemetry()

    # ---- telemetry ----

    def progress(self) -> list[dict]:
        return [s.progress() for s in self.sessions.values()]

    def telemetry(self) -> dict:
        done = [s for s in self.sessions.values() if s.status == DONE]
        now = time.perf_counter()
        wall = now - (self._started_at if self._started_at is not None else now)
        return {
            "wall_s": wall,
            "scenes_done": len(done),
            "scenes_per_sec": len(done) / wall if wall > 0 else 0.0,
            "sessions": self.progress(),
            "render": self.renderer.latency_stats(),
        }
