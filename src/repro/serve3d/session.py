"""SceneSession: one scene's reconstruction job as a schedulable unit.

Wraps `Instant3DTrainer` + occupancy state + `CheckpointManager` behind a
suspend/resume lifecycle so N sessions can time-share one device:

    pending --start()--> active --run_slice(n)*--> done
                 ^            |
                 '--resume()--'--suspend()--> suspended

Two guard-driven transitions ride on top (see `serve3d.guard`):
`rollback(tree)` replaces the live state with a last-good host tree through
the bit-exact resume path, and `quarantine(tree)` is a terminal failure
state that keeps the last-good tree resident on host so serving hooks keep
working while the scheduler never picks the session again.  `run_slice` and
`run_cohort_slice` carry ``serve3d.slice`` fault sites
(`repro.testing.faults`) — one attribute check each when the harness is off.

`run_slice` advances training by a bounded number of iterations and returns;
the scheduler interleaves slices across sessions.  Training streams are
keyed by *absolute* step (the trainer folds the iteration index into its
PRNG), and the trainer's compaction bookkeeping survives suspend/resume, so
an interleaved schedule reproduces sequential single-scene training
bit-for-bit at equal per-scene iteration counts.

`suspend` moves the full training state (params, optimizer moments,
occupancy EMA + fold count, compaction bookkeeping) to host memory — and,
when a checkpoint dir is configured, to disk via the atomic commit protocol
— releasing the device footprint for other sessions.  `resume` restores
from the in-memory tree when present, else from the latest valid on-disk
checkpoint (the fresh-process path).
"""
from __future__ import annotations

import contextlib
import time
from typing import Any

import jax

import numpy as np

from ..checkpoint import CheckpointManager
from ..core import field as field_lib
from ..core.trainer import Instant3DTrainer, TrainerConfig, TrainState, train_cohort
from ..data import RaySampler
from ..obs import trace as obs_trace
from ..testing import faults

PENDING = "pending"
ACTIVE = "active"
SUSPENDED = "suspended"
DONE = "done"
QUARANTINED = "quarantined"


class SceneSession:
    def __init__(
        self,
        session_id: str,
        dataset,
        field_cfg: field_lib.FieldConfig,
        trainer_cfg: TrainerConfig,
        target_iters: int,
        *,
        seed: int = 0,
        ckpt_dir: str | None = None,
        deadline: float | None = None,
    ):
        self.session_id = session_id
        self.dataset = dataset
        self.field_cfg = field_cfg
        self.trainer_cfg = trainer_cfg
        self.target_iters = int(target_iters)
        self.seed = seed
        self.deadline = deadline  # seconds-since-submit budget for EDF scheduling
        self.field = field_lib.Field(field_cfg)
        self.trainer = Instant3DTrainer(self.field, trainer_cfg)
        self.sampler = RaySampler(dataset)
        self.ckpt = CheckpointManager(ckpt_dir, keep_last=2) if ckpt_dir else None
        self.state: TrainState | None = None
        self._host_tree: dict | None = None
        # device affinity (serve3d.placement): every jax entry point below
        # runs under `jax.default_device(self.device)`, so the session's
        # whole state lives on its assigned mesh slot.  None = process
        # default device, the single-device path.
        self.device = None
        self.device_slot: int | None = None
        # samples-per-ray the service serves this session's renders at
        # (None = dense) — `evaluate` routes through the same stage-2b
        # variant so offline eval and served views march one quadrature
        self.render_spr: int | None = None
        self.status = PENDING
        self.hold_until = 0.0  # guard backoff: scheduler skips until this clock
        self.submitted_at = obs_trace.clock()
        self.train_wall_s = 0.0
        self.telemetry: dict[str, list] = {"step": [], "loss": [], "live_fraction": []}

    # ---- device affinity (serve3d.placement) ----

    def place(self, device, slot: int | None = None) -> None:
        """Pin this session to a mesh slot.  Legal while the session holds
        no device state (before `start`, or suspended mid-move): the next
        `start`/`resume` materializes on the new device.  Training streams
        are keyed by absolute step, so a device move is bit-transparent."""
        assert self.state is None, \
            f"{self.session_id}: suspend before moving a resident session"
        self.device = device
        self.device_slot = slot

    def _device_ctx(self):
        if self.device is None:
            return contextlib.nullcontext()
        return jax.default_device(self.device)

    # ---- lifecycle ----

    @property
    def step(self) -> int:
        if self.state is not None:
            return self.state.step
        if self._host_tree is not None:
            return int(self._host_tree["step"])
        return 0

    @property
    def done(self) -> bool:
        return self.step >= self.target_iters

    @property
    def resident(self) -> bool:
        """Whether the session currently holds device state."""
        return self.state is not None

    def start(self):
        assert self.status == PENDING, f"cannot start from {self.status}"
        with self._device_ctx():
            self.state = self.trainer.init(jax.random.PRNGKey(self.seed))
        self.status = ACTIVE

    def run_slice(self, n_iters: int) -> dict:
        """Advance training by up to `n_iters` iterations (one time slice)."""
        assert self.status == ACTIVE, f"cannot train a {self.status} session"
        inj = faults.check("serve3d.slice", session=self.session_id,
                           step=int(self.step))
        if inj is not None:
            self._pre_slice_fault(inj)
        n = min(int(n_iters), self.target_iters - self.step)
        if n <= 0:
            self.status = DONE
            return {}
        t0 = obs_trace.clock()
        with obs_trace.span("serve3d/slice", cat="serve3d",
                            args={"session": self.session_id, "iters": n,
                                  "step": int(self.step),
                                  "device": self.device_slot}):
            with self._device_ctx():
                self.state, hist = self.trainer.train(
                    self.state, self.sampler, iters=n, log_every=n
                )
        if inj is not None:
            self._post_slice_fault(inj, hist)
        self._record_slice(hist, obs_trace.clock() - t0)
        return hist

    # ---- fault sites (repro.testing.faults; inert unless the knob is on) ----

    def _pre_slice_fault(self, inj):
        if inj.kind == "exception":
            raise faults.InjectedFault(
                f"{self.session_id}: injected exception at step {self.step}")
        if inj.kind == "slow":
            time.sleep(float(inj.params.get("seconds", 0.25)))

    def _post_slice_fault(self, inj, hist: dict):
        """Perturb the slice's end state the way a diverged step would: the
        params (NaN/Inf gradients landed) or the reported loss."""
        if inj.kind in ("nan_params", "inf_params"):
            val = float("nan") if inj.kind == "nan_params" else float("inf")
            self.state = self.state._replace(
                params=faults.poison_tree(self.state.params, val))
        elif inj.kind == "nan_loss":
            hist["loss"][-1] = float("nan")
        elif inj.kind == "loss_spike":
            hist["loss"][-1] = float(hist["loss"][-1]) * float(
                inj.params.get("factor", 1e6))

    def _record_slice(self, hist: dict, wall_s: float):
        self.train_wall_s += wall_s
        self.telemetry["step"].append(self.step)
        self.telemetry["loss"].append(hist["loss"][-1])
        self.telemetry["live_fraction"].append(hist["live_fraction"][-1])
        if self.done:
            self.status = DONE

    # ---- cohort training ----

    def cohort_key(self) -> tuple:
        """Sessions whose keys match can advance through one member-axis
        compiled train step: the same device slot (a cohort's stacked state
        must live on one device; None = the unplaced single-device path),
        identical field/trainer configs (the compiled shapes and the
        shared-seed sample/ts streams) and the same absolute step (the
        freeze schedule, occupancy cadence and stream keys are all functions
        of it).  Config-matched sessions co-located on a device still batch;
        the device axis only splits cohorts across slots."""
        return (self.device_slot, self.field_cfg, self.trainer_cfg, self.step)

    @staticmethod
    def run_cohort_slice(sessions: "list[SceneSession]", n_iters: int) -> int:
        """Advance a cohort of sessions in lockstep by one shared time slice.

        The slice length is clamped to the member with the least remaining
        work, so every member advances by the same count and the cohort key
        (which includes the step) stays aligned afterwards; a member that
        reaches its target simply turns DONE and drops out of the next
        quantum's cohort.  States round-trip through `train_cohort`'s
        stack/unstack, which is bit-identical to each member running
        `run_slice` alone.  Wall time is attributed evenly across members
        (one device advanced them together).  Returns the iteration count
        trained."""
        assert len({s.cohort_key() for s in sessions}) == 1, "cohort key mismatch"
        assert all(s.status == ACTIVE for s in sessions)
        injs = [faults.check("serve3d.slice", session=s.session_id,
                             step=int(s.step)) for s in sessions]
        for s, inj in zip(sessions, injs):
            if inj is not None:
                s._pre_slice_fault(inj)
        n = min(int(n_iters), min(s.target_iters - s.step for s in sessions))
        if n <= 0:
            for s in sessions:
                if s.done:
                    s.status = DONE
            return 0
        t0 = obs_trace.clock()
        with obs_trace.span("serve3d/slice", cat="serve3d",
                            args={"cohort": len(sessions), "iters": n,
                                  "step": int(sessions[0].step),
                                  "device": sessions[0].device_slot}):
            with sessions[0]._device_ctx():
                states, hists = train_cohort(
                    [s.trainer for s in sessions],
                    [s.state for s in sessions],
                    [s.sampler for s in sessions],
                    iters=n, log_every=n,
                )
        dt = (obs_trace.clock() - t0) / len(sessions)
        for s, st, hist, inj in zip(sessions, states, hists, injs):
            s.state = st
            if inj is not None:
                s._post_slice_fault(inj, hist)
            s._record_slice(hist, dt)
        return n

    # ---- suspend / resume ----

    def suspend(self, block: bool = True):
        """Offload the full training state to host (and disk if configured)."""
        assert self.state is not None, "no device state to suspend"
        self._host_tree = self.trainer.suspend(self.state)
        if self.ckpt is not None:
            self.ckpt.save(self.step, self._host_tree, block=block)
        self.state = None
        if self.status == ACTIVE:
            self.status = SUSPENDED

    def resume(self):
        """Restore device state from the in-memory tree or the latest valid
        on-disk checkpoint (fresh-process path)."""
        assert self.state is None, "already resident"
        tree = self._host_tree
        if tree is None:
            if self.ckpt is None:
                raise RuntimeError(f"{self.session_id}: nothing to resume from")
            template = self.trainer.suspend(
                self.trainer.init(jax.random.PRNGKey(self.seed))
            )
            tree, _meta = self.ckpt.restore(template)
        with self._device_ctx():
            self.state = self.trainer.resume(tree)
        self._host_tree = None
        self.status = DONE if self.done else ACTIVE

    # ---- guard recovery (see serve3d.guard) ----

    def rollback(self, tree: dict):
        """Replace the live state with a last-good host tree.  Whatever the
        session currently holds is dropped — after a failed slice the device
        state is untrustworthy (donation may have consumed its buffers, or
        its leaves are poisoned).  Restoring through the bit-exact resume
        path means retraining from the restored step reproduces the
        fault-free stream bit for bit."""
        self.state = None
        self._host_tree = dict(tree)
        self.resume()

    def quarantine(self, tree: dict | None = None):
        """Terminal failure state: drop the (possibly poisoned) device
        state, keep the last-good host tree resident so the serving hooks
        (`publish`, `evaluate`) still expose the newest healthy params.  A
        quarantined session is never scheduled again; its snapshot keeps
        being served, annotated stale."""
        self.state = None
        if tree is not None:
            self._host_tree = dict(tree)
        self.status = QUARANTINED

    # ---- serving hooks ----

    def _current_params(self):
        """Latest params, resident or suspended (host tree)."""
        if self.state is not None:
            return self.state.params
        if self._host_tree is not None:
            return self._host_tree["params"]
        raise RuntimeError(f"{self.session_id}: no trained state yet")

    def _current_occ(self) -> tuple:
        """(density EMA, fold count) matching `_current_params` — published
        alongside params so the redistributed render path can rebuild the
        session's occupancy bitfield from the snapshot alone."""
        if self.state is not None:
            occ = self.state.occ_state
            return np.asarray(occ.density_ema), int(occ.step)
        if self._host_tree is not None:
            return (np.asarray(self._host_tree["occ_ema"]),
                    int(self._host_tree["occ_step"]))
        raise RuntimeError(f"{self.session_id}: no trained state yet")

    def publish(self, store, level: int = 0) -> "Any":
        """Publish current params + occupancy to a SnapshotStore (atomic
        swap).  level 0 is the full-resolution snapshot; level k > 0 marks a
        *preview* — same params, but renders resolve at h>>k (progressive
        streaming; see docs/SERVING.md)."""
        meta = {
            "loss": float(self.telemetry["loss"][-1]) if self.telemetry["loss"] else None,
            "train_wall_s": self.train_wall_s,
        }
        return store.publish(self.session_id, self._current_params(), self.step,
                             meta, occ=self._current_occ(), level=level)

    def evaluate(self, views=None) -> dict:
        """PSNR of the *current* params against this session's ground truth.

        Served through the same quadrature the session's renders use: when
        the service registered this session for redistributed serving
        (``render_spr``), eval routes through the trainer's stage-2b
        variant with the current occupancy state — bit-for-bit the served
        render path, closing the train/eval quadrature mismatch.  Dense
        otherwise (standalone sessions keep the historical behavior)."""
        occ = None
        if self.render_spr is not None and self.trainer_cfg.use_occupancy:
            occ = self._current_occ()
        return self.trainer.evaluate(self._current_params(), self.dataset,
                                     views=views, occ=occ,
                                     samples_per_ray=self.render_spr)

    def progress(self) -> dict:
        return {
            "session_id": self.session_id,
            "status": self.status,
            "device": self.device_slot,
            "step": self.step,
            "target_iters": self.target_iters,
            "loss": self.telemetry["loss"][-1] if self.telemetry["loss"] else None,
            "train_wall_s": self.train_wall_s,
        }
