"""serve3d — multi-scene reconstruction service (Instant-3D as a service
primitive: accept scene jobs, shard sessions across a device mesh and
time-slice each device across its concurrent training sessions, serve
batched novel-view renders from published snapshots — routed to the device
holding each scene, optionally from a dedicated async serving thread —
while training continues, and survive divergence/crash faults via guard
rollback and graceful render degradation)."""
from .session import (  # noqa: F401
    SceneSession, PENDING, ACTIVE, SUSPENDED, DONE, QUARANTINED,
)
from .placement import DevicePlacement  # noqa: F401
from .scheduler import SessionScheduler  # noqa: F401
from .snapshot import Snapshot, SnapshotStore  # noqa: F401
from .render import (  # noqa: F401
    RenderError, RenderRequest, RenderResult, RenderService,
    batched_render_fn, batched_redistributed_render_fn,
)
from .guard import GuardConfig, SessionGuard  # noqa: F401
from .service import ReconstructionService  # noqa: F401
