"""serve3d — multi-scene reconstruction service (Instant-3D as a service
primitive: accept scene jobs, time-slice the device across concurrent
training sessions, serve batched novel-view renders from published
snapshots while training continues)."""
from .session import SceneSession, PENDING, ACTIVE, SUSPENDED, DONE  # noqa: F401
from .scheduler import SessionScheduler  # noqa: F401
from .snapshot import Snapshot, SnapshotStore  # noqa: F401
from .render import (  # noqa: F401
    RenderRequest, RenderResult, RenderService,
    batched_render_fn, batched_redistributed_render_fn,
)
from .service import ReconstructionService  # noqa: F401
