"""serve3d — multi-scene reconstruction service (Instant-3D as a service
primitive: accept scene jobs, time-slice the device across concurrent
training sessions, serve batched novel-view renders from published
snapshots while training continues, and survive divergence/crash faults
via guard rollback and graceful render degradation)."""
from .session import (  # noqa: F401
    SceneSession, PENDING, ACTIVE, SUSPENDED, DONE, QUARANTINED,
)
from .scheduler import SessionScheduler  # noqa: F401
from .snapshot import Snapshot, SnapshotStore  # noqa: F401
from .render import (  # noqa: F401
    RenderError, RenderRequest, RenderResult, RenderService,
    batched_render_fn, batched_redistributed_render_fn,
)
from .guard import GuardConfig, SessionGuard  # noqa: F401
from .service import ReconstructionService  # noqa: F401
