"""DevicePlacement: session -> device affinity for the sharded service.

The fleet-scale contract (docs/SERVING.md): every admitted session is
assigned one *mesh slot* (a device) and its entire training state — params,
optimizer moments, occupancy grid — lives on that device until the session
finishes or is explicitly moved.  Sessions are sharded, tensors are not:
no partition specs, no collectives, and the bit-identity invariants of the
single-device service carry over unchanged (training math never crosses a
device boundary).

Policy: **deterministic least-loaded**.  `assign` picks the slot with the
fewest live assigned sessions, breaking ties toward the lowest slot index,
and is *sticky* — re-assigning an already-placed session returns its
existing slot, so suspend/resume round-trips keep their device affinity.
An explicit `move` re-homes a session (used with suspend/resume: suspend
pulls state to host, move retargets the slot, resume materializes on the
new device — bit-identical, because resume is bit-exact and the training
streams are keyed by absolute step, not by device).

`release` drops a finished/quarantined session from the load accounting so
its slot capacity returns to the admission pool — the scheduler's
``max_resident`` is interpreted *per device* when a placement is attached,
which is what makes total residency scale with device count.

Determinism: with the same submission order and the same device count,
assignments are reproducible — the N=1 degenerate case places everything on
device 0 (the process default device) and the service is bit-identical to
the placement-free path, gated by ``scale_out.n1_bit_identical`` in
BENCH_serve3d.json.
"""
from __future__ import annotations

from ..launch.mesh import session_devices


class DevicePlacement:
    def __init__(self, devices=None):
        """devices: an int (use the first n local devices), an explicit
        device list, or None (all local devices)."""
        if devices is None or isinstance(devices, int):
            devices = session_devices(devices)
        self.devices = list(devices)
        if not self.devices:
            raise ValueError("DevicePlacement needs at least one device")
        self._slot: dict[str, int] = {}     # session_id -> slot index
        self._load: list[int] = [0] * len(self.devices)
        self._released: set[str] = set()    # finished: off the load books

    @property
    def n(self) -> int:
        return len(self.devices)

    # ---- assignment ----

    def assign(self, session_id: str) -> int:
        """Sticky least-loaded slot for this session (ties -> lowest slot)."""
        slot = self._slot.get(session_id)
        if slot is not None:
            return slot
        slot = min(range(self.n), key=lambda i: (self._load[i], i))
        self._slot[session_id] = slot
        self._load[slot] += 1
        return slot

    def move(self, session_id: str, slot: int | None = None) -> int:
        """Re-home a session: to an explicit slot, or to the least-loaded
        other slot (the rebalance move).  The caller owns the state motion
        (suspend before, resume after); this only retargets the affinity."""
        old = self._slot.get(session_id)
        if old is None:
            raise KeyError(f"unplaced session {session_id!r}")
        if slot is None:
            others = [i for i in range(self.n) if i != old] or [old]
            slot = min(others, key=lambda i: (self._load[i], i))
        slot = int(slot)
        if not 0 <= slot < self.n:
            raise ValueError(f"slot {slot} out of range for {self.n} devices")
        if slot != old:
            if session_id not in self._released:
                self._load[old] -= 1
                self._load[slot] += 1
            self._slot[session_id] = slot
        return slot

    def release(self, session_id: str) -> None:
        """Drop a finished/quarantined session from the load accounting.
        The slot *mapping* survives — render routing keeps resolving the
        scene's published snapshots to its device — but the slot's capacity
        returns to the admission pool."""
        slot = self._slot.get(session_id)
        if slot is not None and session_id not in self._released:
            self._released.add(session_id)
            self._load[slot] -= 1

    # ---- lookup ----

    def slot(self, session_id: str) -> int | None:
        return self._slot.get(session_id)

    def device(self, session_id: str):
        """The device holding this session's state (None when unplaced)."""
        slot = self._slot.get(session_id)
        return None if slot is None else self.devices[slot]

    def device_for_slot(self, slot: int):
        return self.devices[slot]

    def loads(self) -> list[int]:
        return list(self._load)

    def stats(self) -> dict:
        return {
            "devices": [str(d) for d in self.devices],
            "loads": self.loads(),
            "placed": dict(self._slot),
        }
