"""Snapshot store: atomic publish of live training params for rendering.

The serving contract is train -> snapshot -> serve: render requests never
read a session's live (donated, in-flight) training buffers — they read the
last *published* snapshot, an immutable host-side copy.  Publish builds the
complete record first and then swaps one dict slot under a lock, so a reader
always sees either the previous or the new snapshot, never a torn mix of
params from one step and metadata from another.

With `persist_dir` set, each publish also lands in a per-session
`CheckpointManager` directory (atomic tmp+rename commit protocol), so a
service restart can re-serve every scene's latest published view without
retraining.
"""
from __future__ import annotations

import threading
from typing import Any, NamedTuple

import jax

from ..checkpoint import CheckpointManager


class Snapshot(NamedTuple):
    session_id: str
    version: int        # monotonically increasing per session, starts at 1
    step: int           # training step the params were taken at
    params: Any         # host-side (numpy) param pytree — immutable by contract
    meta: dict


class SnapshotStore:
    def __init__(self, persist_dir: str | None = None, keep_last: int = 2):
        self._latest: dict[str, Snapshot] = {}
        self._lock = threading.Lock()
        self.persist_dir = persist_dir
        self.keep_last = keep_last
        self._ckpts: dict[str, CheckpointManager] = {}

    def publish(self, session_id: str, params, step: int, meta: dict | None = None) -> Snapshot:
        """Copy params to host and atomically make them the session's latest."""
        host = jax.device_get(params)
        with self._lock:
            prev = self._latest.get(session_id)
            snap = Snapshot(
                session_id=session_id,
                version=(prev.version + 1) if prev else 1,
                step=int(step),
                params=host,
                meta=dict(meta or {}),
            )
            self._latest[session_id] = snap
        if self.persist_dir is not None:
            ckpt = self._ckpts.get(session_id)
            if ckpt is None:
                ckpt = self._ckpts[session_id] = CheckpointManager(
                    f"{self.persist_dir}/{session_id}", keep_last=self.keep_last
                )
            ckpt.save(snap.step, {"params": host},
                      extra={"version": snap.version, **snap.meta})
        return snap

    def latest(self, session_id: str) -> Snapshot | None:
        with self._lock:
            return self._latest.get(session_id)

    def sessions(self) -> list[str]:
        with self._lock:
            return sorted(self._latest)

    def wait(self):
        """Block until all in-flight persisted writes are committed."""
        for ckpt in self._ckpts.values():
            ckpt.wait()
