"""Snapshot store: atomic publish of live training params for rendering.

The serving contract is train -> snapshot -> serve: render requests never
read a session's live (donated, in-flight) training buffers — they read the
last *published* snapshot, an immutable host-side copy.  Publish builds the
complete record first and then swaps one dict slot under a lock, so a reader
always sees either the previous or the new snapshot, never a torn mix of
params from one step and metadata from another.

With `persist_dir` set, each publish also lands in a per-session
`CheckpointManager` directory (atomic tmp+rename commit protocol), so a
service restart can re-serve every scene's latest published view without
retraining.

Fault site ``serve3d.snapshot_publish`` (kind ``snapshot_fail``) raises
*before* the lock-swap: a failed publish must leave the previous snapshot
as the session's latest — the service retries the publish on the next
quantum and readers never observe a gap.
"""
from __future__ import annotations

import threading
from typing import Any, NamedTuple

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..testing import faults


class Snapshot(NamedTuple):
    session_id: str
    version: int        # monotonically increasing per session, starts at 1
    step: int           # training step the params were taken at
    params: Any         # host-side (numpy) param pytree — immutable by contract
    meta: dict
    # (density EMA (R^3,), fold count) at the published step, or None for a
    # params-only publisher.  The redistributed render path rebuilds the
    # session's occupancy bitfield from this, so serving needs no live
    # trainer state — same immutability contract as params.
    occ: Any = None


class SnapshotStore:
    def __init__(self, persist_dir: str | None = None, keep_last: int = 2):
        self._latest: dict[str, Snapshot] = {}
        self._lock = threading.Lock()
        self.persist_dir = persist_dir
        self.keep_last = keep_last
        self._ckpts: dict[str, CheckpointManager] = {}

    def publish(self, session_id: str, params, step: int, meta: dict | None = None,
                occ=None) -> Snapshot:
        """Copy params (+ occupancy) to host and atomically make them the
        session's latest."""
        with obs_trace.span("serve3d/snapshot_publish", cat="serve3d",
                            args={"session": session_id, "step": int(step)}):
            return self._publish(session_id, params, step, meta, occ)

    def _publish(self, session_id: str, params, step: int, meta: dict | None,
                 occ) -> Snapshot:
        inj = faults.check("serve3d.snapshot_publish", session=session_id,
                           step=int(step))
        if inj is not None and inj.kind == "snapshot_fail":
            raise faults.InjectedFault(
                f"injected publish failure for {session_id} at step {step}")
        host = jax.device_get(params)
        host_occ = None if occ is None else (
            jax.device_get(occ[0]), int(occ[1])
        )
        with self._lock:
            prev = self._latest.get(session_id)
            snap = Snapshot(
                session_id=session_id,
                version=(prev.version + 1) if prev else 1,
                step=int(step),
                params=host,
                meta=dict(meta or {}),
                occ=host_occ,
            )
            self._latest[session_id] = snap
        if obs_trace.enabled():
            obs_metrics.counter("serve3d.snapshots_published").inc()
        if self.persist_dir is not None:
            ckpt = self._ckpts.get(session_id)
            if ckpt is None:
                ckpt = self._ckpts[session_id] = CheckpointManager(
                    f"{self.persist_dir}/{session_id}", keep_last=self.keep_last
                )
            tree = {"params": host}
            if host_occ is not None:
                tree["occ_ema"] = host_occ[0]
                tree["occ_step"] = np.asarray(host_occ[1], np.int32)
            ckpt.save(snap.step, tree,
                      extra={"version": snap.version, **snap.meta})
        return snap

    def latest(self, session_id: str) -> Snapshot | None:
        with self._lock:
            return self._latest.get(session_id)

    def sessions(self) -> list[str]:
        with self._lock:
            return sorted(self._latest)

    def wait(self):
        """Block until all in-flight persisted writes are committed."""
        for ckpt in self._ckpts.values():
            ckpt.wait()
