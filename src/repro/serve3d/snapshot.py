"""Snapshot store: atomic publish of live training params for rendering.

The serving contract is train -> snapshot -> serve: render requests never
read a session's live (donated, in-flight) training buffers — they read the
last *published* snapshot, an immutable host-side copy.  Publish builds the
complete record first and then swaps one dict slot under a lock, so a reader
always sees either the previous or the new snapshot, never a torn mix of
params from one step and metadata from another.

Snapshot **levels** (progressive streaming; docs/SERVING.md): level 0 is
the full-resolution snapshot, level k > 0 marks a *preview* — the same
params, but render requests against it resolve at h>>k.  A session early in
its life publishes previews every healthy slice until its first level-0
snapshot lands, so clients get a cheap usable view quickly; `latest`
prefers the full snapshot and falls back to the best (lowest-level)
preview, and `gc_previews` drops a dead session's previews so a long-lived
store holds exactly one full snapshot per scene at steady state.  Versions
are monotone per *session* across levels, so a renderer can always order
what it saw.  Only level-0 snapshots persist to disk.

With `persist_dir` set, each full publish also lands in a per-session
`CheckpointManager` directory (atomic tmp+rename commit protocol), so a
service restart can re-serve every scene's latest published view without
retraining.

Fault site ``serve3d.snapshot_publish`` (kind ``snapshot_fail``) raises
*before* the lock-swap: a failed publish must leave the previous snapshot
as the session's latest — the service retries the publish on the next
quantum and readers never observe a gap.
"""
from __future__ import annotations

import threading
from typing import Any, NamedTuple

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..testing import faults


class Snapshot(NamedTuple):
    session_id: str
    version: int        # monotonically increasing per session, starts at 1
    step: int           # training step the params were taken at
    params: Any         # host-side (numpy) param pytree — immutable by contract
    meta: dict
    # (density EMA (R^3,), fold count) at the published step, or None for a
    # params-only publisher.  The redistributed render path rebuilds the
    # session's occupancy bitfield from this, so serving needs no live
    # trainer state — same immutability contract as params.
    occ: Any = None
    # 0 = full resolution; k > 0 = preview (renders resolve at h>>k)
    level: int = 0


class SnapshotStore:
    def __init__(self, persist_dir: str | None = None, keep_last: int = 2):
        # session -> level -> latest snapshot at that level
        self._latest: dict[str, dict[int, Snapshot]] = {}
        self._versions: dict[str, int] = {}
        self._lock = threading.Lock()
        self.persist_dir = persist_dir
        self.keep_last = keep_last
        self._ckpts: dict[str, CheckpointManager] = {}

    def publish(self, session_id: str, params, step: int, meta: dict | None = None,
                occ=None, level: int = 0) -> Snapshot:
        """Copy params (+ occupancy) to host and atomically make them the
        session's latest at `level`."""
        with obs_trace.span("serve3d/snapshot_publish", cat="serve3d",
                            args={"session": session_id, "step": int(step),
                                  "level": int(level)}):
            return self._publish(session_id, params, step, meta, occ, int(level))

    def _publish(self, session_id: str, params, step: int, meta: dict | None,
                 occ, level: int) -> Snapshot:
        inj = faults.check("serve3d.snapshot_publish", session=session_id,
                           step=int(step))
        if inj is not None and inj.kind == "snapshot_fail":
            raise faults.InjectedFault(
                f"injected publish failure for {session_id} at step {step}")
        host = jax.device_get(params)
        host_occ = None if occ is None else (
            jax.device_get(occ[0]), int(occ[1])
        )
        with self._lock:
            version = self._versions.get(session_id, 0) + 1
            self._versions[session_id] = version
            snap = Snapshot(
                session_id=session_id,
                version=version,
                step=int(step),
                params=host,
                meta=dict(meta or {}),
                occ=host_occ,
                level=level,
            )
            self._latest.setdefault(session_id, {})[level] = snap
        if obs_trace.enabled():
            obs_metrics.counter("serve3d.snapshots_published").inc()
            if level > 0:
                obs_metrics.counter("serve3d.previews_published").inc()
        if self.persist_dir is not None and level == 0:
            ckpt = self._ckpts.get(session_id)
            if ckpt is None:
                ckpt = self._ckpts[session_id] = CheckpointManager(
                    f"{self.persist_dir}/{session_id}", keep_last=self.keep_last
                )
            tree = {"params": host}
            if host_occ is not None:
                tree["occ_ema"] = host_occ[0]
                tree["occ_step"] = np.asarray(host_occ[1], np.int32)
            ckpt.save(snap.step, tree,
                      extra={"version": snap.version, **snap.meta})
        return snap

    def latest(self, session_id: str, level: int | None = None) -> Snapshot | None:
        """The session's latest snapshot: at exactly `level` when given,
        otherwise the full snapshot, falling back to the best (lowest-level)
        preview while no full one exists."""
        with self._lock:
            by_level = self._latest.get(session_id)
            if not by_level:
                return None
            if level is not None:
                return by_level.get(int(level))
            return by_level.get(0) or by_level[min(by_level)]

    def gc_previews(self, session_id: str) -> int:
        """Drop every preview (level > 0) for a dead/finished session;
        returns the number collected.  The full snapshot stays — a finished
        scene keeps being servable forever."""
        with self._lock:
            by_level = self._latest.get(session_id)
            if not by_level:
                return 0
            previews = [lv for lv in by_level if lv > 0]
            for lv in previews:
                del by_level[lv]
        if previews and obs_trace.enabled():
            obs_metrics.counter("serve3d.previews_gcd").inc(len(previews))
        return len(previews)

    def levels(self, session_id: str) -> list[int]:
        with self._lock:
            return sorted(self._latest.get(session_id, {}))

    def sessions(self) -> list[str]:
        with self._lock:
            return sorted(self._latest)

    def wait(self):
        """Block until all in-flight persisted writes are committed."""
        for ckpt in self._ckpts.values():
            ckpt.wait()
