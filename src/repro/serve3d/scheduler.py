"""Session scheduler: time-slice one device across many training sessions.

Two policies over the resident set:

* ``round_robin`` (default) — fair rotation; every live session advances by
  one slice per cycle, so concurrent scenes progress at the same
  iterations/sec and an interleaved run matches sequential training at
  equal per-scene iteration counts.
* ``edf`` — earliest-deadline-first; sessions carry an absolute deadline
  (seconds since submission) and the most urgent live session trains next.
  Ties (or sessions without deadlines) fall back to round-robin order.

Residency reuses the continuous-batching slot-reset idiom from
``repro.launch.serve``: at most ``max_resident`` sessions hold device state
at once (a "slot"), the rest queue as pending.  When a resident session
completes, its slot is reset — the next queued session is admitted
(``start`` for fresh jobs, ``resume`` for suspended ones) exactly like a
finished decode sequence being replaced by the next request.  The default
slice length is a multiple of the occupancy update interval so budget
re-measurement happens at the same absolute steps as in a sequential run.
"""
from __future__ import annotations

from .session import ACTIVE, DONE, PENDING, SUSPENDED, SceneSession


class SessionScheduler:
    def __init__(self, slice_iters: int = 16, policy: str = "round_robin",
                 max_resident: int | None = None):
        if policy not in ("round_robin", "edf"):
            raise ValueError(f"unknown policy {policy!r}")
        self.slice_iters = int(slice_iters)
        self.policy = policy
        self.max_resident = max_resident
        self.sessions: list[SceneSession] = []
        self._rr = 0  # round-robin cursor

    # ---- membership ----

    def add(self, session: SceneSession):
        self.sessions.append(session)
        self._admit()

    def live(self) -> list[SceneSession]:
        return [s for s in self.sessions if s.status != DONE]

    @property
    def all_done(self) -> bool:
        return not self.live()

    # ---- slot admission (continuous-batching idiom) ----

    def _resident_count(self) -> int:
        return sum(1 for s in self.sessions if s.resident and s.status != DONE)

    def _admit(self):
        """Fill free slots with queued sessions: submission order under
        round-robin, most-urgent-first under EDF.  Residents are never
        preempted — EDF governs admission of queued jobs and selection among
        active ones, not eviction."""
        cap = self.max_resident if self.max_resident is not None else len(self.sessions)
        queued = [s for s in self.sessions if s.status in (PENDING, SUSPENDED)]
        if self.policy == "edf":
            queued.sort(key=lambda s: (s.deadline is None,
                                       (s.submitted_at + s.deadline)
                                       if s.deadline is not None else 0.0))
        for s in queued:
            if self._resident_count() >= cap:
                break
            if s.status == PENDING:
                s.start()
            else:
                s.resume()

    # ---- selection ----

    def next_session(self) -> SceneSession | None:
        """Pick the session to train next; None when everything is done."""
        self._admit()
        live = [s for s in self.sessions if s.status == ACTIVE]
        if not live:
            return None
        if self.policy == "edf":
            with_deadline = [s for s in live if s.deadline is not None]
            if with_deadline:
                return min(
                    with_deadline, key=lambda s: s.submitted_at + s.deadline
                )
        # fair rotation over the stable session list
        for _ in range(len(self.sessions)):
            s = self.sessions[self._rr % len(self.sessions)]
            self._rr += 1
            if s.status == ACTIVE:
                return s
        return live[0]

    def step(self) -> SceneSession | None:
        """Run one scheduling quantum: pick a session, train one slice,
        reset its slot (admit the next queued job) if it finished."""
        s = self.next_session()
        if s is None:
            return None
        s.run_slice(self.slice_iters)
        if s.status == DONE:
            if self.max_resident is not None and s.resident:
                # bounded residency: a finished job must actually release its
                # device footprint, not just stop counting against the cap
                # (publish/evaluate still work from the suspended host tree)
                s.suspend(block=False)
            self._admit()  # slot reset: finished job's slot goes to the queue
        return s
