"""Session scheduler: time-slice one device across many training sessions.

Two policies over the resident set:

* ``round_robin`` (default) — fair rotation; every live session advances by
  one slice per cycle, so concurrent scenes progress at the same
  iterations/sec and an interleaved run matches sequential training at
  equal per-scene iteration counts.
* ``edf`` — earliest-deadline-first; sessions carry an absolute deadline
  (seconds since submission) and the most urgent live session trains next.
  Ties (or sessions without deadlines) fall back to round-robin order.

Residency reuses the continuous-batching slot-reset idiom from
``repro.launch.serve``: at most ``max_resident`` sessions hold device state
at once (a "slot"), the rest queue as pending.  When a resident session
completes, its slot is reset — the next queued session is admitted
(``start`` for fresh jobs, ``resume`` for suspended ones) exactly like a
finished decode sequence being replaced by the next request.  The default
slice length is a multiple of the occupancy update interval so budget
re-measurement happens at the same absolute steps as in a sequential run.

Train cohorts (``max_cohort``): sessions whose cohort keys match — same
field/trainer configs and the same absolute step — are grouped around the
quantum's primary session and advanced together through one member-axis
compiled train step (`SceneSession.run_cohort_slice`), instead of each
waiting for its own quantum.  Cohort training is bit-identical to the
time-sliced path, so this changes throughput, never results.  Fairness
under round-robin is preserved with slice credits: a session advanced as a
non-primary cohort member is skipped once when its own turn comes, so mixed
workloads (cohort + singleton sessions) still progress at equal
iterations/sec per session.  Under EDF the urgent session stays primary and
compatible sessions ride along — a deliberate throughput-over-latency
trade, since the cohort slice advances M scenes in less wall time than M
quanta but takes longer than the urgent session's solo slice.

Device mesh (``placement``; see docs/SERVING.md): with a `DevicePlacement`
attached, every admitted session is assigned a mesh slot (sticky
least-loaded), ``max_resident`` is interpreted *per device* so total
residency scales with device count, and each quantum advances one cohort
per device — concurrently via a small thread pool when more than one slot
has work.  Cohort keys carry the device axis, so cohorts never straddle
devices and co-located config-matched sessions still batch.  Per-session
training math is untouched by placement (whole-state-per-device, no
collectives), so every bit-identity invariant of the single-device
scheduler carries over; N=1 degenerates to the placement-free path
bit-for-bit.

Fault tolerance (see `serve3d.guard`): with ``capture_errors`` on, an
exception escaping a training slice is caught and parked in ``last_error``
(and per-session in ``last_errors`` — under a multi-device quantum a fault
on one device must only fail that device's cohort)
for the guard to turn into rollbacks instead of killing the quantum loop.
Sessions in guard backoff (``hold_until`` in the future) are skipped by
selection, QUARANTINED sessions are terminal (excluded from `live`, so one
sick scene can't wedge ``all_done``), and a per-session straggler watchdog
(the TrainDriver EWMA detector) flags slices running ``sigma`` deviations
over the session's own trend — flagged sessions are deprioritized one turn
via the slice-credit mechanism (reschedule, never block) and counted in
``serve3d.straggler.flagged``.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..runtime.driver import StragglerStats
from .session import ACTIVE, DONE, PENDING, QUARANTINED, SUSPENDED, SceneSession


class SessionScheduler:
    def __init__(self, slice_iters: int = 16, policy: str = "round_robin",
                 max_resident: int | None = None,
                 max_cohort: int | None = 1,
                 straggler_sigma: float = 4.0,
                 straggler_alpha: float = 0.25,
                 placement=None):
        """max_cohort: largest train cohort formed around a quantum's primary
        session — 1 disables cohort formation (pure time-slicing, the
        PR 2 behavior), None removes the cap (every key-matching session
        rides along).

        placement: a `serve3d.placement.DevicePlacement` sharding admitted
        sessions over a device mesh.  With one attached, ``max_resident``
        is a *per-device* cap and each quantum advances one cohort per
        device (the multi-device quantum)."""
        if policy not in ("round_robin", "edf"):
            raise ValueError(f"unknown policy {policy!r}")
        self.slice_iters = int(slice_iters)
        self.policy = policy
        self.max_resident = max_resident
        self.max_cohort = max_cohort
        self.placement = placement
        self._pool: ThreadPoolExecutor | None = None
        self.sessions: list[SceneSession] = []
        self._rr = 0  # round-robin cursor
        # sessions advanced as non-primary cohort members hold a slice
        # credit; the round-robin cursor skips them once so cohorts don't
        # double-dip relative to singleton sessions
        self._credit: dict[str, int] = {}
        self.last_trained: list[SceneSession] = []
        # fault tolerance: the guard flips capture_errors on so a slice
        # exception becomes last_error (inspected after the quantum) instead
        # of unwinding the service loop
        self.capture_errors = False
        self.last_error: Exception | None = None
        # per-session view of the same thing: under a multi-device quantum a
        # slice exception fails only its own device's cohort members
        self.last_errors: dict[str, Exception] = {}
        # straggler watchdog: per-session EWMA of slice wall time
        self.straggler_sigma = float(straggler_sigma)
        self.straggler_alpha = float(straggler_alpha)
        self._straggler: dict[str, StragglerStats] = {}
        self.stragglers_flagged = 0

    # ---- membership ----

    def add(self, session: SceneSession):
        self.sessions.append(session)
        self._admit()

    def live(self) -> list[SceneSession]:
        # QUARANTINED is terminal: the session will never train again, so it
        # must not keep the service loop alive (its last-good snapshot keeps
        # being served regardless)
        return [s for s in self.sessions
                if s.status not in (DONE, QUARANTINED)]

    @property
    def all_done(self) -> bool:
        return not self.live()

    # ---- slot admission (continuous-batching idiom) ----

    def _resident_count(self, slot: int | None = None) -> int:
        return sum(1 for s in self.sessions
                   if s.resident and s.status != DONE
                   and (slot is None or s.device_slot == slot))

    def _admit(self):
        """Fill free slots with queued sessions: submission order under
        round-robin, most-urgent-first under EDF.  Residents are never
        preempted — EDF governs admission of queued jobs and selection among
        active ones, not eviction.

        With a placement, admission also assigns the mesh slot (sticky
        least-loaded) and the residency cap applies per device — a full
        device defers only its *own* queued sessions (affinity holds across
        suspend/resume), so total residency scales with the mesh."""
        cap = self.max_resident if self.max_resident is not None else len(self.sessions)
        queued = [s for s in self.sessions if s.status in (PENDING, SUSPENDED)]
        if self.policy == "edf":
            queued.sort(key=lambda s: (s.deadline is None,
                                       (s.submitted_at + s.deadline)
                                       if s.deadline is not None else 0.0))
        for s in queued:
            if self.placement is not None:
                slot = self.placement.assign(s.session_id)
                if self._resident_count(slot) >= cap:
                    continue
                if s.device_slot != slot:
                    s.place(self.placement.device_for_slot(slot), slot)
            elif self._resident_count() >= cap:
                break
            if s.status == PENDING:
                s.start()
            else:
                s.resume()

    # ---- selection ----

    def next_session(self) -> SceneSession | None:
        """Pick the session to train next; None when everything is done."""
        self._admit()
        live = [s for s in self.sessions if s.status == ACTIVE]
        if not live:
            return None
        now = obs_trace.clock()
        ready = [s for s in live if s.hold_until <= now]
        if not ready:
            # every active session is in guard backoff: sleep to the
            # earliest release instead of busy-spinning the quantum loop
            time.sleep(max(0.0, min(s.hold_until for s in live) - now))
            now = obs_trace.clock()
            ready = live
        return self._select(ready, now, slot=None)

    def _select(self, ready: list[SceneSession], now: float,
                slot: int | None) -> SceneSession | None:
        """Policy selection over an already-admitted ready set; with `slot`,
        only that device's sessions are considered (the per-device leg of a
        multi-device quantum — selection never sleeps there, an idle device
        simply sits the quantum out)."""
        if slot is not None:
            ready = [s for s in ready if s.device_slot == slot]
            if not ready:
                return None
        if self.policy == "edf":
            # deadlines outrank slice credits: an urgent session is never
            # skipped because it already rode along in someone's cohort
            with_deadline = [s for s in ready if s.deadline is not None]
            if with_deadline:
                return min(
                    with_deadline, key=lambda s: s.submitted_at + s.deadline
                )
        # fair rotation over the stable session list; one extra lap bounds
        # the case where every live session holds a cohort credit
        for _ in range(2 * len(self.sessions)):
            s = self.sessions[self._rr % len(self.sessions)]
            self._rr += 1
            if s.status == ACTIVE and s.hold_until <= now and \
                    (slot is None or s.device_slot == slot):
                if self._credit.get(s.session_id, 0) > 0:
                    self._credit[s.session_id] -= 1
                    continue
                return s
        return ready[0]

    def cohort_for(self, primary: SceneSession) -> list[SceneSession]:
        """The quantum's train cohort: the primary plus every other ACTIVE
        session with a matching cohort key, in stable submission order,
        capped at max_cohort.  Size 1 == today's time-sliced path."""
        cap = self.max_cohort if self.max_cohort is not None else len(self.sessions)
        if cap <= 1:
            return [primary]
        key = primary.cohort_key()
        now = obs_trace.clock()
        members = [primary]
        for s in self.sessions:
            if len(members) >= cap:
                break
            if s is not primary and s.status == ACTIVE and \
                    s.hold_until <= now and s.cohort_key() == key:
                members.append(s)
        return members

    def step(self) -> SceneSession | None:
        """Run one scheduling quantum: pick a primary session, form its
        train cohort, advance the whole cohort one slice, then reset the
        slot of any member that finished (admitting the next queued job).
        Returns the primary; `last_trained` lists every advanced session.

        With a multi-device placement, one cohort per device advances
        concurrently (see `_step_multi`); the returned primary is the
        lowest slot's."""
        if self.placement is not None and self.placement.n > 1:
            return self._step_multi()
        primary = self.next_session()
        if primary is None:
            self.last_trained = []
            return None
        cohort = self.cohort_for(primary)
        if obs_trace.enabled():
            obs_metrics.gauge("serve3d.cohort_size").set(len(cohort))
        self.last_error = None
        self.last_errors = {}
        err, wall = self._run_cohort(cohort)
        if err is not None:
            self.last_error = err
            self.last_errors = {m.session_id: err for m in cohort}
        else:
            self._watch_stragglers(cohort, wall)
        self._finish_members(cohort)
        self.last_trained = cohort
        return primary

    def _run_cohort(self, cohort: list[SceneSession]) -> tuple:
        """Advance one cohort one slice.  Returns (error, wall_s); with
        ``capture_errors`` the error is parked for the guard — every member
        gets rolled back (donated buffers make partially-advanced state
        untrustworthy), no rider credits, no straggler sample."""
        t0 = obs_trace.clock()
        try:
            if len(cohort) == 1:
                cohort[0].run_slice(self.slice_iters)
            else:
                SceneSession.run_cohort_slice(cohort, self.slice_iters)
                for rider in cohort[1:]:
                    self._credit[rider.session_id] = \
                        self._credit.get(rider.session_id, 0) + 1
        except Exception as e:
            if not self.capture_errors:
                raise
            return e, obs_trace.clock() - t0
        return None, obs_trace.clock() - t0

    def _finish_members(self, trained: list[SceneSession]):
        for s in trained:
            if s.status == DONE:
                self._credit.pop(s.session_id, None)
                if self.max_resident is not None and s.resident:
                    # bounded residency: a finished job must actually release
                    # its device footprint, not just stop counting against the
                    # cap (publish/evaluate still work from the suspended
                    # host tree)
                    s.suspend(block=False)
                if self.placement is not None:
                    # slot load returns to the admission pool; the mapping
                    # itself survives so snapshot render routing keeps
                    # working for the finished scene
                    self.placement.release(s.session_id)
        if any(s.status == DONE for s in trained):
            self._admit()  # slot reset: finished jobs' slots go to the queue

    def _step_multi(self) -> SceneSession | None:
        """The multi-device quantum: admit, pick one primary per mesh slot,
        and advance every slot's cohort concurrently (one driver thread per
        busy device — Python dispatch for one device overlaps XLA execution
        on the others).  Per-session training math is identical to the
        single-device path; only wall-clock interleaving changes, and
        training streams are keyed by absolute step, so results stay
        bit-identical to any sequential schedule of the same slices."""
        self._admit()
        self.last_error = None
        self.last_errors = {}
        now = obs_trace.clock()
        live = [s for s in self.sessions if s.status == ACTIVE]
        ready = [s for s in live if s.hold_until <= now]
        if live and not ready:
            # every active session is in guard backoff: sleep to the
            # earliest release instead of busy-spinning the quantum loop
            time.sleep(max(0.0, min(s.hold_until for s in live) - now))
            now = obs_trace.clock()
            ready = live
        work: list[tuple[int, SceneSession, list[SceneSession]]] = []
        for slot in range(self.placement.n):
            primary = self._select(ready, now, slot=slot)
            if primary is not None:
                work.append((slot, primary, self.cohort_for(primary)))
        if not work:
            self.last_trained = []
            return None
        if obs_trace.enabled():
            obs_metrics.gauge("serve3d.cohort_size").set(
                max(len(c) for _, _, c in work))
            obs_metrics.gauge("serve3d.devices_busy").set(len(work))
        if len(work) == 1:
            outcomes = [self._run_cohort(work[0][2])]
        else:
            outcomes = list(self._ensure_pool().map(
                lambda w: self._run_cohort(w[2]), work))
        trained: list[SceneSession] = []
        for (slot, _primary, cohort), (err, wall) in zip(work, outcomes):
            trained.extend(cohort)
            if err is not None:
                self.last_errors.update({m.session_id: err for m in cohort})
                if self.last_error is None:
                    self.last_error = err
            else:
                self._watch_stragglers(cohort, wall)
        self._finish_members(trained)
        self.last_trained = trained
        return work[0][1]

    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.placement.n,
                thread_name_prefix="serve3d-dev")
        return self._pool

    def _watch_stragglers(self, cohort: list[SceneSession], wall_s: float):
        """Per-session EWMA watchdog over slice wall time (the TrainDriver
        straggler detector, applied per scene).  A flagged session is
        deprioritized one turn via a slice credit — rescheduled, never
        blocked — so one slow scene stops dragging every other session's
        latency without stalling its own progress."""
        dt = wall_s / len(cohort)
        for s in cohort:
            stats = self._straggler.setdefault(s.session_id, StragglerStats())
            if stats.update(dt, self.straggler_sigma, self.straggler_alpha):
                self.stragglers_flagged += 1
                self._credit[s.session_id] = \
                    self._credit.get(s.session_id, 0) + 1
                if obs_trace.enabled():
                    obs_metrics.counter("serve3d.straggler.flagged").inc()
                    obs_trace.instant("serve3d/straggler", cat="serve3d",
                                      args={"session": s.session_id,
                                            "slice_s": dt,
                                            "ewma_s": stats.ewma})
