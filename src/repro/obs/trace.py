"""Host-side trace spans with a Chrome-trace-compatible event buffer.

A span marks a named region of *host* time:

    with trace.span("pipeline/shade"):
        ...                                # context-manager form

    @trace.traced("serve3d/render_drain")
    def drain(self): ...                   # decorator form (checks the knob
                                           # per call, not at decoration)

Events are (name, category, start, duration, thread) tuples appended to a
bounded process-global ring buffer; `repro.obs.export.chrome_trace` turns the
buffer into a ``chrome://tracing`` / Perfetto JSON document.  Spans are
thread-aware (thread id + name ride on every event) and nest freely — the
per-thread depth is recorded so consumers can reconstruct the stack without
timestamp arithmetic.

Everything is gated by one knob: the ``REPRO_OBS`` environment variable at
import time, or `set_enabled(...)` at runtime.  When the knob is off,
``span(...)`` returns one shared no-op object and ``traced`` functions call
straight through — the disabled cost is a single attribute check, budgeted
by ``BENCH_obs_overhead.json`` at < 1% of a training step.

The module's clock (`trace.clock`, a ``time.perf_counter`` alias) is the
single wall-time source for spans AND for the trainer/serve3d history
bookkeeping, so benchmark timings and telemetry can never disagree about
what a second is.

Instrumentation placement contract: spans never touch array values, so
wrapping code that runs under ``jax.jit`` is safe — the span then measures
*trace/compile* time (it executes while jax traces the function) and cached
executions of the compiled function produce no stage spans.  That is exactly
the compile-vs-execute split the trainer reports.  With
``jax_annotations`` on (``REPRO_OBS=jax``), spans also enter a
``jax.profiler.TraceAnnotation`` so host spans line up with XLA device
traces captured via ``jax.profiler.trace``.
"""
from __future__ import annotations

import functools
import os
import threading
import time
from collections import deque
from typing import Any, NamedTuple

#: The one wall-clock for spans, trainer histories, serve3d latencies and
#: benchmark timings.  Alias, not a wrapper: calling it is exactly
#: ``time.perf_counter()``.
clock = time.perf_counter
clock_ns = time.perf_counter_ns


def _env_enabled(val: str | None) -> bool:
    return (val or "").strip().lower() not in ("", "0", "off", "false", "no")


class _State:
    __slots__ = ("enabled", "jax_annotations", "events")


_STATE = _State()
_STATE.enabled = _env_enabled(os.environ.get("REPRO_OBS"))
_STATE.jax_annotations = (os.environ.get("REPRO_OBS", "").strip().lower() == "jax")
# bounded ring buffer: a long-lived service can trace forever without
# growing host memory; deque.append is atomic under the GIL, so concurrent
# render/train threads need no lock on the hot path
_STATE.events = deque(maxlen=int(os.environ.get("REPRO_OBS_BUFFER", 262144)))

_tls = threading.local()


class SpanEvent(NamedTuple):
    name: str
    cat: str
    ts_us: float          # start, microseconds on the perf_counter timeline
    dur_us: float | None  # None => instant event
    tid: int
    thread_name: str
    depth: int            # per-thread nesting depth at entry
    args: dict | None


def enabled() -> bool:
    return _STATE.enabled


def set_enabled(on: bool) -> None:
    _STATE.enabled = bool(on)


def configure(enabled: bool | None = None, jax_annotations: bool | None = None,
              buffer_size: int | None = None) -> None:
    """Runtime overrides for the env-var defaults."""
    if enabled is not None:
        _STATE.enabled = bool(enabled)
    if jax_annotations is not None:
        _STATE.jax_annotations = bool(jax_annotations)
    if buffer_size is not None:
        _STATE.events = deque(_STATE.events, maxlen=int(buffer_size))


def events() -> list[SpanEvent]:
    """Snapshot of the event buffer (oldest first)."""
    return list(_STATE.events)


def clear() -> None:
    _STATE.events.clear()


class _NullSpan:
    """Shared no-op span: the disabled path allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL = _NullSpan()


def _jax_annotation(name: str):
    try:  # pragma: no cover - exercised only with REPRO_OBS=jax
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)
    except Exception:  # jax absent or profiler unavailable: host spans only
        return None


class Span:
    __slots__ = ("name", "cat", "args", "_t0", "_depth", "_ann")

    def __init__(self, name: str, cat: str = "obs", args: dict | None = None):
        self.name = name
        self.cat = cat
        self.args = args
        self._ann = None

    def __enter__(self):
        depth = getattr(_tls, "depth", 0)
        _tls.depth = depth + 1
        self._depth = depth
        if _STATE.jax_annotations:
            self._ann = _jax_annotation(self.name)
            if self._ann is not None:
                self._ann.__enter__()
        self._t0 = clock_ns()
        return self

    def __exit__(self, *exc):
        t1 = clock_ns()
        if self._ann is not None:
            self._ann.__exit__(*exc)
            self._ann = None
        _tls.depth = self._depth
        th = threading.current_thread()
        _STATE.events.append(SpanEvent(
            self.name, self.cat, self._t0 / 1e3, (t1 - self._t0) / 1e3,
            th.ident or 0, th.name, self._depth, self.args,
        ))
        return False


def span(name: str, cat: str = "obs", args: dict | None = None):
    """A context manager timing the wrapped region, or the shared no-op when
    observability is off.  `args` ride into the Chrome-trace event's args
    pane — keep them small, JSON-serializable host values (never jax
    arrays)."""
    if not _STATE.enabled:
        return NULL
    return Span(name, cat, args)


def traced(name: str | None = None, cat: str = "obs"):
    """Decorator form of `span`.  The knob is checked per *call*: decorating
    at import time never freezes a disabled state."""
    def deco(fn):
        label = name if name is not None else fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **k):
            if not _STATE.enabled:
                return fn(*a, **k)
            with Span(label, cat):
                return fn(*a, **k)

        return wrapper

    return deco


def record(name: str, start_s: float, end_s: float, cat: str = "obs",
           args: dict | None = None) -> None:
    """Append a completed span from explicit `clock()` timestamps (seconds).

    For regions whose start/stop cannot bracket a ``with`` block (e.g. a
    span closed in a different control-flow arm than it opened).  Shares the
    perf_counter timeline with `Span`, so recorded and context-managed spans
    interleave correctly in the exported trace.
    """
    if not _STATE.enabled:
        return
    th = threading.current_thread()
    _STATE.events.append(SpanEvent(
        name, cat, start_s * 1e6, max(0.0, (end_s - start_s)) * 1e6,
        th.ident or 0, th.name, getattr(_tls, "depth", 0), args,
    ))


def instant(name: str, cat: str = "obs", args: dict | None = None) -> None:
    """Zero-duration marker event (Chrome-trace phase "i")."""
    if not _STATE.enabled:
        return
    th = threading.current_thread()
    _STATE.events.append(SpanEvent(
        name, cat, clock_ns() / 1e3, None, th.ident or 0, th.name,
        getattr(_tls, "depth", 0), args,
    ))
