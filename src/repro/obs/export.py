"""Exporters: Chrome-trace JSON for spans, JSON snapshots and a terminal
pretty-printer for metrics.

`chrome_trace()` emits the Trace Event Format dict that both
``chrome://tracing`` and Perfetto (https://ui.perfetto.dev) load directly:
complete ("X") events carry ``ts``/``dur`` in microseconds on one
monotonic timeline, instant markers use phase "i", and metadata ("M")
events name the process and every thread that emitted a span.  Schema is
validated in CI by ``tools/check_trace.py``.
"""
from __future__ import annotations

import json
import os

from . import metrics as _metrics
from . import trace as _trace


def chrome_trace(events=None, process_name: str = "repro") -> dict:
    """Render span events into a Chrome Trace Event Format document."""
    evs = _trace.events() if events is None else list(events)
    pid = os.getpid()
    out = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0, "ts": 0,
        "args": {"name": process_name},
    }]
    threads_seen: dict[int, str] = {}
    for e in evs:
        if e.tid not in threads_seen:
            threads_seen[e.tid] = e.thread_name
        rec = {
            "name": e.name,
            "cat": e.cat,
            "pid": pid,
            "tid": e.tid,
            "ts": e.ts_us,
        }
        if e.dur_us is None:
            rec["ph"] = "i"
            rec["s"] = "t"  # thread-scoped instant
        else:
            rec["ph"] = "X"
            rec["dur"] = e.dur_us
        args = dict(e.args) if e.args else {}
        args["depth"] = e.depth
        rec["args"] = args
        out.append(rec)
    for tid, tname in sorted(threads_seen.items()):
        out.append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": tid, "ts": 0,
            "args": {"name": tname},
        })
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def dump_trace(path: str, events=None, process_name: str = "repro") -> str:
    """Write the Chrome-trace JSON to `path`; returns the path."""
    doc = chrome_trace(events, process_name=process_name)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


def metrics_snapshot(extra: dict | None = None) -> dict:
    """Exportable metrics document: the registry snapshot plus optional
    caller context (config, wall time) under ``meta``."""
    return {"meta": dict(extra or {}), "metrics": _metrics.snapshot()}


def dump_metrics(path: str, extra: dict | None = None) -> str:
    with open(path, "w") as f:
        json.dump(metrics_snapshot(extra), f, indent=2, sort_keys=True)
    return path


def _fmt_num(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def format_metrics(doc: dict | None = None, prefix: str = "") -> str:
    """One-line-per-metric terminal rendering of a metrics snapshot.

    Accepts either a raw ``Registry.snapshot()`` dict or the
    `metrics_snapshot()` document; `prefix` filters by name prefix.  This is
    the single rendering path drivers print through, so interactive output
    and the exported JSON always show the same numbers.
    """
    if doc is None:
        doc = _metrics.snapshot()
    snap = doc.get("metrics", doc)
    lines = []
    width = max((len(n) for n in snap if n.startswith(prefix)), default=0)
    for name in sorted(snap):
        if not name.startswith(prefix):
            continue
        m = snap[name]
        kind = m.get("type", "?")
        if kind == "histogram":
            body = (f"count={m['count']} p50={_fmt_num(m['p50'])} "
                    f"p95={_fmt_num(m['p95'])} p99={_fmt_num(m['p99'])} "
                    f"max={_fmt_num(m['max'])}")
        else:
            body = _fmt_num(m.get("value"))
        lines.append(f"  {name:<{width}}  {body}")
    return "\n".join(lines)
