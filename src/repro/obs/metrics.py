"""Typed metrics (Counter / Gauge / Histogram) with a process-global registry.

The registry replaces the loose telemetry scalars that used to live on
trainer/pipeline/serve3d instances (live_fraction, overflow windows,
points_queried, dedup ratios, snapshot publishes, render latencies) with one
named, snapshottable plane:

    from repro.obs import metrics
    metrics.counter("serve3d.snapshots_published").inc()
    metrics.gauge("trainer.live_fraction").set(0.17)
    metrics.histogram("serve3d.render_latency_ms").observe(12.3)

Conventions:

* names are dotted paths, ``subsystem.metric``; per-entity flavors append a
  ``.{entity}`` suffix (``serve3d.render_latency_ms.scene-000``) so the
  snapshot stays a flat, sorted, diff-able dict;
* `Registry.snapshot()` is deterministic: keys sorted, every value a plain
  JSON scalar/dict — two snapshots of the same state are `==` and
  `json.dumps` to the same bytes;
* metric *objects* are always live (they are plain data structures and may
  back existing service telemetry such as `RenderService.latency_stats`);
  the ``REPRO_OBS`` knob gates the *instrumentation call sites*, which guard
  on `trace.enabled()` before touching the global registry.

Histogram quantiles use numpy's default (linear-interpolation) definition
over a bounded recent window, so ``h.quantile(0.95)`` agrees with
``np.quantile(window, 0.95)`` exactly — asserted in tests/test_obs.py.
"""
from __future__ import annotations

import threading
from collections import deque


class Counter:
    """Monotone event count."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self):
        return {"type": self.kind, "value": self.value}


class Gauge:
    """Last-written scalar."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = None

    def set(self, v) -> None:
        self.value = float(v)

    def snapshot(self):
        return {"type": self.kind, "value": self.value}


class Histogram:
    """Windowed value distribution with lifetime count/sum.

    Percentiles are computed over the most recent ``window`` observations
    (bounded memory for long-lived services); count and sum are lifetime.
    """

    __slots__ = ("window", "count", "total")
    kind = "histogram"

    def __init__(self, window: int = 4096):
        self.window = deque(maxlen=int(window))
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.window.append(v)
        self.count += 1
        self.total += v

    def values(self) -> list[float]:
        return list(self.window)

    def quantile(self, q: float) -> float | None:
        """numpy-default (linear) quantile over the recent window."""
        vals = sorted(self.window)
        if not vals:
            return None
        pos = (len(vals) - 1) * float(q)
        lo = int(pos)
        hi = min(lo + 1, len(vals) - 1)
        frac = pos - lo
        return vals[lo] * (1.0 - frac) + vals[hi] * frac

    def snapshot(self):
        return {
            "type": self.kind,
            "count": self.count,
            "sum": self.total,
            "window": len(self.window),
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
            "max": max(self.window) if self.window else None,
        }


class Registry:
    """Named metric store.  Get-or-create accessors are type-checked: a name
    keeps its kind for the registry's lifetime."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(*args)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is a {type(m).kind}, not a {cls.kind}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, window: int = 4096) -> Histogram:
        return self._get(name, Histogram, window)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> dict:
        """Deterministic flat dict: sorted names -> typed JSON-able values."""
        with self._lock:
            return {k: self._metrics[k].snapshot() for k in sorted(self._metrics)}

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


#: The process-global registry every instrumentation site records into.
REGISTRY = Registry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str, window: int = 4096) -> Histogram:
    return REGISTRY.histogram(name, window)


def snapshot() -> dict:
    return REGISTRY.snapshot()
