"""repro.obs — zero-dependency observability: trace spans, typed metrics,
Chrome-trace export.

Three pieces, one knob:

* `repro.obs.trace` — host-side spans (`span` context manager / `traced`
  decorator) collected into a bounded ring buffer, thread-aware, with
  optional ``jax.profiler.TraceAnnotation`` pass-through;
* `repro.obs.metrics` — Counter / Gauge / Histogram behind a process-global
  `Registry` with deterministic JSON snapshots;
* `repro.obs.export` — ``chrome://tracing`` / Perfetto JSON for spans,
  metrics JSON dumps, and the terminal pretty-printer drivers share.

The ``REPRO_OBS`` environment variable (or `set_enabled`/`configure` at
runtime) gates every instrumentation site in the repo.  Off (the default),
spans are shared no-op objects and instrumented hot loops skip the metrics
plumbing entirely — overhead is budgeted at < 1% of a training step by
``BENCH_obs_overhead.json`` and all bit-identity gates are untouched
(instrumentation never runs *inside* compiled code: spans wrapping jitted
regions execute at trace time, which is exactly the compile/execute split
the trainer reports).

See docs/OBSERVABILITY.md for the span taxonomy and metric name registry.
"""
from __future__ import annotations

from . import export, metrics, trace
from .metrics import REGISTRY, Counter, Gauge, Histogram, Registry
from .trace import clock, configure, enabled, instant, set_enabled, span, traced

__all__ = [
    "export", "metrics", "trace",
    "REGISTRY", "Counter", "Gauge", "Histogram", "Registry",
    "clock", "configure", "enabled", "instant", "set_enabled", "span",
    "traced",
]


def reset() -> None:
    """Clear the span buffer and the metrics registry (test isolation)."""
    trace.clear()
    REGISTRY.reset()
