"""repro.testing — test-only runtime hooks (fault injection).

Nothing in this package is imported by production code paths unless the
corresponding knob is on; see `repro.testing.faults` for the contract."""
from . import faults  # noqa: F401
