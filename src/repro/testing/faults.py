"""Deterministic fault injection for chaos tests and the CI chaos-smoke leg.

The harness mirrors the `repro.obs` knob pattern: one process-global state
object, gated by the ``REPRO_FAULTS`` environment variable at import time or
`configure(enabled=...)` at runtime.  When the knob is off, every
instrumented call site pays exactly one attribute check (`check` returns
``None`` immediately) — production paths are zero-overhead and, because
faults only ever *perturb* state at slice/publish/write boundaries outside
jitted code, every bit-identity gate in the repo holds with the harness
compiled in.

Usage (a chaos test or benchmarks/bench_robustness.py):

    from repro.testing import faults

    faults.configure(enabled=True)
    faults.inject("serve3d.slice", "nan_params", session="scene-001",
                  at_step=24, times=1)
    faults.inject("serve3d.render_group", "render_fail", times=1)
    ...run the service...
    assert faults.fired_count("nan_params") == 1
    faults.reset()

Sites are dotted names owned by the instrumented module; each call site
passes its context (session id, step, ...) and interprets the returned
`Injection`'s ``kind``:

======================  =====================================================
site                    kinds understood by the call site
======================  =====================================================
``serve3d.slice``       ``nan_params`` (poison the session's params with
                        NaN after the slice — the observable end state of a
                        diverged/NaN-gradient step), ``inf_params``,
                        ``nan_loss`` (poison the reported loss only),
                        ``loss_spike`` (multiply the reported loss by
                        ``factor``, default 1e6 — drives the PSNR-collapse
                        heuristic), ``exception`` (raise `InjectedFault`
                        before training), ``slow`` (sleep ``seconds``,
                        default 0.25 — a straggler slice)
``serve3d.snapshot_publish``  ``snapshot_fail`` (raise before the atomic
                        swap — the previous snapshot must be retained)
``serve3d.render_group``      ``render_fail`` (raise inside the batched
                        render — requests must be retried, then error out)
``checkpoint.write``    ``kill_mid_write`` (raise after the array file is
                        written but before the atomic rename — a torn
                        write), ``corrupt`` (flip bytes in the committed
                        array file — bit-rot the checksum must catch)
======================  =====================================================

Matching is deterministic: an injection fires when the site matches, every
``match`` key equals the call's context, the first ``skip`` matching calls
have passed, and fewer than ``times`` firings have happened.  ``at_step``
is sugar for ``match={"step": ...}`` and matches when the context step is
>= the requested step (slice boundaries rarely land exactly on a step), but
still at most ``times`` times.  Every firing is recorded (site, kind, ctx)
for assertions, and mirrored to the obs metrics registry
(``faults.fired.{kind}``) when observability is on.
"""
from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field as dc_field
from typing import Any

from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace


def _env_enabled(val: str | None) -> bool:
    return (val or "").strip().lower() not in ("", "0", "off", "false", "no")


class _State:
    __slots__ = ("enabled", "plan", "fired", "lock")


_STATE = _State()
_STATE.enabled = _env_enabled(os.environ.get("REPRO_FAULTS"))
_STATE.plan = []
_STATE.fired = []
_STATE.lock = threading.Lock()


class InjectedFault(RuntimeError):
    """Raised by call sites executing an ``exception``-style injection."""


@dataclass
class Injection:
    site: str
    kind: str
    match: dict = dc_field(default_factory=dict)
    at_step: int | None = None
    skip: int = 0                 # matching calls to let pass before firing
    times: int | None = 1         # max firings (None = unbounded)
    params: dict = dc_field(default_factory=dict)
    seen: int = 0                 # matching calls observed
    count: int = 0                # firings so far

    def matches(self, ctx: dict) -> bool:
        for k, v in self.match.items():
            if ctx.get(k) != v:
                return False
        if self.at_step is not None:
            step = ctx.get("step")
            if step is None or step < self.at_step:
                return False
        return True


def enabled() -> bool:
    return _STATE.enabled


def configure(enabled: bool | None = None) -> None:
    """Runtime override for the ``REPRO_FAULTS`` env default."""
    if enabled is not None:
        _STATE.enabled = bool(enabled)


def inject(site: str, kind: str, *, at_step: int | None = None, skip: int = 0,
           times: int | None = 1, **match_and_params) -> Injection:
    """Arm an injection.  Keyword args that name call-site context keys
    (``session``, ``step``, ``member``) become match predicates; the rest
    ride along as ``params`` for the call site (``seconds``, ``factor``).
    Arming an injection enables the harness."""
    match_keys = {"session", "step", "member", "request"}
    match = {k: v for k, v in match_and_params.items() if k in match_keys}
    params = {k: v for k, v in match_and_params.items() if k not in match_keys}
    inj = Injection(site=site, kind=kind, match=match, at_step=at_step,
                    skip=int(skip), times=times, params=params)
    with _STATE.lock:
        _STATE.plan.append(inj)
    _STATE.enabled = True
    return inj


def reset() -> None:
    """Clear the plan and the firing log (leaves the knob as-is)."""
    with _STATE.lock:
        _STATE.plan = []
        _STATE.fired = []


def check(site: str, **ctx: Any) -> Injection | None:
    """The instrumented-call-site entry point: the first armed injection
    matching (site, ctx), else None.  One attribute check when disabled."""
    if not _STATE.enabled:
        return None
    with _STATE.lock:
        for inj in _STATE.plan:
            if inj.site != site or not inj.matches(ctx):
                continue
            inj.seen += 1
            if inj.seen <= inj.skip:
                continue
            if inj.times is not None and inj.count >= inj.times:
                continue
            inj.count += 1
            _STATE.fired.append({"site": site, "kind": inj.kind, **ctx})
            if obs_trace.enabled():
                obs_metrics.counter(f"faults.fired.{inj.kind}").inc()
                obs_trace.instant(f"faults/{inj.kind}", cat="faults",
                                  args={"site": site})
            return inj
    return None


def fired() -> list[dict]:
    """Firing log (site, kind, call context), oldest first."""
    with _STATE.lock:
        return list(_STATE.fired)


def fired_count(kind: str | None = None) -> int:
    with _STATE.lock:
        if kind is None:
            return len(_STATE.fired)
        return sum(1 for f in _STATE.fired if f["kind"] == kind)


# ---- state poisoners (fault path only — never imported into hot loops) ----


def poison_tree(tree, value: float):
    """Every inexact leaf becomes `value` (NaN/Inf) — the end state of a
    diverged training step, injected at a slice boundary."""
    import jax
    import jax.numpy as jnp

    def p(leaf):
        x = jnp.asarray(leaf)
        if jnp.issubdtype(x.dtype, jnp.inexact):
            return jnp.full_like(x, value)
        return x

    return jax.tree.map(p, tree)


def corrupt_file(path, n_bytes: int = 64, offset: int = 0) -> None:
    """Flip `n_bytes` bytes of the file in place (bit-rot simulation)."""
    with open(path, "r+b") as f:
        f.seek(offset)
        chunk = f.read(n_bytes)
        f.seek(offset)
        f.write(bytes(b ^ 0xFF for b in chunk))
