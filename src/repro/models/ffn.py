"""Feed-forward blocks: SwiGLU (llama-family) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers


def init_ffn(rng, d_model: int, d_ff: int, act: str, dtype) -> dict:
    ks = jax.random.split(rng, 3)
    if act == "swiglu":
        return {
            "w_gate": layers.normal_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_up": layers.normal_init(ks[1], (d_model, d_ff), dtype=dtype),
            "w_down": layers.normal_init(ks[2], (d_ff, d_model), dtype=dtype),
        }
    return {
        "w_up": layers.normal_init(ks[0], (d_model, d_ff), dtype=dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": layers.normal_init(ks[1], (d_ff, d_model), dtype=dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def ffn(params: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        g = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
        u = (x @ params["w_up"]).astype(jnp.float32)
        return ((g * u).astype(x.dtype)) @ params["w_down"]
    h = jax.nn.gelu((x @ params["w_up"] + params["b_up"]).astype(jnp.float32))
    return h.astype(x.dtype) @ params["w_down"] + params["b_down"]
