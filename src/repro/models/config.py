"""Model configuration dataclasses for the architecture zoo."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_expert_ff: int
    n_dense_layers: int = 0          # leading layers with a dense FFN
    dense_d_ff: int = 0              # their hidden size (0 = use model d_ff)
    score: str = "softmax"           # softmax | sigmoid (deepseek-v3)
    route_scale: float = 1.0
    ep_axis: Optional[str] = "model" # expert-parallel mesh axis (None = dense path)
    # EP may span multiple mesh axes (deepseek-v3: ('data','model') = 256-way,
    # one expert per device — kills the FSDP all-gather of expert weights)
    ep_axes: tuple = ("model",)


@dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 0                  # 0 => direct q projection (v2-lite)
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba1"             # mamba1 | mamba2
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64                # mamba2 head dim
    dt_rank: int = 0                 # mamba1: 0 => d_model // 16
    chunk: int = 128                 # chunked-scan block length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 => d_model // n_heads
    # attention flavor
    qkv_bias: bool = False
    qk_norm: bool = False
    rope: str = "standard"           # standard | rope2d | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    # block flavor
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0       # zamba2: shared attn block every k layers
    mtp_depth: int = 0               # deepseek-v3 multi-token prediction heads
    # encoder-decoder
    enc_dec: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper audio frames after conv stub
    frontend: str = "none"           # none | audio_stub | vision_stub
    # numerics / training
    dtype: str = "bfloat16"
    remat: bool = True
    # BUM-merged vocab-embedding gradients.  Off by default for LMs: the
    # global sort in the merge must see every token's update, which under
    # data parallelism all-gathers (tokens x d_model) f32 — measured +41 GiB
    # temp on chatglm3 train_4k (§Perf iteration 3, refuted hypothesis).
    # The merge stays on for the paper's own hash grids (F=2 features, huge
    # duplication, single-host windows) where it is the right trade.
    dedup_embed_grad: bool = False
    # python-loop the layer stack instead of lax.scan; used by the dry-run's
    # per-layer cost probes (XLA cost analysis counts a while body once)
    unroll_layers: bool = False
    # which shape suites apply (assignment rules)
    supports_long_context: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D)."""
        from . import counting
        return counting.param_count(self)

    def active_param_count(self) -> int:
        from . import counting
        return counting.active_param_count(self)
