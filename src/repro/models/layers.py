"""Shared LM layers: norms, embeddings (with BUM-merged grads), RoPE variants.

The Embedding's `dedup_grad` option is the paper's technique transferred to
LMs (DESIGN.md §5): a vocab table's backward is a scatter-add with massive
index duplication (every repeated token), exactly the access pattern the BUM
merges — we route it through kernels.grid_update.merged_scatter_add.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.grid_update import ops as gu_ops


# --- init helpers ------------------------------------------------------------

def normal_init(rng, shape, std=0.02, dtype=jnp.float32):
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


# --- norms -------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# --- embedding with optional BUM-merged gradient ------------------------------

def make_embed_lookup(dedup_grad: str | bool = "naive"):
    """Returns lookup(table (V,D), ids (...,)) -> (..., D) with custom VJP.

    dedup_grad: 'naive' (XLA scatter — best under data parallelism, see
    EXPERIMENTS.md §Perf iteration 3), 'merged' (global BUM sort-merge —
    wins for small-F tables like the hash grids), or 'windowed' (the
    paper-faithful sliding-window merge: bounded live set per shard).
    """
    if dedup_grad is True:
        dedup_grad = "merged"
    if dedup_grad is False:
        dedup_grad = "naive"

    @jax.custom_vjp
    def lookup(table, ids):
        return table[ids]

    def fwd(table, ids):
        return table[ids], (ids, table.shape[0], jnp.zeros((0,), table.dtype))

    def bwd(res, g):
        ids, vocab, proto = res
        flat_ids = ids.reshape(-1).astype(jnp.int32)
        flat_g = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
        zero = jnp.zeros((vocab, g.shape[-1]), jnp.float32)
        if dedup_grad == "merged":
            gt = gu_ops.merged_scatter_add(zero, flat_ids, flat_g)
        elif dedup_grad == "windowed":
            gt = gu_ops.windowed_scatter_add(zero, flat_ids, flat_g)
        else:
            gt = zero.at[flat_ids].add(flat_g)
        return gt.astype(proto.dtype), None

    lookup.defvjp(fwd, bwd)
    return lookup


embed_lookup_merged = make_embed_lookup("merged")
embed_lookup_windowed = make_embed_lookup("windowed")
embed_lookup_naive = make_embed_lookup("naive")


# --- RoPE variants -------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Standard interleaved-as-halves RoPE (llama convention).

    x: (..., S, H, hd); positions: broadcastable to (..., S).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (..., S, 1, hd/2)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def apply_rope_2d(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """ChatGLM-style 2D RoPE: rotary on the first half of head_dim only."""
    hd = x.shape[-1]
    rot, keep = x[..., : hd // 2], x[..., hd // 2 :]
    rot = apply_rope(rot, positions, theta)
    return jnp.concatenate([rot, keep], axis=-1)


def apply_mrope(
    x: jnp.ndarray, positions_3d: jnp.ndarray, sections=(16, 24, 24), theta: float = 1000000.0
) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: head_dim/2 frequency slots are split into (t, h, w)
    sections, each rotated by its own position stream.

    x: (B, S, H, hd); positions_3d: (3, B, S) — temporal, height, width.
    `sections` counts are in half-dim units and must sum to hd/2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    # build a per-slot position by section
    splits = []
    start = 0
    for axis, count in enumerate(sections):
        pos = positions_3d[axis]  # (B, S)
        ang = pos[..., None].astype(jnp.float32) * freqs[start : start + count]
        splits.append(ang)
        start += count
    ang = jnp.concatenate(splits, axis=-1)  # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
