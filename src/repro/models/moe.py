"""Mixture-of-Experts: router + shared experts + two execution paths.

* `dense` — every expert runs on every token, gates combine.  Used by smoke
  tests and tiny configs; also the numerical oracle for the EP path.
* `ep` — production expert parallelism: tokens are sharded over the 'model'
  mesh axis inside a shard_map, routed, exchanged with all_to_all to their
  expert-owner shards (DeepSeek-style EP), processed by a capacity-bounded
  grouped matmul (scan over local experts), and returned by a second
  all_to_all.  Token order, gates and drops are tracked explicitly.
* decode (S == 1): tokens replicated over 'model'; each shard computes only
  its local experts' contributions and a psum combines — the right trade for
  a few tokens where dispatch overhead would dominate.

Shared experts are algebraically fused into a single FFN of width
n_shared * d_expert_ff (sum of parallel SwiGLUs == one wider SwiGLU).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers
from .ffn import init_ffn, ffn
from .config import ModelConfig, MoEConfig


# --- params -------------------------------------------------------------------

def init_moe(rng, cfg: ModelConfig, dtype) -> dict:
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(rng, 6)
    p = {
        "router": layers.normal_init(ks[0], (d, m.n_routed), dtype=jnp.float32),
        "router_bias": jnp.zeros((m.n_routed,), jnp.float32),  # v3 balance bias
        "w_gate": layers.normal_init(ks[1], (m.n_routed, d, m.d_expert_ff), dtype=dtype),
        "w_up": layers.normal_init(ks[2], (m.n_routed, d, m.d_expert_ff), dtype=dtype),
        "w_down": layers.normal_init(ks[3], (m.n_routed, m.d_expert_ff, d), dtype=dtype),
    }
    if m.n_shared:
        p["shared"] = init_ffn(ks[4], d, m.n_shared * m.d_expert_ff, "swiglu", dtype)
    return p


# --- routing ------------------------------------------------------------------

def route(params, x_flat, m: MoEConfig):
    """x_flat (N, D) -> (gates (N, k) f32, expert_ids (N, k) i32)."""
    logits = (x_flat.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    if m.score == "sigmoid":  # deepseek-v3: sigmoid scores + selection bias
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["router_bias"]
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        sel = scores
    _, ids = jax.lax.top_k(sel, m.top_k)
    gates = jnp.take_along_axis(scores, ids, axis=-1)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9) * m.route_scale
    return gates, ids.astype(jnp.int32)


def _expert_ffn(w_gate, w_up, w_down, x):
    g = jax.nn.silu((x @ w_gate).astype(jnp.float32))
    u = (x @ w_up).astype(jnp.float32)
    return ((g * u).astype(x.dtype)) @ w_down


# --- dense path (oracle / smoke) -----------------------------------------------

def moe_dense(params, x, cfg: ModelConfig):
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    gates, ids = route(params, xf, m)
    outs = jax.vmap(lambda wg, wu, wd: _expert_ffn(wg, wu, wd, xf))(
        params["w_gate"], params["w_up"], params["w_down"]
    )  # (E, N, D)
    onehot = jax.nn.one_hot(ids, m.n_routed, dtype=jnp.float32)  # (N, k, E)
    combine = jnp.einsum("nk,nke->ne", gates, onehot)  # (N, E)
    y = jnp.einsum("ne,end->nd", combine.astype(outs.dtype), outs)
    y = y.reshape(b, s, d)
    if m.n_shared:
        y = y + ffn(params["shared"], x, "swiglu")
    return y


# --- EP path -------------------------------------------------------------------

def _group_pack(sort_key, n_groups: int, capacity: int):
    """Given integer group keys (A,), compute a stable grouped layout.

    Returns (order (A,), group (A,) sorted keys, slot (A,) rank within group,
    counts (n_groups,)).  Entries with slot >= capacity must be dropped by
    the caller.
    """
    a = sort_key.shape[0]
    order = jnp.argsort(sort_key, stable=True)
    sorted_key = sort_key[order]
    counts = jnp.bincount(sort_key, length=n_groups)
    starts = jnp.cumsum(counts) - counts  # (n_groups,)
    slot = jnp.arange(a, dtype=jnp.int32) - starts[sorted_key]
    return order, sorted_key, slot, counts


def _local_grouped_ffn(params_local, x_sorted, e_sorted, n_local: int, capacity: int):
    """Scan over local experts; each takes a capacity-window dynamic slice.

    x_sorted (M, D) sorted by e_sorted (M,) in [0, n_local] (n_local = invalid
    sentinel sorted last).  Returns y (M, D) aligned with x_sorted.  Tokens
    beyond an expert's capacity window are dropped (standard MoE behaviour).

    The buffer is padded with `capacity` zero rows so a group start near the
    end never needs clamping (clamping would desynchronize the keep mask).
    """
    m_tot, d = x_sorted.shape
    counts = jnp.bincount(e_sorted, length=n_local + 1)[:n_local]
    starts = jnp.cumsum(counts) - counts
    x_pad = jnp.concatenate([x_sorted, jnp.zeros((capacity, d), x_sorted.dtype)])

    def body(y, inp):
        wg, wu, wd, start, count = inp
        seg = jax.lax.dynamic_slice_in_dim(x_pad, start, capacity, axis=0)
        out = _expert_ffn(wg, wu, wd, seg)
        keep = (jnp.arange(capacity, dtype=jnp.int32) < count)[:, None]
        out = jnp.where(keep, out, 0)
        prev = jax.lax.dynamic_slice_in_dim(y, start, capacity, axis=0)
        y = jax.lax.dynamic_update_slice_in_dim(y, prev + out, start, axis=0)
        return y, None

    y0 = jnp.zeros((m_tot + capacity, d), x_sorted.dtype)
    # python-unrolled expert loop (not lax.scan): the per-expert matmuls
    # pipeline better on the MXU, and XLA's cost analysis counts a while
    # body once — unrolling keeps the dry-run roofline exact
    y = y0
    for le in range(n_local):
        y, _ = body(y, (params_local["w_gate"][le], params_local["w_up"][le],
                        params_local["w_down"][le], starts[le], counts[le]))
    return y[:m_tot]


def _moe_ep_local(params, x, m: MoEConfig, n_model: int, capacity_factor: float,
                  axis_name="model"):
    """Per-shard body (inside shard_map). x (b_loc, s_loc, d).
    axis_name may be a tuple of mesh axes (multi-axis EP)."""
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    gates, ids = route(params, xf, m)  # (n, k)
    k = m.top_k
    e_loc_count = m.n_routed // n_model

    a = n * k
    e_flat = ids.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    owner = e_flat // e_loc_count  # destination shard

    cap = int(math.ceil(a / n_model * capacity_factor))
    order, sorted_owner, slot, _ = _group_pack(owner, n_model, cap)
    valid = slot < cap

    # scatter into (n_model, cap) send buffers; slot >= cap rows drop (mode)
    send_x = jnp.zeros((n_model, cap, d), x.dtype)
    send_e = jnp.full((n_model, cap), e_loc_count, jnp.int32)  # sentinel = invalid
    send_x = send_x.at[sorted_owner, slot].set(xf[tok_idx[order]], mode="drop")
    send_e = send_e.at[sorted_owner, slot].set(e_flat[order] % e_loc_count, mode="drop")

    # exchange: recv[j] = what shard j sent to me
    recv_x = jax.lax.all_to_all(send_x, axis_name, split_axis=0, concat_axis=0, tiled=True)
    recv_e = jax.lax.all_to_all(send_e, axis_name, split_axis=0, concat_axis=0, tiled=True)

    mt = n_model * cap
    rx = recv_x.reshape(mt, d)
    re = recv_e.reshape(mt)
    cap2 = int(math.ceil(mt / max(e_loc_count, 1) * capacity_factor))
    cap2 = min(cap2, mt)
    order2, sorted_e, slot2, _ = _group_pack(re, e_loc_count + 1, mt)
    x_sorted = rx[order2]
    y_sorted = _local_grouped_ffn(params, x_sorted, sorted_e, e_loc_count, cap2)
    # unsort back to recv layout
    y_flat = jnp.zeros_like(rx).at[order2].set(y_sorted)
    y_back = jax.lax.all_to_all(
        y_flat.reshape(n_model, cap, d), axis_name, split_axis=0, concat_axis=0, tiled=True
    )

    # gather each assignment's result and combine with gates
    res = y_back[sorted_owner, jnp.minimum(slot, cap - 1)]  # aligned with `order`
    res = jnp.where(valid[:, None], res, 0)
    y_assign = jnp.zeros((a, d), x.dtype).at[order].set(res)
    y_tok = (y_assign.reshape(n, k, d) * gates[..., None].astype(x.dtype)).sum(axis=1)
    return y_tok.reshape(b, s, d)


def moe_ep(params, x, cfg: ModelConfig, mesh, dp_axes=("pod", "data"), capacity_factor: float = 1.3):
    """Expert-parallel MoE. x (B, S, D) -> (B, S, D).

    EP may span multiple mesh axes (cfg.moe.ep_axes): deepseek-v3 uses
    ('data','model') = 256-way, one expert per device, so expert weights are
    never all-gathered and their grads never cross-reduced."""
    m = cfg.moe
    ep_axes = tuple(a for a in m.ep_axes if a in mesh.shape)
    n_ep = 1
    for a in ep_axes:
        n_ep *= mesh.shape[a]
    axis_name = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    e_spec = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    # x layout inside the shard_map: batch over the remaining dp axes, seq
    # over 'model'.  A mesh axis may serve batch AND expert ownership at
    # once (deepseek-v3: 'data' shards batch for x and the expert dim for
    # weights; the all_to_all over ('data','model') moves tokens across
    # both) — that's what makes 256-way EP free of weight gathers.
    batch_ax = tuple(a for a in dp_axes if a in mesh.shape and a != "model")
    n_seq = mesh.shape.get("model", 1)

    expert_specs = {"router": P(), "router_bias": P(),
                    "w_gate": P(e_spec, None, None), "w_up": P(e_spec, None, None),
                    "w_down": P(e_spec, None, None)}
    routed = {k: params[k] for k in expert_specs}

    if x.shape[1] == 1:  # decode: local-dense + psum over the EP axes
        fn = jax.shard_map(
            partial(_moe_decode_local, m=m, n_model=n_ep, axis_name=axis_name),
            mesh=mesh, in_specs=(expert_specs, P(batch_ax or None, None, None)),
            out_specs=P(batch_ax or None, None, None), check_vma=False,
        )
        y = fn(routed, x)
    else:
        s = x.shape[1]
        pad = (-s) % n_seq  # seq splits over 'model' for dispatch
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
        fn = jax.shard_map(
            partial(_moe_ep_local, m=m, n_model=n_ep, capacity_factor=capacity_factor,
                    axis_name=axis_name),
            mesh=mesh, in_specs=(expert_specs, P(batch_ax or None, "model", None)),
            out_specs=P(batch_ax or None, "model", None), check_vma=False,
        )
        y = fn(routed, xp)
        if pad:
            y = y[:, :s]

    if m.n_shared:
        y = y + ffn(params["shared"], x, "swiglu")
    return y


def _moe_decode_local(params, x, m: MoEConfig, n_model: int, axis_name="model"):
    """Decode-path shard body: all local experts on all (few) tokens, psum."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    gates, ids = route(params, xf, m)  # routing is replicated (same result on all shards)
    e_loc_count = m.n_routed // n_model
    my = jax.lax.axis_index(axis_name)
    lo = my * e_loc_count
    outs = jax.vmap(lambda wg, wu, wd: _expert_ffn(wg, wu, wd, xf))(
        params["w_gate"], params["w_up"], params["w_down"]
    )  # (E_loc, N, D)
    onehot = jax.nn.one_hot(ids - lo, e_loc_count, dtype=jnp.float32)  # (N, k, E_loc)
    combine = jnp.einsum("nk,nke->ne", gates, onehot)
    y = jnp.einsum("ne,end->nd", combine.astype(outs.dtype), outs)
    y = jax.lax.psum(y, axis_name)
    return y.reshape(b, s, d)


def moe_layer(params, x, cfg: ModelConfig, mesh=None):
    """Entry point: picks dense vs EP by config + mesh availability."""
    m = cfg.moe
    if m.ep_axis is None or mesh is None:
        return moe_dense(params, x, cfg)
    ep_axes = tuple(a for a in m.ep_axes if a in mesh.shape)
    n_ep = 1
    for a in ep_axes:
        n_ep *= mesh.shape[a]
    if n_ep == 1 or m.n_routed % n_ep != 0:
        return moe_dense(params, x, cfg)
    return moe_ep(params, x, cfg, mesh)
