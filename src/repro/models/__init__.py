from .config import ModelConfig, MoEConfig, MLAConfig, SSMConfig  # noqa: F401
from .lm import LM  # noqa: F401
from . import attention, ffn, layers, moe, ssm, transformer, counting  # noqa: F401
