"""State-space blocks: Mamba-1 (falcon-mamba) and Mamba-2/SSD (zamba2).

TPU adaptation notes (DESIGN.md §3): the CUDA selective-scan kernel does not
port; instead both variants use *chunked* formulations that keep the live
state B x d_inner x d_state instead of materializing it for every timestep:

* mamba1: lax.scan over chunks, associative_scan (Blelloch) within a chunk —
  O(S/Q) sequential steps, VMEM-sized intermediates.
* mamba2: the SSD block-matrix form — intra-chunk attention-like matmuls
  (MXU-friendly) + inter-chunk state recurrence.

Decode keeps O(1) recurrent state: (conv tail, ssm state) per layer — this is
why the long_500k suite runs for the SSM/hybrid archs only.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig, SSMConfig


def _dt_rank(cfg: ModelConfig) -> int:
    s = cfg.ssm
    return s.dt_rank or max(cfg.d_model // 16, 1)


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


# --- params -------------------------------------------------------------------

def init_ssm(rng, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    d, di, n = cfg.d_model, d_inner(cfg), s.d_state
    ks = jax.random.split(rng, 10)
    if s.kind == "mamba1":
        r = _dt_rank(cfg)
        return {
            "in_proj": layers.normal_init(ks[0], (d, 2 * di), dtype=dtype),
            "conv_w": layers.normal_init(ks[1], (s.d_conv, di), std=0.2, dtype=dtype),
            "conv_b": jnp.zeros((di,), dtype),
            "x_proj": layers.normal_init(ks[2], (di, r + 2 * n), dtype=dtype),
            "dt_proj": layers.normal_init(ks[3], (r, di), std=r**-0.5, dtype=dtype),
            "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))),
            "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
            "D": jnp.ones((di,), jnp.float32),
            "out_proj": layers.normal_init(ks[4], (di, d), dtype=dtype),
        }
    # mamba2: heads of size headdim, scalar A per head, B/C shared (1 group)
    p_heads = di // s.headdim
    conv_ch = di + 2 * n  # conv over x, B, C
    return {
        "in_proj": layers.normal_init(ks[0], (d, 2 * di + 2 * n + p_heads), dtype=dtype),
        "conv_w": layers.normal_init(ks[1], (s.d_conv, conv_ch), std=0.2, dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((p_heads,), 0.01, jnp.float32))),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, p_heads, dtype=jnp.float32)),
        "D": jnp.ones((p_heads,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": layers.normal_init(ks[2], (di, d), dtype=dtype),
    }


# --- causal depthwise conv ------------------------------------------------------

def causal_conv(x, w, b, tail=None):
    """x (B,S,C), w (K,C), b (C,). tail: (B,K-1,C) state from previous tokens.
    Returns (y (B,S,C), new_tail)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k)) + b
    new_tail = xp[:, -(k - 1) :, :] if k > 1 else tail
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_tail


# --- mamba1 ---------------------------------------------------------------------

class SSMState(NamedTuple):
    h: jnp.ndarray       # mamba1: (B, di, n); mamba2: (B, P, hd, n)
    conv_tail: jnp.ndarray


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    s = cfg.ssm
    di = d_inner(cfg)
    if s.kind == "mamba1":
        return SSMState(
            jnp.zeros((batch, di, s.d_state), jnp.float32),
            jnp.zeros((batch, s.d_conv - 1, di), dtype),
        )
    p = di // s.headdim
    return SSMState(
        jnp.zeros((batch, p, s.headdim, s.d_state), jnp.float32),
        jnp.zeros((batch, s.d_conv - 1, di + 2 * s.d_state), dtype),
    )


def _mamba1_scan_chunk(h0, a, bx):
    """h0 (B,d,n); a, bx (B,Q,d,n).  Returns (h (B,Q,d,n), h_end)."""
    def combine(l, r):
        return (l[0] * r[0], r[0] * l[1] + r[1])
    aa, bb = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = bb + aa * h0[:, None]
    return h, h[:, -1]


def mamba1(params, cfg: ModelConfig, x, state: SSMState | None = None):
    """x (B,S,D) -> (y (B,S,D), new_state).  Chunked selective scan."""
    s = cfg.ssm
    b, seq, _ = x.shape
    di, n, r = d_inner(cfg), s.d_state, _dt_rank(cfg)
    xz = x @ params["in_proj"]
    xs, z = xz[..., :di], xz[..., di:]
    tail = state.conv_tail if state is not None else None
    xs, new_tail = causal_conv(xs, params["conv_w"], params["conv_b"], tail)

    dbc = xs @ params["x_proj"]  # (B,S,r+2n)
    dt = jax.nn.softplus(
        (dbc[..., :r] @ params["dt_proj"]).astype(jnp.float32) + params["dt_bias"]
    )  # (B,S,di)
    bmat = dbc[..., r : r + n].astype(jnp.float32)   # (B,S,n)
    cmat = dbc[..., r + n :].astype(jnp.float32)     # (B,S,n)
    a_cont = -jnp.exp(params["A_log"])               # (di,n)

    q = min(s.chunk, seq)
    n_chunks, rem = divmod(seq, q)
    main = n_chunks * q
    h0 = state.h if state is not None else jnp.zeros((b, di, n), jnp.float32)

    def chunk_body(h, inp):
        dt_q, b_q, c_q, x_q = inp  # (B,Q,di) (B,Q,n) (B,Q,n) (B,Q,di)
        a = jnp.exp(dt_q[..., None] * a_cont)                    # (B,Q,di,n)
        bx = (dt_q * x_q)[..., None] * b_q[:, :, None, :]        # (B,Q,di,n)
        hs, h_end = _mamba1_scan_chunk(h, a, bx)
        y = jnp.einsum("bqdn,bqn->bqd", hs, c_q)
        return h_end, y

    xf32 = xs.astype(jnp.float32)
    ch = lambda t: t[:, :main].reshape(b, n_chunks, q, *t.shape[2:]).swapaxes(0, 1)
    h_end, ys = jax.lax.scan(chunk_body, h0, (ch(dt), ch(bmat), ch(cmat), ch(xf32)))
    y = ys.swapaxes(0, 1).reshape(b, main, di)
    if rem:  # remainder chunk (seq not a multiple of the chunk length)
        h_end, y_rem = chunk_body(
            h_end, (dt[:, main:], bmat[:, main:], cmat[:, main:], xf32[:, main:])
        )
        y = jnp.concatenate([y, y_rem], axis=1)
    y = y + params["D"] * xs.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"]
    return out, SSMState(h_end, new_tail)


def mamba1_decode(params, cfg: ModelConfig, x, state: SSMState):
    """Single-token recurrent step. x (B,1,D)."""
    y, new_state = mamba1(params, cfg, x, state)
    return y, new_state


# --- mamba2 (SSD) ---------------------------------------------------------------

def mamba2(params, cfg: ModelConfig, x, state: SSMState | None = None):
    """Chunked SSD. x (B,S,D) -> (y, new_state)."""
    s = cfg.ssm
    b, seq, _ = x.shape
    di, n, hd = d_inner(cfg), s.d_state, s.headdim
    p = di // hd
    proj = x @ params["in_proj"]  # (B,S, 2di+2n+P)
    z, xbc, dt_raw = proj[..., :di], proj[..., di : di + di + 2 * n], proj[..., -p:]
    tail = state.conv_tail if state is not None else None
    xbc, new_tail = causal_conv(xbc, params["conv_w"], params["conv_b"], tail)
    xs = xbc[..., :di]
    bmat = xbc[..., di : di + n].astype(jnp.float32)  # (B,S,n)
    cmat = xbc[..., di + n :].astype(jnp.float32)     # (B,S,n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,P)
    a_head = -jnp.exp(params["A_log"])  # (P,)
    dta = dt * a_head                   # (B,S,P) log-decay per step

    q = min(s.chunk, seq)
    n_chunks, rem = divmod(seq, q)
    main = n_chunks * q
    xh = xs.astype(jnp.float32).reshape(b, seq, p, hd)
    h0 = state.h if state is not None else jnp.zeros((b, p, hd, n), jnp.float32)

    def chunk_body(h, inp):
        dt_q, dta_q, b_q, c_q, x_q = inp  # (B,Q,P) (B,Q,P) (B,Q,n) (B,Q,n) (B,Q,P,hd)
        qq = dt_q.shape[1]
        cum = jnp.cumsum(dta_q, axis=1)  # (B,Q,P)
        # intra-chunk: Y_ij = C_i.B_j * exp(cum_i - cum_j) * dt_j  (i >= j)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,Q,Q,P)
        tri = jnp.tril(jnp.ones((qq, qq), bool))
        cb = jnp.einsum("bin,bjn->bij", c_q, b_q)  # (B,Q,Q)
        w = jnp.where(tri[None, :, :, None], cb[..., None] * decay, 0.0)  # (B,Q,Q,P)
        y_intra = jnp.einsum("bijp,bjp,bjpe->bipe", w, dt_q, x_q)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bin,bpen,bip->bipe", c_q, h, jnp.exp(cum))
        # state update: h' = exp(cum_Q) h + sum_j exp(cum_Q - cum_j) dt_j B_j x_j
        end = cum[:, -1:, :]  # (B,1,P)
        dec_j = jnp.exp(end - cum)  # (B,Q,P)
        h_new = jnp.exp(end[:, 0, :])[:, :, None, None] * h + jnp.einsum(
            "bjp,bjn,bjpe->bpen", dec_j * dt_q, b_q, x_q
        )
        return h_new, y_intra + y_inter

    ch = lambda t: t[:, :main].reshape(b, n_chunks, q, *t.shape[2:]).swapaxes(0, 1)
    h_end, ys = jax.lax.scan(chunk_body, h0, (ch(dt), ch(dta), ch(bmat), ch(cmat), ch(xh)))
    y = ys.swapaxes(0, 1).reshape(b, main, di)
    if rem:  # remainder chunk
        h_end, y_rem = chunk_body(
            h_end, (dt[:, main:], dta[:, main:], bmat[:, main:], cmat[:, main:], xh[:, main:])
        )
        y = jnp.concatenate([y, y_rem.reshape(b, rem, di)], axis=1)
    y = y + (params["D"][:, None] * xh.reshape(b, seq, p, hd)).reshape(b, seq, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = layers.rms_norm(y.astype(x.dtype), params["norm"])
    out = y @ params["out_proj"]
    return out, SSMState(h_end, new_tail)


def ssm_block(params, cfg: ModelConfig, x, state=None):
    fn = mamba1 if cfg.ssm.kind == "mamba1" else mamba2
    return fn(params, cfg, x, state)
