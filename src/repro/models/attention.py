"""Attention: GQA/MHA (bias, qk_norm, RoPE variants) and DeepSeek MLA.

Shapes: activations (B, S, D); projection weights keep the head axis explicit
— wq (D, H, hd) — so the TP partition rules in repro.parallel can shard heads
on the 'model' axis by annotating that axis directly.

Decode: `kv_cache` is a dict {'k': (B, S_max, K, hd), 'v': ...} (MLA caches
the compressed c_kv + shared k_rope instead — its headline memory win).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig, MLAConfig


def _apply_positional(cfg: ModelConfig, x, positions):
    if cfg.rope == "standard":
        return layers.apply_rope(x, positions, cfg.rope_theta)
    if cfg.rope == "rope2d":
        return layers.apply_rope_2d(x, positions, cfg.rope_theta)
    if cfg.rope == "mrope":
        return layers.apply_mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    return x


def init_attention(rng, cfg: ModelConfig, dtype) -> dict:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 6)
    p = {
        "wq": layers.normal_init(ks[0], (d, h, hd), dtype=dtype),
        "wk": layers.normal_init(ks[1], (d, k, hd), dtype=dtype),
        "wv": layers.normal_init(ks[2], (d, k, hd), dtype=dtype),
        "wo": layers.normal_init(ks[3], (h, hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((k, hd), dtype)
        p["bv"] = jnp.zeros((k, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(params, cfg: ModelConfig, x, positions):
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dke->bske", x, params["wk"])
    v = jnp.einsum("bsd,dke->bske", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"])
        k = layers.rms_norm(k, params["k_norm"])
    q = _apply_positional(cfg, q, positions)
    k = _apply_positional(cfg, k, positions)
    return q, k, v


# above this many score elements per head-group, use the chunked online-softmax
# path (flash-attention pattern): never materializes (Sq, Sk) scores.
# 2048x4096 pulls the train_4k shapes in — dense (S,S) f32 scores were the
# peak-memory driver at 4k (§Perf iteration 3).
_CHUNKED_THRESHOLD = 2048 * 4096
_Q_CHUNK = 1024
_K_CHUNK = 1024


def _sdpa_dense(q, k, v, causal: bool, q_offset=0):
    b, sq, h, hd = q.shape
    sk, kh, hd_v = v.shape[1], v.shape[2], v.shape[3]
    rep = h // kh
    q = q.reshape(b, sq, kh, rep, hd)
    scores = jnp.einsum("bqkre,bske->bkrqs", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(sk)[None, :]
        scores = jnp.where(ki <= qi, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrqs,bske->bqkre", probs, v)
    return out.reshape(b, sq, h, hd_v)


def _sdpa_chunked(q, k, v, causal: bool, q_offset=0):
    """Memory-efficient attention (Rabe-Staats / flash pattern in pure JAX):
    outer scan over query chunks, inner scan over key chunks with running
    (max, denom, acc) online softmax.  Peak memory per step is one
    (q_chunk, k_chunk) score tile per head group instead of (Sq, Sk).

    Causality is enforced by masking; key chunks entirely in the future of a
    query chunk are skipped structurally (inner scan length is bounded by
    the chunk diagonal), so causal flops stay ~half of the full rectangle.
    """
    b, sq0, h, hd = q.shape
    sk0, kh, hd_v = v.shape[1], v.shape[2], v.shape[3]
    rep = h // kh
    qc = min(_Q_CHUNK, sq0)
    kc = min(_K_CHUNK, sk0)
    # pad both sequence axes to chunk multiples (e.g. whisper's 1500-frame
    # cross-attention); padded keys are masked, padded queries sliced off
    pad_q = (-sq0) % qc
    pad_k = (-sk0) % kc
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq, sk = sq0 + pad_q, sk0 + pad_k
    nq, nk = sq // qc, sk // kc
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qr = q.reshape(b, nq, qc, kh, rep, hd)
    kr = k.reshape(b, nk, kc, kh, hd)
    vr = v.reshape(b, nk, kc, kh, hd_v)

    @jax.checkpoint  # flash-style: recompute score tiles in bwd, O(tile) memory
    def q_block(carry, qi):
        q_blk = qr[:, qi]  # (b, qc, kh, rep, hd)

        def k_block(state, ki):
            m, l, acc = state
            k_blk = kr[:, ki]
            v_blk = vr[:, ki]
            s = jnp.einsum("bqkre,bske->bkrqs", q_blk, k_blk).astype(jnp.float32) * scale
            kpos = ki * kc + jnp.arange(kc)[None, :]
            if causal:
                qpos = qi * qc + jnp.arange(qc)[:, None] + q_offset
                s = jnp.where(kpos <= qpos, s, -1e30)
            if pad_k:
                s = jnp.where(kpos[0] < sk0, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkrqs,bske->bkrqe", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, rep, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kh, rep, qc), jnp.float32)
        a0 = jnp.zeros((b, kh, rep, qc, hd_v), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_block, (m0, l0, a0), jnp.arange(nk))
        out = (acc / jnp.maximum(l, 1e-30)[..., None])  # (b,kh,rep,qc,hd_v)
        return carry, out.transpose(0, 3, 1, 2, 4)  # (b,qc,kh,rep,hd_v)

    _, outs = jax.lax.scan(q_block, 0, jnp.arange(nq))  # (nq, b, qc, kh, rep, hd_v)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hd_v)
    if pad_q:
        out = out[:, :sq0]
    return out.astype(v.dtype)


def _sdpa(q, k, v, causal: bool, q_offset=0):
    """q/k (B,S,·,hd_qk), v (B,Sk,K,hd_v) with GQA head repetition.
    hd_v may differ from hd_qk (MLA).  Long sequences route to the chunked
    online-softmax path."""
    sq, sk = q.shape[1], v.shape[1]
    if sq * sk > _CHUNKED_THRESHOLD and sq > 1:
        return _sdpa_chunked(q, k, v, causal, q_offset)
    return _sdpa_dense(q, k, v, causal, q_offset)


def attention(params, cfg: ModelConfig, x, positions, causal=True):
    """Full-sequence attention (training / prefill)."""
    q, k, v = _qkv(params, cfg, x, positions)
    out = _sdpa(q, k, v, causal)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def attention_with_kv(params, cfg: ModelConfig, x, positions, causal=True):
    """Prefill variant: also returns the (k, v) tensors for cache fill."""
    q, k, v = _qkv(params, cfg, x, positions)
    out = _sdpa(q, k, v, causal)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"]), k, v


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    k, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, max_seq, k, hd), dtype),
        "v": jnp.zeros((batch, max_seq, k, hd), dtype),
    }


def decode_attention(params, cfg: ModelConfig, x, cache: dict, pos: jnp.ndarray,
                     rope_positions=None):
    """One-token decode. x (B,1,D); pos (B,1) absolute position (cache slot);
    rope_positions defaults to pos but may carry the (3,B,1) M-RoPE streams.
    Returns (out (B,1,D), new_cache)."""
    q, k_new, v_new = _qkv(params, cfg, x, pos if rope_positions is None else rope_positions)
    b = x.shape[0]
    oh = jax.nn.one_hot(pos[:, 0], cache["k"].shape[1], dtype=cache["k"].dtype)  # (B, S_max)
    k_cache = cache["k"] + oh[:, :, None, None] * k_new
    v_cache = cache["v"] + oh[:, :, None, None] * v_new
    # mask: positions <= pos are valid
    sk = k_cache.shape[1]
    valid = jnp.arange(sk)[None, :] <= pos  # (B, S_max)
    kh = cfg.n_kv_heads
    rep = cfg.n_heads // kh
    qr = q.reshape(b, 1, kh, rep, cfg.hd)
    scores = jnp.einsum("bqkre,bske->bkrqs", qr, k_cache).astype(jnp.float32)
    scores = scores / jnp.sqrt(cfg.hd).astype(jnp.float32)
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkrqs,bske->bqkre", probs, v_cache).reshape(b, 1, cfg.n_heads, cfg.hd)
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return out, {"k": k_cache, "v": v_cache}


# --- DeepSeek MLA (Multi-head Latent Attention) -------------------------------

def init_mla(rng, cfg: ModelConfig, dtype) -> dict:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(rng, 8)
    p = {}
    if m.q_lora:
        p["wq_a"] = layers.normal_init(ks[0], (d, m.q_lora), dtype=dtype)
        p["q_a_norm"] = jnp.ones((m.q_lora,), dtype)
        p["wq_b"] = layers.normal_init(ks[1], (m.q_lora, h, m.d_nope + m.d_rope), dtype=dtype)
    else:
        p["wq"] = layers.normal_init(ks[0], (d, h, m.d_nope + m.d_rope), dtype=dtype)
    p["wkv_a"] = layers.normal_init(ks[2], (d, m.kv_lora + m.d_rope), dtype=dtype)
    p["kv_a_norm"] = jnp.ones((m.kv_lora,), dtype)
    p["wkv_b"] = layers.normal_init(ks[3], (m.kv_lora, h, m.d_nope + m.d_v), dtype=dtype)
    p["wo"] = layers.normal_init(ks[4], (h, m.d_v, d), dtype=dtype)
    return p


def _mla_q(params, cfg: ModelConfig, x, positions):
    m = cfg.mla
    if m.q_lora:
        qa = layers.rms_norm(x @ params["wq_a"], params["q_a_norm"])
        q = jnp.einsum("bsl,lhe->bshe", qa, params["wq_b"])
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    q_nope, q_rope = q[..., : m.d_nope], q[..., m.d_nope :]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(params, cfg: ModelConfig, x, positions):
    """Compressed latent (B,S,kv_lora) + shared rotary key (B,S,d_rope)."""
    m = cfg.mla
    kv = x @ params["wkv_a"]  # (B, S, kv_lora + d_rope)
    c_kv = layers.rms_norm(kv[..., : m.kv_lora], params["kv_a_norm"])
    k_rope = kv[..., m.kv_lora :][:, :, None, :]  # (B,S,1,d_rope)
    k_rope = layers.apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_attention(params, cfg: ModelConfig, x, positions, causal=True):
    """Training/prefill MLA: expand latent to per-head k/v, standard SDPA."""
    m = cfg.mla
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv, k_rope = _mla_kv_latent(params, cfg, x, positions)
    kv = jnp.einsum("bsl,lhe->bshe", c_kv, params["wkv_b"])  # (B,S,H,nope+v)
    k_nope, v = kv[..., : m.d_nope], kv[..., m.d_nope :]
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, cfg.n_heads, m.d_rope))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    out = _sdpa(q, k, v, causal)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def mla_attention_with_cache(params, cfg: ModelConfig, x, positions, causal=True):
    """Prefill variant: also returns (c_kv, k_rope) latents for cache fill."""
    m = cfg.mla
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv, k_rope = _mla_kv_latent(params, cfg, x, positions)
    kv = jnp.einsum("bsl,lhe->bshe", c_kv, params["wkv_b"])
    k_nope, v = kv[..., : m.d_nope], kv[..., m.d_nope :]
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, cfg.n_heads, m.d_rope))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    out = _sdpa(q, k, v, causal)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"]), c_kv, k_rope


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_seq, m.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, max_seq, m.d_rope), dtype),
    }


def mla_decode_attention(params, cfg: ModelConfig, x, cache: dict, pos: jnp.ndarray,
                         rope_positions=None):
    """Absorbed-matmul MLA decode: attention runs in the 512-d latent space;
    per-token cache is kv_lora + d_rope floats (the paper's 576 vs 32k for
    full MHA).  W_kv_b is absorbed into the query/output sides."""
    m = cfg.mla
    b = x.shape[0]
    rp = pos if rope_positions is None else rope_positions
    q_nope, q_rope = _mla_q(params, cfg, x, rp)  # (B,1,H,·)
    c_new, r_new = _mla_kv_latent(params, cfg, x, rp)  # (B,1,L), (B,1,R)
    oh = jax.nn.one_hot(pos[:, 0], cache["c_kv"].shape[1], dtype=cache["c_kv"].dtype)
    c_cache = cache["c_kv"] + oh[:, :, None] * c_new
    r_cache = cache["k_rope"] + oh[:, :, None] * r_new
    wkv_b = params["wkv_b"]  # (L, H, nope+v)
    wk_b, wv_b = wkv_b[..., : m.d_nope], wkv_b[..., m.d_nope :]
    # absorb: q_lat = q_nope @ wk_b^T  -> score against latent cache directly
    q_lat = jnp.einsum("bqhe,lhe->bqhl", q_nope, wk_b)  # (B,1,H,L)
    scores = (
        jnp.einsum("bqhl,bsl->bhqs", q_lat, c_cache)
        + jnp.einsum("bqhe,bse->bhqs", q_rope, r_cache)
    ).astype(jnp.float32) / jnp.sqrt(m.d_nope + m.d_rope).astype(jnp.float32)
    valid = jnp.arange(c_cache.shape[1])[None, :] <= pos
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(c_cache.dtype)
    o_lat = jnp.einsum("bhqs,bsl->bqhl", probs, c_cache)  # (B,1,H,L)
    o = jnp.einsum("bqhl,lhe->bqhe", o_lat, wv_b)  # (B,1,H,d_v)
    out = jnp.einsum("bshe,hed->bsd", o, params["wo"])
    return out, {"c_kv": c_cache, "k_rope": r_cache}
