"""Top-level LM: init / forward / loss / prefill / decode for all 10 archs.

Pure-functional: `LM` holds only config + mesh; params/caches are pytrees.
`init_abstract()` gives ShapeDtypeStruct params for the no-allocation dry-run.

Positional streams: standard/rope2d take (B,S) int positions; mrope takes
(3,B,S).  Whisper uses sinusoidal added embeddings (deviation from learned
tables, noted in DESIGN.md — keeps param shapes independent of seq length).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention, layers, transformer as tfm
from .config import ModelConfig
from .transformer import segments


def sinusoidal(seq: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


class LM:
    def __init__(self, cfg: ModelConfig, mesh=None, tp_logits: bool = True,
                 act_spec=None):
        self.cfg = cfg
        self.mesh = mesh
        self.tp_logits = tp_logits  # vocab-shard the logits constraint (TP policy)
        # activation PartitionSpec for (B, S, D) residual-stream tensors;
        # constraining at segment boundaries pins GSPMD's propagation into
        # the scanned while bodies (without it the body can fall back to
        # replicated compute — §Perf iteration 2 post-mortem)
        self.act_spec = act_spec
        self.segs = segments(cfg)
        self.dtype = jnp.dtype(cfg.dtype)
        self._embed_lookup = (
            layers.embed_lookup_merged if cfg.dedup_embed_grad else layers.embed_lookup_naive
        )

    def _constrain(self, x):
        if self.mesh is None or self.act_spec is None or x.ndim != 3:
            return x
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, self.act_spec))

    # ---- params ----

    def init(self, rng: jax.Array) -> dict:
        cfg, dtype = self.cfg, self.dtype
        ks = iter(jax.random.split(rng, 16 + len(self.segs)))
        params: dict[str, Any] = {
            "embed": layers.normal_init(next(ks), (cfg.vocab, cfg.d_model), dtype=dtype),
            "final_norm": tfm._init_norm(cfg, dtype),
        }
        for i, (kind, n) in enumerate(self.segs):
            params[f"seg{i}_{kind}"] = tfm.init_segment(next(ks), cfg, kind, n, dtype)
        if cfg.hybrid_attn_every:
            params["shared_attn"] = tfm.init_block(next(ks), cfg, "attn_dense", dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.normal_init(next(ks), (cfg.d_model, cfg.vocab), dtype=dtype)
        if cfg.enc_dec:
            params["enc_segs"] = tfm.init_segment(next(ks), cfg, "enc_attn", cfg.n_encoder_layers, dtype)
            params["enc_norm"] = tfm._init_norm(cfg, dtype)
        if cfg.mtp_depth:
            params["mtp"] = {
                "proj": layers.normal_init(next(ks), (2 * cfg.d_model, cfg.d_model), dtype=dtype),
                "block": tfm.init_block(next(ks), cfg, self.segs[-1][0], dtype),
                "norm_h": tfm._init_norm(cfg, dtype),
                "norm_e": tfm._init_norm(cfg, dtype),
            }
        return params

    def init_abstract(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ---- positions ----

    def default_positions(self, batch: int, seq: int, offset: int = 0):
        pos = jnp.arange(offset, offset + seq, dtype=jnp.int32)[None, :].repeat(batch, 0)
        if self.cfg.rope == "mrope":
            return jnp.broadcast_to(pos[None], (3,) + pos.shape)  # degenerate text M-RoPE
        return pos

    # ---- embedding / head ----

    def embed(self, params, tokens):
        return self._embed_lookup(params["embed"], tokens).astype(self.dtype)

    def logits(self, params, x):
        head = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        out = (x @ head).astype(jnp.float32)
        mesh = self.mesh
        if self.tp_logits and mesh is not None and "model" in mesh.shape \
                and self.cfg.vocab % mesh.shape["model"] == 0:
            from jax.sharding import PartitionSpec as P, NamedSharding
            dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
            out = jax.lax.with_sharding_constraint(
                out, NamedSharding(mesh, P(dp, None, "model"))
            )
        return out

    # ---- encoder (whisper) ----

    def encode(self, params, encoder_embeds):
        cfg = self.cfg
        b, s, _ = encoder_embeds.shape
        x = encoder_embeds.astype(self.dtype) + sinusoidal(s, cfg.d_model, self.dtype)[None]
        pos = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
        x = tfm.apply_segment(params["enc_segs"], cfg, "enc_attn", x, pos, self.mesh)
        return tfm.apply_norm(cfg, params["enc_norm"], x)

    # ---- forward (train / prefill logits) ----

    def forward(self, params, tokens=None, embeds=None, positions=None, encoder_embeds=None):
        cfg = self.cfg
        if embeds is not None:
            x = embeds.astype(self.dtype)
            b, s = x.shape[:2]
        else:
            b, s = tokens.shape
            x = self.embed(params, tokens)
        if cfg.enc_dec:
            x = x + sinusoidal(s, cfg.d_model, self.dtype)[None]
        if positions is None:
            positions = self.default_positions(b, s)
        enc_out = self.encode(params, encoder_embeds) if cfg.enc_dec else None

        x = self._constrain(x)
        for i, (kind, n) in enumerate(self.segs):
            seg_params = params[f"seg{i}_{kind}"]
            seg_kind = "dec_attn" if (cfg.enc_dec and kind == "attn_dense") else kind
            if cfg.hybrid_attn_every and kind in ("mamba1", "mamba2"):
                x = tfm.apply_hybrid_segment(
                    seg_params, cfg, kind, x, positions, params["shared_attn"], self.mesh,
                    constrain=self._constrain,
                )
            else:
                x = tfm.apply_segment(seg_params, cfg, seg_kind, x, positions, self.mesh,
                                      enc_out, constrain=self._constrain)
            x = self._constrain(x)
        h = tfm.apply_norm(cfg, params["final_norm"], x)
        return self.logits(params, h), h

    # ---- loss ----

    def loss(self, params, batch: dict) -> jnp.ndarray:
        """batch: tokens (B,S) plus optional embeds/encoder_embeds/positions.
        Next-token CE; MTP head adds the deepseek-v3 auxiliary loss."""
        cfg = self.cfg
        tokens = batch["tokens"]
        logits, h = self.forward(
            params,
            tokens=None if "embeds" in batch else tokens,
            embeds=batch.get("embeds"),
            positions=batch.get("positions"),
            encoder_embeds=batch.get("encoder_embeds"),
        )
        targets = tokens[:, 1:]
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
        loss = nll.mean()
        if cfg.mtp_depth:
            loss = loss + 0.3 * self._mtp_loss(params, h, tokens)
        return loss

    def _mtp_loss(self, params, h, tokens):
        """Depth-1 multi-token prediction: from h_t and emb(t+1), predict t+2."""
        cfg = self.cfg
        mtp = params["mtp"]
        emb_next = self.embed(params, tokens[:, 1:])          # (B, S-1, D)
        h_trunc = h[:, :-1]                                   # (B, S-1, D)
        z = jnp.concatenate(
            [tfm.apply_norm(cfg, mtp["norm_h"], h_trunc),
             tfm.apply_norm(cfg, mtp["norm_e"], emb_next)], axis=-1
        ) @ mtp["proj"]
        pos = self.default_positions(z.shape[0], z.shape[1])
        kind = self.segs[-1][0]
        z = tfm.apply_block(mtp["block"], cfg, kind, z, pos, self.mesh)
        logits = self.logits(params, tfm.apply_norm(cfg, params["final_norm"], z))
        targets = tokens[:, 2:]
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
        return nll.mean()

    # ---- serving ----

    def init_caches(self, batch: int, max_seq: int) -> dict:
        cfg = self.cfg
        caches: dict[str, Any] = {}
        for i, (kind, n) in enumerate(self.segs):
            seg_kind = "dec_attn" if (cfg.enc_dec and kind == "attn_dense") else kind
            one = tfm.init_block_cache(cfg, seg_kind, batch, max_seq, self.dtype)
            caches[f"seg{i}_{kind}"] = jax.tree.map(
                lambda t: jnp.zeros((n,) + t.shape, t.dtype), one
            )
        if cfg.hybrid_attn_every:
            n_groups = cfg.n_layers // cfg.hybrid_attn_every
            one = tfm.init_block_cache(cfg, "attn_dense", batch, max_seq, self.dtype)
            caches["shared_attn"] = jax.tree.map(
                lambda t: jnp.zeros((n_groups,) + t.shape, t.dtype), one
            )
        return caches

    def prefill(self, params, tokens=None, embeds=None, positions=None, encoder_embeds=None,
                max_seq: int | None = None):
        """Run the prompt, returning (last-token logits, filled caches, enc_out).

        Caches hold the prompt's K/V (or SSM states) laid out exactly as
        decode_step expects; decode continues at pos = prompt_len.  Pass
        `max_seq` > prompt length to leave room for generated tokens.
        """
        cfg = self.cfg
        if embeds is not None:
            x = embeds.astype(self.dtype)
            b, s = x.shape[:2]
        else:
            b, s = tokens.shape
            x = self.embed(params, tokens)
        if cfg.enc_dec:
            x = x + sinusoidal(s, cfg.d_model, self.dtype)[None]
        if positions is None:
            positions = self.default_positions(b, s)
        enc_out = self.encode(params, encoder_embeds) if cfg.enc_dec else None

        caches: dict[str, Any] = {}
        for i, (kind, n) in enumerate(self.segs):
            seg_params = params[f"seg{i}_{kind}"]
            seg_kind = "dec_attn" if (cfg.enc_dec and kind == "attn_dense") else kind
            if cfg.hybrid_attn_every and kind in ("mamba1", "mamba2"):
                x, nc, nsh = tfm.apply_hybrid_segment_prefill(
                    seg_params, cfg, kind, x, positions, params["shared_attn"], self.mesh,
                    max_seq=max_seq,
                )
                caches["shared_attn"] = nsh
            else:
                x, nc = tfm.apply_segment_prefill(
                    seg_params, cfg, seg_kind, x, positions, self.mesh, enc_out,
                    max_seq=max_seq, constrain=self._constrain,
                )
            caches[f"seg{i}_{kind}"] = nc
        h = tfm.apply_norm(cfg, params["final_norm"], x)
        logits = self.logits(params, h[:, -1:, :])
        return logits[:, 0], caches, enc_out

    def decode_step(self, params, caches, tokens, pos, encoder_out=None):
        """tokens (B,1) int32, pos (B,1) absolute positions.
        Returns (logits (B,V) f32, new_caches)."""
        cfg = self.cfg
        x = self.embed(params, tokens)
        if cfg.enc_dec:
            # sinusoidal at the absolute position
            d = cfg.d_model
            x = x + sinusoidal_at(pos, d, self.dtype)
        rope_positions = None
        if cfg.rope == "mrope":
            rope_positions = jnp.broadcast_to(pos[None], (3,) + pos.shape)
        new_caches = {}
        for i, (kind, n) in enumerate(self.segs):
            seg_params = params[f"seg{i}_{kind}"]
            seg_caches = caches[f"seg{i}_{kind}"]
            seg_kind = "dec_attn" if (cfg.enc_dec and kind == "attn_dense") else kind
            if cfg.hybrid_attn_every and kind in ("mamba1", "mamba2"):
                x, nc, nsh = tfm.apply_hybrid_segment_decode(
                    seg_params, cfg, kind, x, seg_caches, pos,
                    params["shared_attn"], caches["shared_attn"], self.mesh,
                )
                new_caches["shared_attn"] = nsh
            else:
                x, nc = tfm.apply_segment_decode(
                    seg_params, cfg, seg_kind, x, seg_caches, pos, self.mesh, encoder_out,
                    rope_positions,
                )
            new_caches[f"seg{i}_{kind}"] = nc
        h = tfm.apply_norm(cfg, params["final_norm"], x)
        logits = self.logits(params, h)
        return logits[:, 0], new_caches


def sinusoidal_at(pos: jnp.ndarray, d: int, dtype) -> jnp.ndarray:
    """Sinusoidal embedding at arbitrary positions. pos (B,1) -> (B,1,D)."""
    dim = jnp.arange(d // 2)[None, None, :].astype(jnp.float32)
    ang = pos[..., None].astype(jnp.float32) / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
