"""Analytic parameter counts (for MODEL_FLOPS = 6·N·D roofline ratios).

Total counts come from `jax.eval_shape` over the real init (exact, zero
maintenance); MoE active counts subtract the non-activated routed experts.
"""
from __future__ import annotations

import math

import jax


def _abstract_params(cfg):
    from .lm import LM  # local import to avoid a cycle
    model = LM(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def param_count(cfg) -> int:
    shapes = _abstract_params(cfg)
    # python-int product: stacked leaves exceed int32 (e.g. 64x4096x16384)
    return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes))


def active_param_count(cfg) -> int:
    """Params touched per token: total minus the routed experts not selected."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_expert_ff
    n_moe_layers = cfg.n_layers - m.n_dense_layers
    inactive = (m.n_routed - m.top_k) * per_expert * n_moe_layers
    return total - inactive
