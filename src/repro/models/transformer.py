"""Block definitions and the scanned decoder stack.

Architectures are expressed as *segments* — contiguous runs of one block kind
whose stacked params scan with lax.scan (one trace per kind, so deepseek-v3's
61 layers compile as two scans, not 61 inlined blocks):

    dense LMs        [('attn_dense', n)]
    deepseek-v2/v3   [('mla_dense', k), ('mla_moe', n-k)]
    falcon-mamba     [('mamba1', n)]
    zamba2           [('mamba2', n)] + a weight-shared attention block applied
                     every `hybrid_attn_every` layers inside the scan
    whisper          encoder [('enc_attn', n)] / decoder [('dec_attn', n)]
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention, ffn, layers, moe, ssm
from .config import ModelConfig


# --- segment layout -------------------------------------------------------------

def segments(cfg: ModelConfig) -> list[tuple[str, int]]:
    if cfg.enc_dec:
        return [("dec_attn", cfg.n_layers)]
    if cfg.family == "ssm":
        return [("mamba1" if cfg.ssm.kind == "mamba1" else "mamba2", cfg.n_layers)]
    if cfg.family == "hybrid":
        return [("mamba2" if cfg.ssm.kind == "mamba2" else "mamba1", cfg.n_layers)]
    if cfg.moe is not None:
        nd = cfg.moe.n_dense_layers
        segs = []
        if nd:
            segs.append(("mla_dense" if cfg.mla else "attn_dense", nd))
        segs.append(("mla_moe" if cfg.mla else "attn_moe", cfg.n_layers - nd))
        return segs
    return [("attn_dense", cfg.n_layers)]


# --- per-block params -------------------------------------------------------------

def _init_norm(cfg: ModelConfig, dtype):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype), "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.ones((cfg.d_model,), dtype)}


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return layers.layer_norm(x, p["scale"], p["bias"])
    return layers.rms_norm(x, p["scale"])


def init_block(rng, cfg: ModelConfig, kind: str, dtype) -> dict:
    ks = jax.random.split(rng, 4)
    p: dict[str, Any] = {"ln1": _init_norm(cfg, dtype)}
    if kind in ("mamba1", "mamba2"):
        p["ssm"] = ssm.init_ssm(ks[0], cfg, dtype)
        return p
    if kind.startswith("mla"):
        p["attn"] = attention.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = attention.init_attention(ks[0], cfg, dtype)
    p["ln2"] = _init_norm(cfg, dtype)
    if kind.endswith("moe"):
        p["moe"] = moe.init_moe(ks[1], cfg, dtype)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.dense_d_ff:
            d_ff = cfg.moe.dense_d_ff  # deepseek leading dense layers are wider
        p["ffn"] = ffn.init_ffn(ks[1], cfg.d_model, d_ff, cfg.act, dtype)
    if kind == "dec_attn":  # whisper decoder: cross-attention sublayer
        p["ln_x"] = _init_norm(cfg, dtype)
        p["xattn"] = attention.init_attention(ks[2], cfg, dtype)
    return p


# --- per-block application ----------------------------------------------------------

def apply_block(params, cfg: ModelConfig, kind: str, x, positions, mesh=None, encoder_out=None):
    """Full-sequence (train / prefill) block application."""
    h = apply_norm(cfg, params["ln1"], x)
    if kind in ("mamba1", "mamba2"):
        y, _ = ssm.ssm_block(params["ssm"], cfg, h)
        return x + y
    if kind.startswith("mla"):
        y = attention.mla_attention(params["attn"], cfg, h, positions)
    elif kind == "enc_attn":
        y = attention.attention(params["attn"], cfg, h, positions, causal=False)
    else:
        y = attention.attention(params["attn"], cfg, h, positions)
    x = x + y
    if kind == "dec_attn" and encoder_out is not None:
        h = apply_norm(cfg, params["ln_x"], x)
        y = _cross_attention(params["xattn"], cfg, h, encoder_out)
        x = x + y
    h = apply_norm(cfg, params["ln2"], x)
    if kind.endswith("moe"):
        y = moe.moe_layer(params["moe"], h, cfg, mesh)
    else:
        d_ff_act = cfg.act
        y = ffn.ffn(params["ffn"], h, d_ff_act)
    return x + y


def _cross_attention(params, cfg: ModelConfig, x, encoder_out):
    """Decoder->encoder attention (no positional rotation, no causal mask)."""
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dke->bske", encoder_out, params["wk"])
    v = jnp.einsum("bsd,dke->bske", encoder_out, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    out = attention._sdpa(q, k, v, causal=False)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def apply_block_decode(params, cfg: ModelConfig, kind: str, x, cache, pos, mesh=None,
                       encoder_out=None, rope_positions=None):
    """One-token decode.  cache is the block's state pytree; returns (x, cache).
    pos (B,1) is the cache slot; rope_positions may carry M-RoPE streams."""
    h = apply_norm(cfg, params["ln1"], x)
    if kind in ("mamba1", "mamba2"):
        y, new_state = ssm.ssm_block(params["ssm"], cfg, h, cache)
        return x + y, new_state
    if kind.startswith("mla"):
        y, cache_sa = attention.mla_decode_attention(params["attn"], cfg, h, cache["self"],
                                                     pos, rope_positions)
    else:
        y, cache_sa = attention.decode_attention(params["attn"], cfg, h, cache["self"],
                                                 pos, rope_positions)
    x = x + y
    new_cache = dict(cache)
    new_cache["self"] = cache_sa
    if kind == "dec_attn":
        # cross-attention against cached encoder K/V (filled at prefill)
        h = apply_norm(cfg, params["ln_x"], x)
        y = _cross_attention_cached(params["xattn"], cfg, h, cache["cross"])
        x = x + y
    h = apply_norm(cfg, params["ln2"], x)
    if kind.endswith("moe"):
        y = moe.moe_layer(params["moe"], h, cfg, mesh)
    else:
        y = ffn.ffn(params["ffn"], h, cfg.act)
    return x + y, new_cache


def _cross_attention_cached(params, cfg: ModelConfig, x, cross_cache):
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    if cfg.qkv_bias:
        q = q + params["bq"]
    out = attention._sdpa(q, cross_cache["k"], cross_cache["v"], causal=False)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def apply_block_prefill(params, cfg: ModelConfig, kind: str, x, positions, mesh=None,
                        encoder_out=None, max_seq: int | None = None):
    """Full-prompt pass that also emits the block's decode cache."""
    h = apply_norm(cfg, params["ln1"], x)
    if kind in ("mamba1", "mamba2"):
        y, state = ssm.ssm_block(params["ssm"], cfg, h)
        return x + y, state
    if kind.startswith("mla"):
        y, c_kv, k_rope = attention.mla_attention_with_cache(params["attn"], cfg, h, positions)
        cache = {"self": {"c_kv": _pad_seq(c_kv, max_seq), "k_rope": _pad_seq(k_rope, max_seq)}}
    else:
        causal = kind != "enc_attn"
        y, k, v = attention.attention_with_kv(params["attn"], cfg, h, positions, causal=causal)
        cache = {"self": {"k": _pad_seq(k, max_seq), "v": _pad_seq(v, max_seq)}}
    x = x + y
    if kind == "dec_attn":
        h = apply_norm(cfg, params["ln_x"], x)
        xk = jnp.einsum("bsd,dke->bske", encoder_out, params["xattn"]["wk"])
        xv = jnp.einsum("bsd,dke->bske", encoder_out, params["xattn"]["wv"])
        if cfg.qkv_bias:
            xk, xv = xk + params["xattn"]["bk"], xv + params["xattn"]["bv"]
        cache["cross"] = {"k": xk, "v": xv}
        y = attention._sdpa(
            jnp.einsum("bsd,dhe->bshe", h, params["xattn"]["wq"])
            + (params["xattn"]["bq"] if cfg.qkv_bias else 0),
            xk, xv, causal=False,
        )
        x = x + jnp.einsum("bshe,hed->bsd", y, params["xattn"]["wo"])
    h = apply_norm(cfg, params["ln2"], x)
    if kind.endswith("moe"):
        y = moe.moe_layer(params["moe"], h, cfg, mesh)
    else:
        y = ffn.ffn(params["ffn"], h, cfg.act)
    return x + y, cache


def _pad_seq(t, max_seq):
    """Pad the sequence axis (axis 1) of a cache tensor up to max_seq."""
    if max_seq is None or t.shape[1] == max_seq:
        return t
    pad = max_seq - t.shape[1]
    return jnp.concatenate([t, jnp.zeros((t.shape[0], pad) + t.shape[2:], t.dtype)], axis=1)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int, dtype):
    if kind in ("mamba1", "mamba2"):
        return ssm.init_ssm_state(cfg, batch, dtype)
    if kind.startswith("mla"):
        return {"self": attention.init_mla_cache(cfg, batch, max_seq, dtype)}
    cache = {"self": attention.init_kv_cache(cfg, batch, max_seq, dtype)}
    if kind == "dec_attn":
        k, hd = cfg.n_kv_heads, cfg.hd
        cache["cross"] = {
            "k": jnp.zeros((batch, cfg.encoder_seq, k, hd), dtype),
            "v": jnp.zeros((batch, cfg.encoder_seq, k, hd), dtype),
        }
    return cache


# --- stacked segments ----------------------------------------------------------------

def init_segment(rng, cfg: ModelConfig, kind: str, n: int, dtype):
    """Stack n blocks' params along a leading layer axis (for lax.scan)."""
    ks = jax.random.split(rng, n)
    blocks = [init_block(k, cfg, kind, dtype) for k in ks]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def _maybe_remat(f, cfg: ModelConfig):
    return jax.checkpoint(f) if cfg.remat else f


def _n_layers_of(params) -> int:
    return jax.tree_util.tree_leaves(params)[0].shape[0]


def _layer(params, i):
    return jax.tree.map(lambda t: t[i], params)


def apply_segment(params, cfg: ModelConfig, kind: str, x, positions, mesh=None,
                  encoder_out=None, constrain=None):
    """Scan a homogeneous stacked segment.  `constrain` (optional callable)
    re-pins the carry's sharding every iteration — GSPMD propagation into
    while bodies can otherwise degrade to replicated compute."""
    keep = constrain or (lambda h: h)
    fn = _maybe_remat(
        lambda p, h_: apply_block(p, cfg, kind, h_, positions, mesh, encoder_out), cfg
    )
    if cfg.unroll_layers:
        for i in range(_n_layers_of(params)):
            x = keep(fn(_layer(params, i), x))
        return x

    def body(h, layer_params):
        return keep(fn(layer_params, keep(h))), None

    x, _ = jax.lax.scan(body, x, params)
    return x


def apply_segment_decode(params, cfg: ModelConfig, kind: str, x, caches, pos,
                         mesh=None, encoder_out=None, rope_positions=None):
    """Decode scan; caches are stacked along the layer axis too."""
    if cfg.unroll_layers:
        outs = []
        for i in range(_n_layers_of(params)):
            x, nc = apply_block_decode(_layer(params, i), cfg, kind, x, _layer(caches, i),
                                       pos, mesh, encoder_out, rope_positions)
            outs.append(nc)
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    def body(h, inp):
        layer_params, cache = inp
        h, new_cache = apply_block_decode(layer_params, cfg, kind, h, cache, pos, mesh,
                                          encoder_out, rope_positions)
        return h, new_cache

    x, new_caches = jax.lax.scan(body, x, (params, caches))
    return x, new_caches


def apply_segment_prefill(params, cfg: ModelConfig, kind: str, x, positions,
                          mesh=None, encoder_out=None, max_seq: int | None = None,
                          constrain=None):
    """Prefill scan: returns (x, stacked caches)."""
    keep = constrain or (lambda h: h)
    if cfg.unroll_layers:
        outs = []
        for i in range(_n_layers_of(params)):
            x, cache = apply_block_prefill(_layer(params, i), cfg, kind, x, positions,
                                           mesh, encoder_out, max_seq)
            x = keep(x)
            outs.append(cache)
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    def body(h, layer_params):
        h, cache = apply_block_prefill(layer_params, cfg, kind, keep(h), positions, mesh,
                                       encoder_out, max_seq)
        return keep(h), cache

    x, caches = jax.lax.scan(body, x, params)
    return x, caches


def apply_hybrid_segment_prefill(params, cfg: ModelConfig, kind: str, x, positions,
                                 shared_attn, mesh=None, max_seq: int | None = None):
    every = cfg.hybrid_attn_every
    grouped, tail, n_groups, rem = _hybrid_split(params, cfg.n_layers, every)

    def group_body(h, group_params):
        h, gc = apply_segment_prefill(group_params, cfg, kind, h, positions, mesh,
                                      max_seq=max_seq)
        h, sh_cache = apply_block_prefill(shared_attn, cfg, "attn_dense", h, positions,
                                          mesh, max_seq=max_seq)
        return h, (gc, sh_cache)

    if cfg.unroll_layers:
        outs = []
        for g in range(n_groups):
            x, out = group_body(x, _layer(grouped, g))
            outs.append(out)
        grouped_caches, shared_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        x, (grouped_caches, shared_caches) = jax.lax.scan(group_body, x, grouped)
    if rem:
        x, tail_caches = apply_segment_prefill(tail, cfg, kind, x, positions, mesh,
                                               max_seq=max_seq)
        flat = jax.tree.map(
            lambda g, t: jnp.concatenate([g.reshape((-1,) + g.shape[2:]), t]),
            grouped_caches, tail_caches,
        )
    else:
        flat = jax.tree.map(lambda g: g.reshape((-1,) + g.shape[2:]), grouped_caches)
    return x, flat, shared_caches


# --- zamba2-style hybrid: mamba stack + weight-shared attention every k layers ---
#
# The shared block's WEIGHTS are reused at every application point (the
# arch's parameter-saving trick) but each point has its own KV cache.  To keep
# scans homogeneous, layers are processed in groups of `every`: an outer scan
# over groups runs an inner scan of `every` ssm layers then one shared-attn
# application.  Remainder layers (n % every) run in a final plain scan.

def _hybrid_split(params_stacked, n: int, every: int):
    n_groups, rem = divmod(n, every)
    grouped = jax.tree.map(
        lambda t: t[: n_groups * every].reshape((n_groups, every) + t.shape[1:]),
        params_stacked,
    )
    tail = jax.tree.map(lambda t: t[n_groups * every :], params_stacked)
    return grouped, tail, n_groups, rem


def apply_hybrid_segment(params, cfg: ModelConfig, kind: str, x, positions,
                         shared_attn, mesh=None, constrain=None):
    every = cfg.hybrid_attn_every
    grouped, tail, n_groups, rem = _hybrid_split(params, cfg.n_layers, every)
    keep = constrain or (lambda h: h)

    def group_body(h, group_params):
        h = apply_segment(group_params, cfg, kind, h, positions, mesh, constrain=constrain)
        fn = _maybe_remat(
            lambda p, h_: apply_block(p, cfg, "attn_dense", h_, positions, mesh), cfg
        )
        return keep(fn(shared_attn, h)), None

    if cfg.unroll_layers:
        for g in range(n_groups):
            x, _ = group_body(x, _layer(grouped, g))
    else:
        x, _ = jax.lax.scan(group_body, x, grouped)
    if rem:
        x = apply_segment(tail, cfg, kind, x, positions, mesh, constrain=constrain)
    return x


def apply_hybrid_segment_decode(params, cfg: ModelConfig, kind: str, x, caches, pos,
                                shared_attn, shared_caches, mesh=None):
    """shared_caches: stacked (n_groups, ...) KV caches for the shared block."""
    every = cfg.hybrid_attn_every
    grouped, tail, n_groups, rem = _hybrid_split(params, cfg.n_layers, every)
    grouped_caches, tail_caches, _, _ = _hybrid_split(caches, cfg.n_layers, every)

    def group_body(h, inp):
        group_params, group_caches, sh_cache = inp
        h, new_gc = apply_segment_decode(group_params, cfg, kind, h, group_caches, pos, mesh)
        h, new_sh = apply_block_decode(shared_attn, cfg, "attn_dense", h, sh_cache, pos, mesh)
        return h, (new_gc, new_sh)

    if cfg.unroll_layers:
        outs = []
        for g in range(n_groups):
            x, out = group_body(
                x, (_layer(grouped, g), _layer(grouped_caches, g), _layer(shared_caches, g))
            )
            outs.append(out)
        new_grouped, new_shared = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        x, (new_grouped, new_shared) = jax.lax.scan(
            group_body, x, (grouped, grouped_caches, shared_caches)
        )
    if rem:
        x, new_tail = apply_segment_decode(tail, cfg, kind, x, tail_caches, pos, mesh)
    else:
        new_tail = tail_caches
    flat = jax.tree.map(
        lambda g, t: jnp.concatenate([g.reshape((-1,) + g.shape[2:]), t]), new_grouped, new_tail
    )
    return x, flat, new_shared
