"""Architecture + shape registry: `get_config(name)`, `list_archs()`."""
from __future__ import annotations

import importlib

from ..models.config import ModelConfig

ARCHS = [
    "qwen1_5-0_5b", "qwen3-8b", "yi-9b", "chatglm3-6b",
    "deepseek-v2-lite-16b", "deepseek-v3-671b",
    "whisper-medium", "qwen2-vl-2b", "zamba2-7b", "falcon-mamba-7b",
]

_ALIASES = {
    "qwen1.5-0.5b": "qwen1_5-0_5b",
}

_MODULES = {
    "qwen1_5-0_5b": "qwen1_5_05b",
    "qwen3-8b": "qwen3_8b",
    "yi-9b": "yi_9b",
    "chatglm3-6b": "chatglm3_6b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "deepseek-v3-671b": "deepseek_v3",
    "whisper-medium": "whisper_medium",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "zamba2-7b": "zamba2_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


def get_config(name: str, **overrides) -> ModelConfig:
    name = _ALIASES.get(name, name)
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    cfg: ModelConfig = mod.config()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    name = _ALIASES.get(name, name)
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.smoke_config()


def list_archs():
    return list(ARCHS)
