"""zamba2-7b [hybrid] — 81 Mamba-2 layers d3584 + weight-shared full-attention
block (32H) every 6 layers, ff14336 shared-block MLP, ssm_state 64,
vocab 32000. [arXiv:2411.15242]"""
import dataclasses
from ..models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab=32000,
        ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2, headdim=64),
        hybrid_attn_every=6, supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, dtype="float32", remat=False,
        ssm=SSMConfig(kind="mamba2", d_state=16, d_conv=4, expand=2, headdim=16, chunk=8),
        hybrid_attn_every=2,
    )
