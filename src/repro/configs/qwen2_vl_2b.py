"""qwen2-vl-2b [vlm] — 28L d1536 12H (GQA kv 2) ff8960 vocab 151936, M-RoPE,
vision frontend stubbed (input_specs provides patch embeddings + 3D position
ids for dynamic resolution). [arXiv:2409.12191]"""
import dataclasses
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab=151936, qkv_bias=True, rope="mrope",
        mrope_sections=(16, 24, 24), rope_theta=1e6, tie_embeddings=True,
        frontend="vision_stub",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, mrope_sections=(4, 2, 2), dtype="float32", remat=False,
    )
