"""The 4 assigned input-shape suites + `input_specs()` (ShapeDtypeStruct
stand-ins, weak-type-correct, shardable, no device allocation).

    train_4k      seq 4096,    global_batch 256   -> train_step
    prefill_32k   seq 32768,   global_batch 32    -> prefill (serve)
    decode_32k    seq 32768,   global_batch 128   -> decode_step (1 new token,
                                                     KV cache of seq_len)
    long_500k     seq 524288,  global_batch 1     -> decode_step; SSM/hybrid only

Applicability rules (DESIGN.md §5): long_500k is skipped for pure
full-attention archs; all archs here have a decode step.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.lm import LM


@dataclass(frozen=True)
class Shape:
    name: str
    seq: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 0.5M-token dense KV decode is quadratic-cost; skipped per assignment rules (DESIGN.md §5)"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """ShapeDtypeStruct batch for the step function of `shape.kind`."""
    b, s = shape.global_batch, shape.seq
    d = cfg.d_model
    if shape.kind == "train":
        batch = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.frontend == "vision_stub":
            batch["embeds"] = _sds((b, s, d), jnp.bfloat16)
            batch["positions"] = _sds((3, b, s), jnp.int32)
        elif cfg.frontend == "audio_stub":
            batch["encoder_embeds"] = _sds((b, cfg.encoder_seq, d), jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        batch = {}
        if cfg.frontend == "vision_stub":
            batch["embeds"] = _sds((b, s, d), jnp.bfloat16)
            batch["positions"] = _sds((3, b, s), jnp.int32)
        else:
            batch["tokens"] = _sds((b, s), jnp.int32)
        if cfg.frontend == "audio_stub":
            batch["encoder_embeds"] = _sds((b, cfg.encoder_seq, d), jnp.bfloat16)
        return batch
    # decode: one token + caches sized seq
    model = LM(cfg)
    caches = jax.eval_shape(lambda: model.init_caches(b, s))
    batch = {
        "tokens": _sds((b, 1), jnp.int32),
        "pos": _sds((b, 1), jnp.int32),
        "caches": caches,
    }
    if cfg.enc_dec:
        batch["encoder_out"] = _sds((b, cfg.encoder_seq, d), jnp.bfloat16)
    return batch
