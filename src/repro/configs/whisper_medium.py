"""whisper-medium [audio] — enc-dec 24+24L d1024 16H ff4096 vocab 51865,
GELU + LayerNorm, conv frontend stubbed (input_specs provides frame
embeddings). [arXiv:2212.04356]"""
import dataclasses
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=51865, norm="layernorm", act="gelu", rope="none",
        qkv_bias=True, enc_dec=True, n_encoder_layers=24, encoder_seq=1500,
        frontend="audio_stub",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, encoder_seq=32,
        dtype="float32", remat=False,
    )
