"""deepseek-v2-lite-16b [moe] — 27L d2048 16H, MLA kv_lora 512,
64 routed + 2 shared top-6 experts (d_ff_expert 1408), first layer dense
(d_ff 10944), vocab 102400.  [arXiv:2405.04434]

Note: assignment line also says "160 routed" — that is DeepSeek-V2 (236B);
the Lite config per the HF release is 64 routed, which matches the primary
"MoE 64e top-6" spec.  See DESIGN.md §5.
"""
import dataclasses
from ..models.config import ModelConfig, MoEConfig, MLAConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=102400,
        mla=MLAConfig(kv_lora=512, q_lora=0, d_nope=128, d_rope=64, d_v=128),
        moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_expert_ff=1408,
                      n_dense_layers=1, dense_d_ff=10944),
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab=256, dtype="float32", remat=False,
        mla=MLAConfig(kv_lora=32, q_lora=0, d_nope=16, d_rope=8, d_v=16),
        moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_expert_ff=96,
                      n_dense_layers=1, dense_d_ff=256),
    )
