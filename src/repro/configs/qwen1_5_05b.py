"""qwen1.5-0.5b [dense] — 24L d1024 16H (kv 16) ff2816 vocab 151936, QKV bias.
[hf:Qwen/Qwen1.5-0.5B]"""
import dataclasses
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1_5-0_5b", family="dense",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=2816, vocab=151936, qkv_bias=True, tie_embeddings=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, dtype="float32", remat=False,
    )
