"""deepseek-v3-671b [moe] — 61L d7168 128H, MLA (kv_lora 512, q_lora 1536),
1 shared + 256 routed top-8 (d_ff_expert 2048), first 3 layers dense
(d_ff 18432), sigmoid scoring, MTP depth 1, vocab 129280. [arXiv:2412.19437]"""
import dataclasses
from ..models.config import ModelConfig, MoEConfig, MLAConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=2048, vocab=129280,
        mla=MLAConfig(kv_lora=512, q_lora=1536, d_nope=128, d_rope=64, d_v=128),
        moe=MoEConfig(n_routed=256, n_shared=1, top_k=8, d_expert_ff=2048,
                      n_dense_layers=3, dense_d_ff=18432, score="sigmoid",
                      route_scale=2.5,
                      # 256-way EP over (data x model): one expert per device,
                      # expert weights never gathered (EXPERIMENTS.md §Perf)
                      ep_axes=("data", "model")),
        mtp_depth=1,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab=256, dtype="float32", remat=False,
        mla=MLAConfig(kv_lora=32, q_lora=48, d_nope=16, d_rope=8, d_v=16),
        moe=MoEConfig(n_routed=8, n_shared=1, top_k=2, d_expert_ff=96,
                      n_dense_layers=1, dense_d_ff=256, score="sigmoid",
                      route_scale=2.5),
        mtp_depth=1,
    )
