"""chatglm3-6b [dense] — 28L d4096 32H (GQA kv 2) ff13696 vocab 65024, 2D RoPE.
[arXiv:2406.12793]"""
import dataclasses
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=65024, rope="rope2d", qkv_bias=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, dtype="float32", remat=False,
    )
