"""falcon-mamba-7b [ssm] — 64 Mamba-1 layers d4096 (attention-free),
ssm_state 16, vocab 65024. [arXiv:2410.05355]"""
import dataclasses
from ..models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm",
        n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=65024,
        ssm=SSMConfig(kind="mamba1", d_state=16, d_conv=4, expand=2),
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=3, d_model=64, vocab=256, dtype="float32", remat=False,
        ssm=SSMConfig(kind="mamba1", d_state=8, d_conv=4, expand=2, chunk=8),
    )
