"""Learning-rate schedules (pure functions of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    """Linear warmup to peak, cosine decay to floor."""
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = floor + (peak_lr - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


def exponential_decay(lr: float, decay_rate: float, decay_steps: int):
    def fn(step):
        return jnp.asarray(lr * decay_rate ** (jnp.asarray(step, jnp.float32) / decay_steps), jnp.float32)
    return fn
