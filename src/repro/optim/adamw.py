"""From-scratch AdamW with global-norm clipping and per-leaf update masks.

API mirrors the init/update transform style so the trainer stays functional:

    opt = AdamW(lr=schedule.warmup_cosine(...), weight_decay=0.1)
    state = opt.init(params)
    params, state = opt.apply(params, grads, state, mask=mask)

The `mask` pytree (True = update) is how Instant-3D's *different update
frequencies* (paper §3.3) reach the optimizer: on color-frozen iterations the
color grid's moments and parameters are left untouched, exactly like the
accelerator skipping that branch's back-propagation.

Moments are kept in f32 regardless of param dtype (bf16-safe); per-parameter
lr scaling supports Instant-NGP's grid-vs-MLP lr split.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    m: Any             # pytree like params, f32
    v: Any             # pytree like params, f32


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


class AdamW:
    def __init__(
        self,
        lr: float | Callable,
        b1: float = 0.9,
        b2: float = 0.999,
        eps: float = 1e-8,
        weight_decay: float = 0.0,
        clip_norm: float | None = None,
        lr_scale_fn: Callable[[tuple], float] | None = None,
    ):
        """lr may be a float or a step->lr schedule.  lr_scale_fn maps a leaf
        path (tuple of keys) to a multiplicative lr factor (e.g. hash grids
        at 1.0, MLPs at 0.1 as in Instant-NGP)."""
        self.lr = lr if callable(lr) else (lambda step, _lr=lr: jnp.asarray(_lr, jnp.float32))
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm
        self.lr_scale_fn = lr_scale_fn

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def apply(self, params, grads, state: AdamWState, mask=None):
        """Returns (new_params, new_state).  mask: pytree of bools, True=update."""
        if self.clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)

        step = state.step + 1
        lr_t = self.lr(step)
        b1, b2 = self.b1, self.b2
        bias1 = 1.0 - b1 ** step.astype(jnp.float32)
        bias2 = 1.0 - b2 ** step.astype(jnp.float32)

        if mask is None:
            mask = jax.tree.map(lambda _: True, params)

        paths_scales = None
        if self.lr_scale_fn is not None:
            flat, _ = jax.tree_util.tree_flatten_with_path(params)
            # normalize DictKey/SequenceKey entries to plain strings
            as_str = lambda k: str(getattr(k, "key", getattr(k, "idx", k)))
            paths_scales = [
                self.lr_scale_fn(tuple(as_str(k) for k in path)) for path, _ in flat
            ]

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_mask = treedef.flatten_up_to(mask)

        new_p, new_m, new_v = [], [], []
        for i, (p, g, m, v, upd) in enumerate(zip(flat_p, flat_g, flat_m, flat_v, flat_mask)):
            g32 = g.astype(jnp.float32)
            m1 = b1 * m + (1 - b1) * g32
            v1 = b2 * v + (1 - b2) * jnp.square(g32)
            scale = paths_scales[i] if paths_scales is not None else 1.0
            update = lr_t * scale * (m1 / bias1) / (jnp.sqrt(v1 / bias2) + self.eps)
            if self.weight_decay:
                update = update + lr_t * scale * self.weight_decay * p.astype(jnp.float32)
            p1 = (p.astype(jnp.float32) - update).astype(p.dtype)
            # masked leaves keep params AND moments frozen (branch skipped)
            new_p.append(jnp.where(upd, p1, p))
            new_m.append(jnp.where(upd, m1, m))
            new_v.append(jnp.where(upd, v1, v))

        return (
            treedef.unflatten(new_p),
            AdamWState(step, treedef.unflatten(new_m), treedef.unflatten(new_v)),
        )
