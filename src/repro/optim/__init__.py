from .adamw import AdamW, AdamWState, clip_by_global_norm, global_norm  # noqa: F401
from . import schedule  # noqa: F401
