"""repro — Instant-3D (ISCA'23) on TPU: JAX/Pallas training framework.

Layers:
    repro.core      — the paper's contribution (decomposed hash-grid NeRF training)
    repro.kernels   — Pallas TPU kernels + pure-jnp oracles
    repro.models    — LM model zoo (10 assigned architectures)
    repro.parallel  — mesh axes + partition rules (DP/FSDP/TP/EP/SP)
    repro.optim     — AdamW, schedules, grad compression
    repro.checkpoint— atomic/async/elastic checkpointing
    repro.runtime   — fault-tolerant training driver
    repro.data      — procedural scenes, ray sampler, LM token streams
    repro.configs   — architecture + shape registries
    repro.launch    — production mesh, dry-run, roofline, train/serve entries
"""

__version__ = "1.0.0"
