"""Pallas kernels for the one-kernel training step (fwd + hand-written bwd).

Forward (`fused_step_pallas`) — grid (point-block, level), level innermost:

* each (block, level) step streams ONE level table per grid HBM->VMEM and
  runs the segment-sum dedup encode: the block's corner-address stream is
  sorted, duplicate runs are collapsed, each point's trilinear weights are
  segment-summed at the unique in-block addresses, and the level's features
  come out of a dense (B, B*8) x (B*8, F) matmul against the uniquely
  gathered rows — the FMU dedup as MXU *compute*, not just gather
  coalescing;
* the concatenated (B, L*F) feature blocks (one per grid) live in
  revisited VMEM output blocks across the level steps — the encode->MLP
  boundary never touches HBM;
* at the last level the 2-layer density MLP and 3-layer color MLP run as an
  in-kernel epilogue on the resident feature blocks, so the whole shade
  stage is ONE pallas_call.

Backward (`fused_step_bwd_pallas`) — grid (point-block,):

* the residual-policy "recompute" contract realized in-kernel: corner
  geometry, indices and features are re-derived from the stashed
  Morton-sorted points block; the (L,N,8) weight tensor and the index
  streams NEVER exist in HBM;
* MLP backward is hand-chained on the recomputed activations (matmul
  transposes on the MXU), producing weight-gradient partial sums that
  accumulate across blocks in revisited output blocks (zeroed at block 0,
  `+=` thereafter — the canonical pallas accumulation pattern);
* table gradients apply the in-block BUM: per (level, grid) the block's
  update stream is segment-merged at unique addresses and committed with
  one scatter per run into the VMEM-resident gradient table.

Interpret-mode notes: this container is CPU-only, so both kernels are
validated with interpret=True against the ref backend (allclose — the
dedup pre-sum and per-block accumulation reassociate float adds).  The
backward holds the full (L,T,F) gradient tables resident; a real-TPU
lowering at L=16/2^18 would tile the level axis like the forward does.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..hash_encode import kernel as he_kernel

DEFAULT_BLOCK_POINTS = 256

_MLP_D_KEYS = ("w1", "b1", "w2", "b2")
_MLP_C_KEYS = ("w1", "b1", "w2", "b2", "w3", "b3")


def _dedup_encode_block(table, idx, weights):
    """Segment-sum dedup encode for one (block, level, grid) step.

    table (T,F), idx (B,8) int32, weights (B,8) f32 -> (B,F) f32.
    Mirrors `ref.dedup_weight_matrix` exactly: sorted address runs, per-run
    representative gather, per-point weight pre-sum, dense reconstruction
    matmul.  Sentinel rows (weight 0) produce all-zero W rows.
    """
    b = idx.shape[0]
    m = b * 8
    flat = idx.reshape(-1)
    order = jnp.argsort(flat)
    sa = flat[order]
    is_start = jnp.concatenate([jnp.ones((1,), bool), sa[1:] != sa[:-1]])
    seg = jnp.cumsum(is_start) - 1
    uniq = jax.ops.segment_min(sa, seg, num_segments=m)
    uniq = jnp.minimum(uniq, jnp.max(flat))  # clamp empty-run INT32_MAX pads
    rows = table[uniq].astype(jnp.float32)  # (m, F): one gather per run
    pt = order // 8
    w_mat = jnp.zeros((b, m), jnp.float32).at[pt, seg].add(weights.reshape(-1)[order])
    return w_mat @ rows


def _mlp2_fwd(x, w1, b1, w2, b2):
    h1 = jnp.maximum(x @ w1.astype(jnp.float32) + b1, 0.0)
    return h1 @ w2.astype(jnp.float32) + b2


def _mlp3_fwd(x, w1, b1, w2, b2, w3, b3):
    h1 = jnp.maximum(x @ w1.astype(jnp.float32) + b1, 0.0)
    h2 = jnp.maximum(h1 @ w2.astype(jnp.float32) + b2, 0.0)
    return h2 @ w3.astype(jnp.float32) + b3


def _fused_step_kernel(res_ref, dd_ref, dc_ref, pts_ref, sh_ref, td_ref, tc_ref,
                       w1d_ref, b1d_ref, w2d_ref, b2d_ref,
                       w1c_ref, b1c_ref, w2c_ref, b2c_ref, w3c_ref, b3c_ref,
                       featd_ref, featc_ref, outd_ref, outc_ref):
    l = pl.program_id(1)
    num_l = pl.num_programs(1)
    f = td_ref.shape[-1]
    pts = pts_ref[...].astype(jnp.float32)

    # --- encode this level for both grids (shared corner geometry) ---
    idx_d, weights = he_kernel.corner_indices_block(
        pts, res_ref[0], dd_ref[0], td_ref.shape[1]
    )
    idx_c, _ = he_kernel.corner_indices_block(
        pts, res_ref[0], dc_ref[0], tc_ref.shape[1]
    )
    featd_ref[:, pl.ds(l * f, f)] = _dedup_encode_block(td_ref[0], idx_d, weights)
    featc_ref[:, pl.ds(l * f, f)] = _dedup_encode_block(tc_ref[0], idx_c, weights)

    # --- MLP epilogue on the VMEM-resident feature blocks ---
    @pl.when(l == num_l - 1)
    def _epilogue():
        hd = featd_ref[...]
        hc = featc_ref[...]
        outd_ref[...] = _mlp2_fwd(hd, w1d_ref[...], b1d_ref[...],
                                  w2d_ref[...], b2d_ref[...])
        cin = jnp.concatenate([hc, sh_ref[...].astype(jnp.float32)], axis=-1)
        outc_ref[...] = _mlp3_fwd(cin, w1c_ref[...], b1c_ref[...],
                                  w2c_ref[...], b2c_ref[...],
                                  w3c_ref[...], b3c_ref[...])


@functools.partial(jax.jit, static_argnames=("block_points", "interpret"))
def fused_step_pallas(points, sh, t_density, t_color, mlp_d: dict, mlp_c: dict,
                      resolutions, dense_d, dense_c, *,
                      block_points: int = DEFAULT_BLOCK_POINTS,
                      interpret: bool = True):
    """One-kernel forward.  points (N,3) sentinel-padded to block_points,
    sh (N,S); returns (out_d (N, 1+geo), raw_c (N,3)) f32."""
    n = points.shape[0]
    assert n % block_points == 0, (n, block_points)
    n_blocks = n // block_points
    num_l, td, f = t_density.shape
    tc = t_color.shape[1]
    s_dim = sh.shape[1]
    d_out = mlp_d["w2"].shape[1]

    def const2(a):  # whole array resident, revisited every step
        return pl.BlockSpec(a.shape, lambda i, l: (0,) * a.ndim)

    weights = [mlp_d[k] for k in _MLP_D_KEYS] + [mlp_c[k] for k in _MLP_C_KEYS]
    _, _, out_d, out_c = pl.pallas_call(
        _fused_step_kernel,
        grid=(n_blocks, num_l),
        in_specs=[
            pl.BlockSpec((1,), lambda i, l: (l,)),             # resolution
            pl.BlockSpec((1,), lambda i, l: (l,)),             # dense (density)
            pl.BlockSpec((1,), lambda i, l: (l,)),             # dense (color)
            pl.BlockSpec((block_points, 3), lambda i, l: (i, 0)),
            pl.BlockSpec((block_points, s_dim), lambda i, l: (i, 0)),
            pl.BlockSpec((1, td, f), lambda i, l: (l, 0, 0)),  # one level/step
            pl.BlockSpec((1, tc, f), lambda i, l: (l, 0, 0)),
        ] + [const2(w) for w in weights],
        out_specs=[
            # feature accumulators: revisited across the level axis, so the
            # concatenated (B, L*F) block stays VMEM-resident into the epilogue
            pl.BlockSpec((block_points, num_l * f), lambda i, l: (i, 0)),
            pl.BlockSpec((block_points, num_l * f), lambda i, l: (i, 0)),
            pl.BlockSpec((block_points, d_out), lambda i, l: (i, 0)),
            pl.BlockSpec((block_points, 3), lambda i, l: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, num_l * f), jnp.float32),
            jax.ShapeDtypeStruct((n, num_l * f), jnp.float32),
            jax.ShapeDtypeStruct((n, d_out), jnp.float32),
            jax.ShapeDtypeStruct((n, 3), jnp.float32),
        ],
        interpret=interpret,
    )(resolutions, dense_d, dense_c, points, sh, t_density, t_color, *weights)
    return out_d, out_c


def _fused_step_bwd_kernel(pts_ref, sh_ref, gd_ref, gc_ref,
                           res_ref, dd_ref, dc_ref, td_ref, tc_ref,
                           w1d_ref, b1d_ref, w2d_ref, b2d_ref,
                           w1c_ref, b1c_ref, w2c_ref, b2c_ref, w3c_ref, b3c_ref,
                           dtd_ref, dtc_ref,
                           dw1d_ref, db1d_ref, dw2d_ref, db2d_ref,
                           dw1c_ref, db1c_ref, dw2c_ref, db2c_ref,
                           dw3c_ref, db3c_ref, dsh_ref):
    i = pl.program_id(0)
    num_l = td_ref.shape[0]
    f = td_ref.shape[-1]
    pts = pts_ref[...].astype(jnp.float32)
    g_d = gd_ref[...].astype(jnp.float32)
    g_c = gc_ref[...].astype(jnp.float32)

    @pl.when(i == 0)
    def _zero_accumulators():
        for r in (dtd_ref, dtc_ref, dw1d_ref, db1d_ref, dw2d_ref, db2d_ref,
                  dw1c_ref, db1c_ref, dw2c_ref, db2c_ref, dw3c_ref, db3c_ref):
            r[...] = jnp.zeros(r.shape, r.dtype)

    # --- recompute corner geometry + features from the stashed points block
    # (the residual_policy="recompute" contract: no (L,N,8) weight loads) ---
    geom = []  # per level: (idx_d, idx_c, weights)
    hd_cols, hc_cols = [], []
    for l in range(num_l):
        idx_d, weights = he_kernel.corner_indices_block(
            pts, res_ref[l], dd_ref[l], td_ref.shape[1]
        )
        idx_c, _ = he_kernel.corner_indices_block(
            pts, res_ref[l], dc_ref[l], tc_ref.shape[1]
        )
        geom.append((idx_d, idx_c, weights))
        hd_cols.append(jnp.sum(
            weights[..., None] * td_ref[l][idx_d.reshape(-1)]
            .reshape(idx_d.shape + (f,)).astype(jnp.float32), axis=1))
        hc_cols.append(jnp.sum(
            weights[..., None] * tc_ref[l][idx_c.reshape(-1)]
            .reshape(idx_c.shape + (f,)).astype(jnp.float32), axis=1))
    hd = jnp.concatenate(hd_cols, axis=-1)
    hc = jnp.concatenate(hc_cols, axis=-1)

    # --- hand-chained MLP backward on recomputed activations ---
    w1d = w1d_ref[...].astype(jnp.float32)
    w2d = w2d_ref[...].astype(jnp.float32)
    z1d = hd @ w1d + b1d_ref[...]
    h1d = jnp.maximum(z1d, 0.0)
    g_h1d = jnp.where(z1d > 0, g_d @ w2d.T, 0.0)
    dw2d_ref[...] += h1d.T @ g_d
    db2d_ref[...] += jnp.sum(g_d, axis=0)
    dw1d_ref[...] += hd.T @ g_h1d
    db1d_ref[...] += jnp.sum(g_h1d, axis=0)
    g_hd = g_h1d @ w1d.T

    cin = jnp.concatenate([hc, sh_ref[...].astype(jnp.float32)], axis=-1)
    w1c = w1c_ref[...].astype(jnp.float32)
    w2c = w2c_ref[...].astype(jnp.float32)
    w3c = w3c_ref[...].astype(jnp.float32)
    z1c = cin @ w1c + b1c_ref[...]
    h1c = jnp.maximum(z1c, 0.0)
    z2c = h1c @ w2c + b2c_ref[...]
    h2c = jnp.maximum(z2c, 0.0)
    g_h2c = jnp.where(z2c > 0, g_c @ w3c.T, 0.0)
    g_h1c = jnp.where(z1c > 0, g_h2c @ w2c.T, 0.0)
    dw3c_ref[...] += h2c.T @ g_c
    db3c_ref[...] += jnp.sum(g_c, axis=0)
    dw2c_ref[...] += h1c.T @ g_h2c
    db2c_ref[...] += jnp.sum(g_h2c, axis=0)
    dw1c_ref[...] += cin.T @ g_h1c
    db1c_ref[...] += jnp.sum(g_h1c, axis=0)
    g_cin = g_h1c @ w1c.T
    g_hc = g_cin[:, : num_l * f]
    dsh_ref[...] = g_cin[:, num_l * f:]

    # --- table gradients: in-block BUM (segment-merge + one scatter per run)
    def commit(acc_ref, l, idx, g_feat, weights):
        b = idx.shape[0]
        m = b * 8
        upd = (weights[:, :, None] * g_feat[:, None, :]).reshape(-1, f)
        flat = idx.reshape(-1)
        order = jnp.argsort(flat)
        sa = flat[order]
        is_start = jnp.concatenate([jnp.ones((1,), bool), sa[1:] != sa[:-1]])
        seg = jnp.cumsum(is_start) - 1
        summed = jax.ops.segment_sum(upd[order], seg, num_segments=m)
        seg_idx = jax.ops.segment_min(sa, seg, num_segments=m)
        acc_ref[l, :, :] = acc_ref[l].at[seg_idx].add(summed, mode="drop")

    for l in range(num_l):
        idx_d, idx_c, weights = geom[l]
        commit(dtd_ref, l, idx_d, g_hd[:, l * f:(l + 1) * f], weights)
        commit(dtc_ref, l, idx_c, g_hc[:, l * f:(l + 1) * f], weights)


@functools.partial(jax.jit, static_argnames=("block_points", "interpret"))
def fused_step_bwd_pallas(points, sh, g_d, g_c, t_density, t_color,
                          mlp_d: dict, mlp_c: dict,
                          resolutions, dense_d, dense_c, *,
                          block_points: int = DEFAULT_BLOCK_POINTS,
                          interpret: bool = True):
    """Hand-written one-kernel backward.  Inputs padded like the forward
    (g rows zero on pad lanes); returns (d_t_density, d_t_color, d_mlp_d,
    d_mlp_c, d_sh)."""
    n = points.shape[0]
    assert n % block_points == 0, (n, block_points)
    n_blocks = n // block_points
    num_l, td, f = t_density.shape
    tc = t_color.shape[1]
    s_dim = sh.shape[1]
    d_out = mlp_d["w2"].shape[1]

    def block2(cols):
        return pl.BlockSpec((block_points, cols), lambda i: (i, 0))

    def const(a):
        shape = a.shape
        return pl.BlockSpec(shape, lambda i: (0,) * len(shape))

    weights = [mlp_d[k] for k in _MLP_D_KEYS] + [mlp_c[k] for k in _MLP_C_KEYS]
    acc_shape = [jax.ShapeDtypeStruct(t_density.shape, jnp.float32),
                 jax.ShapeDtypeStruct(t_color.shape, jnp.float32)] + [
        jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in weights
    ]
    outs = pl.pallas_call(
        _fused_step_bwd_kernel,
        grid=(n_blocks,),
        in_specs=[
            block2(3), block2(s_dim), block2(d_out), block2(3),
            const(resolutions), const(dense_d), const(dense_c),
            const(t_density), const(t_color),
        ] + [const(w) for w in weights],
        out_specs=[const(s) for s in acc_shape] + [block2(s_dim)],
        out_shape=acc_shape + [jax.ShapeDtypeStruct((n, s_dim), jnp.float32)],
        interpret=interpret,
    )(points, sh, g_d, g_c, resolutions, dense_d, dense_c,
      t_density, t_color, *weights)
    d_td, d_tc = outs[0], outs[1]
    wg = outs[2:12]
    d_mlp_d = dict(zip(_MLP_D_KEYS, wg[:4]))
    d_mlp_c = dict(zip(_MLP_C_KEYS, wg[4:]))
    return (d_td.astype(t_density.dtype), d_tc.astype(t_color.dtype),
            d_mlp_d, d_mlp_c, outs[12])
