"""Jitted public API for the one-kernel training step.

`make_fused_step(...)` returns a differentiable

    step(points, sh, t_density, t_color, mlp_d, mlp_c)
        -> (density head out (N, 1+geo), raw rgb (N, 3))

covering the whole shade stage of a decomposed field in ONE custom-VJP op:
shared corner geometry, both grid encodes, and both MLP heads.  On the ref
backend every primitive is the PR 3 chain's primitive (`fused_path.ref`
geometry + `fused_mlp.ref` MLPs), so forward values, table gradients and
MLP gradients are all bit-identical to `make_fused_encode` + `mlp_heads`;
on Pallas backends the forward runs `kernel.fused_step_pallas` (segment-sum
dedup + in-VMEM MLP epilogue) and the backward runs the hand-written
`kernel.fused_step_bwd_pallas`.

residual_policy — what the VJP keeps live between forward and backward:

* "stash": the PR 3 residual set — trilinear weights (L,N,8), two
  (L*N*8,) pre-sorted index streams per grid, and both feature blocks for
  the MLP pullback.  Backward does no geometry work at all.
* "recompute" (default): stash only the Morton-sorted INPUTS (points, sh,
  tables, MLP params — all aliases, nothing materialized) and re-derive
  geometry, streams and features in the backward.  Because the recompute
  runs exactly the forward's deterministic ops on exactly the same inputs,
  its gradients are BIT-identical to "stash" — the knob trades backward
  FLOPs for residual bandwidth, never numerics (property-tested on ref and
  pallas-interpret).  At production scale (L=16, 100k points) the stash set
  is hundreds of MB/step while the recompute set is just the live model —
  hence the default.  On Pallas backends the hand-written backward kernel
  recomputes in-VMEM under either policy (the residual set is identical);
  the knob only changes the ref/XLA path.

Table-gradient commits route through `grid_update.windowed_scatter_add`'s
stacked per-step form (each step is a one-row window; the F_D:F_C schedule
in trainer.py makes multi-row windows by freezing a branch's stream), which
is bit-identical to `merged_scatter_add` per stream by the shared
`_segment_commit` body.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from . import kernel as _kernel
from ..fused_path import ref as fp_ref
from ..hash_encode import ref as he_ref
from ..hash_encode import ops as he_ops
from ..grid_update import ops as gu_ops
from ...obs import trace as _trace

DEFAULT_BLOCK_POINTS = _kernel.DEFAULT_BLOCK_POINTS
RESIDUAL_POLICIES = ("stash", "recompute")


def make_fused_step(
    resolutions,
    table_sizes,
    n_features: int,
    *,
    residual_policy: str = "recompute",
    backend=None,
    merged_backward: bool = True,
    block_points: int = DEFAULT_BLOCK_POINTS,
) -> Callable:
    """Build the one-kernel step for fixed level geometry.

    resolutions: static per-level grid resolutions (shared by both grids).
    table_sizes: (T_density, T_color).
    Returns step(points (N,3), sh (N,S), t_d (L,Td,F), t_c (L,Tc,F),
                 mlp_d {w1,b1,w2,b2}, mlp_c {w1..b3}) -> (out_d, raw_c).

    Inherits every fused-path contract (Morton-ordered input, presorted
    commit invariant, PAD_SENTINEL padding) — see `fused_path.ops`.
    """
    if residual_policy not in RESIDUAL_POLICIES:
        raise ValueError(f"residual_policy must be one of {RESIDUAL_POLICIES}")
    from .. import resolve_backend
    be = resolve_backend(backend)
    resolutions = tuple(int(r) for r in resolutions)
    table_sizes = tuple(int(t) for t in table_sizes)
    assert len(table_sizes) == 2, "fused step covers decomposed fields (2 grids)"
    num_l = len(resolutions)
    dense_flags = tuple(
        tuple(bool(x) for x in he_ref.level_is_dense(np.asarray(resolutions), t))
        for t in table_sizes
    )

    def _geometry(points):
        corners, weights = fp_ref.corner_geometry(points, resolutions)
        idx = [
            fp_ref.level_indices(corners, resolutions, table_sizes[g], dense_flags[g])
            for g in range(2)
        ]
        return idx, weights

    def _forward(points, sh, tables, mlp_d, mlp_c):
        if be.use_pallas:
            pts, n = he_ops._pad_to(points, block_points)
            shp, _ = he_ops._pad_to(sh, block_points, fill=0.0)
            out_d, raw_c = _kernel.fused_step_pallas(
                pts, shp, tables[0], tables[1], mlp_d, mlp_c,
                jnp.asarray(resolutions, jnp.int32),
                jnp.asarray(dense_flags[0], jnp.int32),
                jnp.asarray(dense_flags[1], jnp.int32),
                block_points=block_points, interpret=be.interpret,
            )
            return out_d[:n], raw_c[:n]
        idx, weights = _geometry(points)
        hd = fp_ref.encode_from_indices(tables[0], idx[0], weights)
        hc = fp_ref.encode_from_indices(tables[1], idx[1], weights)
        return ref.mlp_heads(hd, hc, sh, mlp_d, mlp_c)

    def _table_grads(w_stack, streams, g_feats, protos):
        """PR 3 encode_bwd, committed through the stacked windowed form.

        Each grid's stream is a one-row window (W=1); `_segment_commit`
        sharing makes this bit-identical to `merged_scatter_add`.  The two
        grids stay SEPARATE commits so a frozen branch's whole chain
        (values + argsort) dead-code-eliminates out of the step.
        """
        grads = []
        for g in range(2):
            n = g_feats[g].shape[0]
            gg = g_feats[g].reshape(n, num_l, n_features).astype(jnp.float32)
            vals = (
                w_stack[:, :, :, None] * jnp.transpose(gg, (1, 0, 2))[:, :, None, :]
            ).reshape(-1, n_features)
            addr_sorted, order = streams[g]
            flat = jnp.zeros((num_l * table_sizes[g], n_features), jnp.float32)
            if merged_backward:
                flat = gu_ops.windowed_scatter_add(
                    flat, addr_sorted[None], vals[order][None],
                    presorted=True, backend=be,
                )
            else:
                flat = flat.at[addr_sorted].add(vals[order])
            grads.append(
                flat.reshape(num_l, table_sizes[g], n_features).astype(protos[g].dtype)
            )
        return grads

    def _plan_streams(idx):
        streams = []
        for g in range(2):
            addr = fp_ref.address_stream(idx[g], table_sizes[g])
            order = jnp.argsort(addr)
            streams.append((addr[order], order))
        return tuple(streams)

    @jax.custom_vjp
    def step(points, sh, t_density, t_color, mlp_d, mlp_c):
        # non-differentiated calls (pure renders) run the primal, not
        # step_fwd — span both so serve-side traces see the kernel too
        with _trace.span("kernels/fused_step/fwd", cat="kernels",
                         args={"policy": residual_policy, "backend": be.name}):
            return _forward(points, sh, (t_density, t_color), mlp_d, mlp_c)

    def step_fwd(points, sh, t_density, t_color, mlp_d, mlp_c):
        # host-side span: under jit this times the forward's trace (the
        # compile-side cost of the one-kernel step); with REPRO_OBS=jax the
        # jax.profiler annotation carries the name into XLA device traces
        with _trace.span("kernels/fused_step/fwd", cat="kernels",
                         args={"policy": residual_policy, "backend": be.name}):
            tables = (t_density, t_color)
            if be.use_pallas or residual_policy == "recompute":
                # Nothing but input aliases crosses to the backward; notably
                # the forward also SKIPS stream planning — pure renders pay
                # zero backward-prep cost, and a frozen grid's recomputed
                # plan is dead code in the backward.
                outs = _forward(points, sh, tables, mlp_d, mlp_c)
                return outs, (points, sh, tables, mlp_d, mlp_c, None)
            idx, weights = _geometry(points)
            hd = fp_ref.encode_from_indices(tables[0], idx[0], weights)
            hc = fp_ref.encode_from_indices(tables[1], idx[1], weights)
            outs = ref.mlp_heads(hd, hc, sh, mlp_d, mlp_c)
            protos = tuple(jnp.zeros((0,), t.dtype) for t in tables)
            stash = (jnp.stack(weights), _plan_streams(idx), hd, hc)
            return outs, (points, sh, protos, mlp_d, mlp_c, stash)

    def step_bwd(res, g_out):
        with _trace.span("kernels/fused_step/bwd", cat="kernels",
                         args={"policy": residual_policy, "backend": be.name}):
            return _step_bwd(res, g_out)

    def _step_bwd(res, g_out):
        points, sh, tables, mlp_d, mlp_c, stash = res
        if be.use_pallas:
            return _kernel_bwd(points, sh, tables, mlp_d, mlp_c, g_out)
        if stash is None:
            # recompute: same deterministic ops as the forward -> the
            # residual quantities are bit-equal to what "stash" kept.
            idx, weights = _geometry(points)
            hd = fp_ref.encode_from_indices(tables[0], idx[0], weights)
            hc = fp_ref.encode_from_indices(tables[1], idx[1], weights)
            w_stack, streams = jnp.stack(weights), _plan_streams(idx)
            protos = tuple(jnp.zeros((0,), t.dtype) for t in tables)
        else:
            w_stack, streams, hd, hc = stash
            protos = tables  # zero-size dtype carriers from the forward
        # MLP pullback: jax.vjp over the exact ref chain — the same autodiff
        # program the unfused path runs, fed the same (hd, hc, sh) values.
        _, mlp_vjp = jax.vjp(
            lambda hd_, hc_, sh_, md_, mc_: ref.mlp_heads(hd_, hc_, sh_, md_, mc_),
            hd, hc, sh, mlp_d, mlp_c,
        )
        g_hd, g_hc, g_sh, g_md, g_mc = mlp_vjp(g_out)
        g_td, g_tc = _table_grads(w_stack, streams, (g_hd, g_hc), protos)
        return (jnp.zeros_like(points), g_sh, g_td, g_tc, g_md, g_mc)

    def _kernel_bwd(points, sh, tables, mlp_d, mlp_c, g_out):
        pts, n = he_ops._pad_to(points, block_points)
        shp, _ = he_ops._pad_to(sh, block_points, fill=0.0)
        gd, _ = he_ops._pad_to(g_out[0], block_points, fill=0.0)
        gc, _ = he_ops._pad_to(g_out[1], block_points, fill=0.0)
        g_td, g_tc, g_md, g_mc, g_sh = _kernel.fused_step_bwd_pallas(
            pts, shp, gd, gc, tables[0], tables[1], mlp_d, mlp_c,
            jnp.asarray(resolutions, jnp.int32),
            jnp.asarray(dense_flags[0], jnp.int32),
            jnp.asarray(dense_flags[1], jnp.int32),
            block_points=block_points, interpret=be.interpret,
        )
        return (jnp.zeros_like(points), g_sh[:n], g_td, g_tc, g_md, g_mc)

    step.defvjp(step_fwd, step_bwd)
    return step
