"""Pure-jnp oracle for the one-kernel training step (encode -> MLP heads).

The fused compacted path (PR 3, `kernels/fused_path`) already shares corner
geometry across grids and pre-sorts the backward update stream, but it still
dispatches encode and the MLPs as separate ops and materializes the full
residual set — weights (L,N,8) plus two (L*N*8,) index streams per grid —
between forward and backward.  This module is the oracle for the next step
(ROADMAP item 2): ONE differentiable op spanning

    points, SH(dirs)  ->  hash-encode(density), hash-encode(color)
                      ->  density MLP (2-layer), color MLP (3-layer)
                      ->  (density head out (N, 1+geo), raw rgb (N, 3))

with the encode->MLP boundary never leaving the kernel on Pallas backends.

Everything here is composed from the existing oracles (`fused_path.ref`
geometry + `fused_mlp.ref` MLPs) with NO new math, so the fused step is
bit-identical to the PR 3 chain on the ref backend by construction — the
acceptance criterion the ops-level VJP is tested against.

`encode_block_dedup` is the oracle for the kernel's segment-sum dedup: the
per-block trilinear interpolation is re-expressed as  out = W @ T[uniq]
where W[p, u] segment-sums point p's trilinear weights at unique in-block
address u.  Dedup stops being a gather-coalescing trick and becomes a
*compute* structure — the table is gathered once per unique address and the
reconstruction is a dense (B, B*8) x (B*8, F) matmul (MXU work), which is
how the FMU win survives on hardware whose gathers don't coalesce.  It is
allclose (not bit-identical) to `encode_from_indices`: summing duplicate
weights before the multiply reassociates, the same tolerance class as the
Pallas kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..fused_path import ref as fp_ref
from ..fused_mlp import ref as mlp_ref


def mlp_heads(hd, hc, sh, mlp_d: dict, mlp_c: dict):
    """(density feats, color feats, SH feats) -> (density out, raw rgb).

    Exactly the op sequence `Field._mlp_heads` runs on the ref backend for a
    decomposed field (mlp2 on hd; mlp3 on concat([hc, sh])), so gradients
    through `jax.vjp(mlp_heads, ...)` are bit-identical to the unfused
    autodiff path.  Activations (trunc_exp / sigmoid) stay OUTSIDE the fused
    step, in the field, where the outer autodiff already handles them.
    """
    out_d = mlp_ref.mlp2(hd, mlp_d["w1"], mlp_d["b1"], mlp_d["w2"], mlp_d["b2"])
    cin = jnp.concatenate([hc, sh], axis=-1)
    raw_c = mlp_ref.mlp3(cin, mlp_c["w1"], mlp_c["b1"], mlp_c["w2"], mlp_c["b2"],
                         mlp_c["w3"], mlp_c["b3"])
    return out_d, raw_c


def fused_step_ref(points, sh, t_density, t_color, mlp_d: dict, mlp_c: dict,
                   resolutions, dense_d, dense_c):
    """Whole-step oracle: encode both grids + both MLP heads, shared geometry.

    points (N,3) Morton-ordered unit coords, sh (N, sh_dim) view encoding.
    Returns (out_d (N, 1+geo), raw_c (N, 3)).  Bit-identical to
    `make_fused_encode` + `mlp_heads` on the ref backend (same primitives).
    """
    corners, weights = fp_ref.corner_geometry(points, resolutions)
    idx_d = fp_ref.level_indices(corners, resolutions, t_density.shape[1], dense_d)
    idx_c = fp_ref.level_indices(corners, resolutions, t_color.shape[1], dense_c)
    hd = fp_ref.encode_from_indices(t_density, idx_d, weights)
    hc = fp_ref.encode_from_indices(t_color, idx_c, weights)
    return mlp_heads(hd, hc, sh, mlp_d, mlp_c)


def dedup_weight_matrix(idx: jnp.ndarray, weights: jnp.ndarray):
    """Segment-sum dedup plan for one (block, level, grid): (B,8) indices +
    trilinear weights -> (W (B, B*8), uniq (B*8,) clamped addresses).

    Sorting the block's flat corner-address stream groups duplicates into
    runs; run r's representative address is `uniq[r]` and W[p, r] is the SUM
    of point p's trilinear weights over its corners landing in run r.  Empty
    trailing runs get segment_min's INT32_MAX identity, clamped to row 0 —
    their W column is all zero, so the clamped gather contributes nothing
    (the same harmless-row-0 convention as PAD_SENTINEL lanes, whose zero
    weights already zero their W rows).
    """
    b = idx.shape[0]
    m = b * 8
    flat = idx.reshape(-1)
    order = jnp.argsort(flat)
    sa = flat[order]
    is_start = jnp.concatenate([jnp.ones((1,), bool), sa[1:] != sa[:-1]])
    seg = jnp.cumsum(is_start) - 1  # (m,) run id per sorted lane
    uniq = jax.ops.segment_min(sa, seg, num_segments=m)
    uniq = jnp.where(uniq >= 0, uniq, 0)  # guard; addresses are non-negative
    uniq = jnp.minimum(uniq, jnp.max(flat))  # clamp INT32_MAX pad runs
    pt = order // 8
    w_mat = jnp.zeros((b, m), jnp.float32).at[pt, seg].add(weights.reshape(-1)[order])
    return w_mat, uniq


def encode_block_dedup(points, tables, resolutions, table_size: int, dense_flags,
                       block_points: int = 256):
    """Segment-sum-dedup encode oracle: out = W @ T[uniq] per (block, level).

    Same signature family as `encode_from_indices` but computed the way the
    fused kernel computes it; allclose to the gather-per-corner form (the
    weight pre-sum reassociates float adds).  N must divide into blocks.
    """
    n = points.shape[0]
    assert n % block_points == 0, (n, block_points)
    corners, weights = fp_ref.corner_geometry(points, resolutions)
    idx_l = fp_ref.level_indices(corners, resolutions, table_size, dense_flags)
    outs = []
    for l in range(tables.shape[0]):
        per_block = []
        for s in range(0, n, block_points):
            w_mat, uniq = dedup_weight_matrix(
                idx_l[l][s:s + block_points], weights[l][s:s + block_points]
            )
            per_block.append(w_mat @ tables[l][uniq].astype(jnp.float32))
        outs.append(jnp.concatenate(per_block, axis=0))
    return jnp.concatenate(outs, axis=-1)


# --- residual accounting (static shapes, host-side) --------------------------

def residual_bytes(policy: str, n_points: int, n_levels: int, n_features: int,
                   table_sizes, sh_dim: int, mlp_d_params: int,
                   mlp_c_params: int, itemsize: int = 4) -> int:
    """Bytes held live between forward and backward for one fused step.

    Counts every array the custom VJP keeps reachable as a residual,
    including stashed *references to inputs* (they pin the buffer either
    way); what differs between policies is the non-input set:

    * "stash": weights (L,N,8) + two (L*N*8,) streams per grid + both
      feature blocks (N, L*F) + SH + MLP params.  Tables and points are NOT
      residuals — the backward never touches them.
    * "recompute": points + SH + tables + MLP params, nothing else — the
      backward re-derives geometry, streams and features from the inputs.

    Pure static arithmetic so benchmarks can report production-scale
    (L=16, N=100k) footprints without allocating them.
    """
    n, L, f = int(n_points), int(n_levels), int(n_features)
    grids = len(tuple(table_sizes))
    mlp = (int(mlp_d_params) + int(mlp_c_params)) * itemsize
    sh = n * int(sh_dim) * itemsize
    if policy == "stash":
        w_stack = L * n * 8 * itemsize
        streams = grids * 2 * (L * n * 8) * itemsize
        feats = grids * n * L * f * itemsize
        return w_stack + streams + feats + sh + mlp
    if policy == "recompute":
        points = n * 3 * itemsize
        tables = sum(L * int(t) * f for t in table_sizes) * itemsize
        return points + sh + tables + mlp
    raise ValueError(f"unknown residual_policy {policy!r}")
