"""One-kernel training step: fused encode -> MLP with a recompute-in-backward
residual policy.  See ops.make_fused_step."""
from . import ref, ops  # noqa: F401
