"""Pure-jnp oracle for the small NeRF MLPs (Instant-NGP Step 3-2).

Instant-NGP replaces vanilla NeRF's 10x256 MLP with tiny MLPs (<= 3 layers,
64 hidden units).  The density branch is 1 hidden layer -> 16 outputs (first
output is the density logit); the color branch is 2 hidden layers -> 3 RGB
channels.  The oracle is the autodiff path used in training; the Pallas kernel
(kernel.py) is the fused inference path (MLP-unit analogue, DESIGN.md §3).
"""
from __future__ import annotations

import jax.numpy as jnp


def mlp2(x, w1, b1, w2, b2):
    """x (N,Din) -> relu(x@w1+b1) @ w2 + b2, f32 accumulation."""
    h = jnp.maximum(x.astype(jnp.float32) @ w1.astype(jnp.float32) + b1, 0.0)
    return h @ w2.astype(jnp.float32) + b2


def mlp3(x, w1, b1, w2, b2, w3, b3):
    """Two hidden relu layers then a linear head."""
    h1 = jnp.maximum(x.astype(jnp.float32) @ w1.astype(jnp.float32) + b1, 0.0)
    h2 = jnp.maximum(h1 @ w2.astype(jnp.float32) + b2, 0.0)
    return h2 @ w3.astype(jnp.float32) + b3
