"""Jitted wrappers for the fused NeRF MLPs with backend routing + padding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref


def _pad_rows(x, multiple):
    n = x.shape[0]
    if n % multiple == 0:
        return x, n
    pad = multiple - n % multiple
    return jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)]), n


def mlp2(x, w1, b1, w2, b2, *, backend: str = "ref", block_rows: int = _kernel.DEFAULT_BLOCK_ROWS):
    if backend == "pallas":
        xp, n = _pad_rows(x, block_rows)
        out = _kernel.fused_mlp2(
            xp, w1, b1, w2, b2, block_rows=block_rows,
            interpret=jax.default_backend() != "tpu",
        )
        return out[:n]
    return ref.mlp2(x, w1, b1, w2, b2)


def mlp3(x, w1, b1, w2, b2, w3, b3, *, backend: str = "ref", block_rows: int = _kernel.DEFAULT_BLOCK_ROWS):
    if backend == "pallas":
        xp, n = _pad_rows(x, block_rows)
        out = _kernel.fused_mlp3(
            xp, w1, b1, w2, b2, w3, b3, block_rows=block_rows,
            interpret=jax.default_backend() != "tpu",
        )
        return out[:n]
    return ref.mlp3(x, w1, b1, w2, b2, w3, b3)
