"""Jitted wrappers for the fused NeRF MLPs with backend routing + padding.

Routing resolves through the `repro.kernels` KernelBackend registry:
`backend=None` uses the process default; strings ("ref", "pallas",
"pallas-interpret", "pallas-tpu", "auto") are accepted as explicit overrides.

The Pallas kernels are forward-only; to keep pallas backends trainable the
wrappers carry a custom VJP built by ONE shared `_make_mlp_op` (the 2- and
3-layer ops used to duplicate the whole fwd/bwd plumbing).  What the VJP
keeps live between forward and backward follows `residual_policy`:

* "recompute" (default): residuals are the op INPUTS only — the backward is
  `jax.vjp` of the jnp reference over them, re-running the forward chain.
  Nothing beyond the already-live inputs is stashed (in particular `x` and
  `w1` are kept once, as aliases, not copied per layer).
* "stash": additionally keep each hidden layer's PRE-activation (the
  smallest set that lets the backward skip every hidden-layer matmul — the
  relu masks and post-activations fall out elementwise).  The backward
  chains `jax.vjp` over the reference chain split at those stashed
  pre-activations; since the split pieces compose to the exact primitive
  sequence of the whole-chain reference, the gradients are BIT-identical to
  "recompute" — the policy trades residual bandwidth for backward FLOPs,
  never numerics.

A fused backward kernel is a future optimization — see ROADMAP.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref

RESIDUAL_POLICIES = ("stash", "recompute")


def _pad_rows(x, multiple):
    n = x.shape[0]
    if n % multiple == 0:
        return x, n
    pad = multiple - n % multiple
    return jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)]), n


def _resolve(backend):
    from .. import resolve_backend
    return resolve_backend(backend)


# the reference chains split at the pre-activations: mlp2 == _relu_lin(_lin(
# x, w1, b1), w2, b2) primitive-for-primitive (mlp3 adds one more _relu_lin),
# so chaining the pieces' jax.vjp at stashed pre-activations applies the same
# pullbacks, in the same order, to the same values as whole-chain jax.vjp.

def _lin(x, w, b):
    return x.astype(jnp.float32) @ w.astype(jnp.float32) + b


def _relu_lin(z, w, b):
    return _lin(jnp.maximum(z, 0.0), w, b)


@functools.lru_cache(maxsize=None)
def _make_mlp_op(n_layers: int, block_rows: int, interpret: bool,
                 residual_policy: str):
    """Custom-VJP pallas MLP op: op(x, w1, b1, ..., wN, bN) -> out.

    One builder for both depths (n_layers in {2, 3}); cached so every call
    site with the same static config shares one op instance (stable jit
    caches, no re-tracing).
    """
    if residual_policy not in RESIDUAL_POLICIES:
        raise ValueError(f"residual_policy must be one of {RESIDUAL_POLICIES}")
    ref_fn = ref.mlp2 if n_layers == 2 else ref.mlp3
    kernel_fn = _kernel.fused_mlp2 if n_layers == 2 else _kernel.fused_mlp3

    @jax.custom_vjp
    def op(x, *params):
        xp, n = _pad_rows(x, block_rows)
        out = kernel_fn(xp, *params, block_rows=block_rows, interpret=interpret)
        return out[:n]

    def op_fwd(x, *params):
        out = op(x, *params)
        if residual_policy == "recompute":
            return out, (None, (x, *params))
        zs = [_lin(x, params[0], params[1])]
        for i in range(1, n_layers - 1):
            zs.append(_relu_lin(zs[-1], params[2 * i], params[2 * i + 1]))
        return out, (tuple(zs), (x, *params))

    def op_bwd(res, g):
        zs, inputs = res
        if zs is None:
            _, vjp = jax.vjp(ref_fn, *inputs)
            return vjp(g)
        x, *params = inputs
        grads = [None] * len(inputs)
        for i in reversed(range(1, n_layers)):
            _, vjp = jax.vjp(_relu_lin, zs[i - 1], params[2 * i], params[2 * i + 1])
            g, grads[1 + 2 * i], grads[2 + 2 * i] = vjp(g)
        _, vjp = jax.vjp(_lin, x, params[0], params[1])
        grads[0], grads[1], grads[2] = vjp(g)
        return tuple(grads)

    op.defvjp(op_fwd, op_bwd)
    return op


def mlp2(x, w1, b1, w2, b2, *, backend=None,
         block_rows: int = _kernel.DEFAULT_BLOCK_ROWS,
         residual_policy: str = "recompute"):
    be = _resolve(backend)
    if be.use_pallas:
        op = _make_mlp_op(2, block_rows, be.interpret, residual_policy)
        return op(x, w1, b1, w2, b2)
    return ref.mlp2(x, w1, b1, w2, b2)


def mlp3(x, w1, b1, w2, b2, w3, b3, *, backend=None,
         block_rows: int = _kernel.DEFAULT_BLOCK_ROWS,
         residual_policy: str = "recompute"):
    be = _resolve(backend)
    if be.use_pallas:
        op = _make_mlp_op(3, block_rows, be.interpret, residual_policy)
        return op(x, w1, b1, w2, b2, w3, b3)
    return ref.mlp3(x, w1, b1, w2, b2, w3, b3)
