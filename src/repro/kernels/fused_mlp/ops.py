"""Jitted wrappers for the fused NeRF MLPs with backend routing + padding.

Routing resolves through the `repro.kernels` KernelBackend registry:
`backend=None` uses the process default; strings ("ref", "pallas",
"pallas-interpret", "pallas-tpu", "auto") are accepted as explicit overrides.

The Pallas kernels are forward-only; to keep pallas backends trainable the
wrappers carry a custom VJP whose backward is the autodiff of the jnp
reference (numerically the oracle gradient).  A fused backward kernel is a
future optimization — see ROADMAP.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref


def _pad_rows(x, multiple):
    n = x.shape[0]
    if n % multiple == 0:
        return x, n
    pad = multiple - n % multiple
    return jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)]), n


def _resolve(backend):
    from .. import resolve_backend
    return resolve_backend(backend)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _mlp2_pallas(x, w1, b1, w2, b2, block_rows, interpret):
    xp, n = _pad_rows(x, block_rows)
    out = _kernel.fused_mlp2(xp, w1, b1, w2, b2, block_rows=block_rows,
                             interpret=interpret)
    return out[:n]


def _mlp2_fwd(x, w1, b1, w2, b2, block_rows, interpret):
    return _mlp2_pallas(x, w1, b1, w2, b2, block_rows, interpret), (x, w1, b1, w2, b2)


def _mlp2_bwd(block_rows, interpret, res, g):
    _, vjp = jax.vjp(ref.mlp2, *res)
    return vjp(g)


_mlp2_pallas.defvjp(_mlp2_fwd, _mlp2_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8))
def _mlp3_pallas(x, w1, b1, w2, b2, w3, b3, block_rows, interpret):
    xp, n = _pad_rows(x, block_rows)
    out = _kernel.fused_mlp3(xp, w1, b1, w2, b2, w3, b3, block_rows=block_rows,
                             interpret=interpret)
    return out[:n]


def _mlp3_fwd(x, w1, b1, w2, b2, w3, b3, block_rows, interpret):
    out = _mlp3_pallas(x, w1, b1, w2, b2, w3, b3, block_rows, interpret)
    return out, (x, w1, b1, w2, b2, w3, b3)


def _mlp3_bwd(block_rows, interpret, res, g):
    _, vjp = jax.vjp(ref.mlp3, *res)
    return vjp(g)


_mlp3_pallas.defvjp(_mlp3_fwd, _mlp3_bwd)


def mlp2(x, w1, b1, w2, b2, *, backend=None, block_rows: int = _kernel.DEFAULT_BLOCK_ROWS):
    be = _resolve(backend)
    if be.use_pallas:
        return _mlp2_pallas(x, w1, b1, w2, b2, block_rows, be.interpret)
    return ref.mlp2(x, w1, b1, w2, b2)


def mlp3(x, w1, b1, w2, b2, w3, b3, *, backend=None, block_rows: int = _kernel.DEFAULT_BLOCK_ROWS):
    be = _resolve(backend)
    if be.use_pallas:
        return _mlp3_pallas(x, w1, b1, w2, b2, w3, b3, block_rows, be.interpret)
    return ref.mlp3(x, w1, b1, w2, b2, w3, b3)
