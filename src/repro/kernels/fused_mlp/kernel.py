"""Pallas TPU kernel: fused small-MLP forward (MLP-unit analogue).

The paper pairs a systolic-array MLP unit with an adder-tree unit for tiny
output channels.  On TPU the MXU *is* the systolic array; the win to port is
not the adder tree but the fusion: all layers of the 64-wide MLP execute in
one kernel with weights resident in VMEM, so activations never round-trip to
HBM between layers (tiny-cuda-nn's "fully fused MLP", TPU edition).

Blocking: grid over rows of x; weight operands use constant index maps so
they are loaded into VMEM once and reused across all row blocks.  Matmul
dims are zero-padded to MXU-friendly multiples of 128 by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 512


def _mlp2_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h = jnp.maximum(x @ w1_ref[...].astype(jnp.float32) + b1_ref[...], 0.0)
    o_ref[...] = (h @ w2_ref[...].astype(jnp.float32) + b2_ref[...]).astype(o_ref.dtype)


def _mlp3_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h1 = jnp.maximum(x @ w1_ref[...].astype(jnp.float32) + b1_ref[...], 0.0)
    h2 = jnp.maximum(h1 @ w2_ref[...].astype(jnp.float32) + b2_ref[...], 0.0)
    o_ref[...] = (h2 @ w3_ref[...].astype(jnp.float32) + b3_ref[...]).astype(o_ref.dtype)


def _full(shape):
    return pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_mlp2(x, w1, b1, w2, b2, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    n, d_in = x.shape
    h = w1.shape[1]
    d_out = w2.shape[1]
    assert n % block_rows == 0
    return pl.pallas_call(
        _mlp2_kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d_in), lambda i: (i, 0)),
            _full((d_in, h)), _full((1, h)),
            _full((h, d_out)), _full((1, d_out)),
        ],
        out_specs=pl.BlockSpec((block_rows, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d_out), jnp.float32),
        interpret=interpret,
    )(x, w1, b1.reshape(1, -1), w2, b2.reshape(1, -1))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_mlp3(x, w1, b1, w2, b2, w3, b3, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    n, d_in = x.shape
    h1 = w1.shape[1]
    h2 = w2.shape[1]
    d_out = w3.shape[1]
    assert n % block_rows == 0
    return pl.pallas_call(
        _mlp3_kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d_in), lambda i: (i, 0)),
            _full((d_in, h1)), _full((1, h1)),
            _full((h1, h2)), _full((1, h2)),
            _full((h2, d_out)), _full((1, d_out)),
        ],
        out_specs=pl.BlockSpec((block_rows, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d_out), jnp.float32),
        interpret=interpret,
    )(x, w1, b1.reshape(1, -1), w2, b2.reshape(1, -1), w3, b3.reshape(1, -1))
