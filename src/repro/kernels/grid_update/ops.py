"""Jitted wrappers for BUM-style merged grid updates.

`merged_scatter_add` is the production path: sort-by-address + run merge +
unique scatter.  It is mathematically identical to the naive duplicate
scatter-add (ref.py) but removes write collisions — the TPU analogue of the
paper's BUM unit (DESIGN.md §3).  On CPU the merge runs in pure XLA; on TPU
the commit stage can be served by the Pallas kernel (`use_pallas=True`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _kernel


def _sort_updates(idx: jnp.ndarray, vals: jnp.ndarray, table_size: int, pad_to: int | None,
                  presorted: bool = False):
    """Sort the update stream by address; pad with spill-row entries.

    presorted=True skips the argsort: the caller guarantees idx is already
    non-decreasing (e.g. the fused-path VJP, which emits the stream through
    the stable order computed once in its forward pass).  Because jnp.argsort
    is stable, sorting an already-sorted stream is the identity permutation,
    so both paths are bit-identical on sorted input.
    """
    if presorted:
        idx_s, vals_s = idx, vals
    else:
        order = jnp.argsort(idx)
        idx_s = idx[order]
        vals_s = vals[order]
    if pad_to is not None and idx.shape[0] % pad_to != 0:
        pad = pad_to - idx.shape[0] % pad_to
        idx_s = jnp.concatenate([idx_s, jnp.full((pad,), table_size, jnp.int32)])
        vals_s = jnp.concatenate([vals_s, jnp.zeros((pad,) + vals.shape[1:], vals.dtype)])
    return idx_s, vals_s


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "backend", "presorted"))
def merged_scatter_add(
    table: jnp.ndarray,
    idx: jnp.ndarray,
    vals: jnp.ndarray,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
    backend=None,
    presorted: bool = False,
) -> jnp.ndarray:
    """table (T,F) += vals (M,F) at rows idx (M,) with BUM-merged writes.

    The XLA segment-merge (default) is the production CPU path; `backend`
    (a `repro.kernels` registry name or KernelBackend) routes the commit
    stage to the Pallas kernel, overriding the use_pallas/interpret pair
    (kernel-level escape hatch kept for direct validation).

    presorted=True promises idx is already non-decreasing and skips the
    argsort — the BUM fast path for callers that control update order (the
    fused compacted-path VJP emits its table-gradient stream pre-sorted).
    """
    if backend is not None:
        from .. import resolve_backend
        be = resolve_backend(backend)
        use_pallas, interpret = be.use_pallas, be.interpret
    t = table.shape[0]
    if use_pallas:
        idx_s, vals_s = _sort_updates(idx, vals, t, _kernel.DEFAULT_BLOCK,
                                      presorted=presorted)
        return _kernel.bum_scatter_pallas(table, idx_s, vals_s, interpret=interpret)

    idx_s, vals_s = _sort_updates(idx, vals, t, None, presorted=presorted)
    m = idx_s.shape[0]
    is_start = jnp.concatenate([jnp.ones((1,), bool), idx_s[1:] != idx_s[:-1]])
    seg_id = jnp.cumsum(is_start) - 1  # (M,)
    summed = jax.ops.segment_sum(vals_s.astype(jnp.float32), seg_id, num_segments=m)
    # Representative address per run; empty trailing segments get INT32_MAX
    # from segment_min's identity and are dropped by the scatter.
    seg_idx = jax.ops.segment_min(idx_s, seg_id, num_segments=m)
    return table.at[seg_idx].add(summed.astype(table.dtype), mode="drop")


@jax.jit
def num_unique_addresses(idx: jnp.ndarray) -> jnp.ndarray:
    """How many unique table rows a batch of updates touches (Fig. 10 stat)."""
    s = jnp.sort(idx)
    return jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]]).sum()


@functools.partial(jax.jit, static_argnames=("window",))
def windowed_scatter_add(
    table: jnp.ndarray,
    idx: jnp.ndarray,
    vals: jnp.ndarray,
    *,
    window: int = 4096,
) -> jnp.ndarray:
    """BUM with the paper's *sliding window*: merge duplicates only within
    fixed-size windows of the update stream, then scatter each window's
    merged updates.

    This is the faithful adaptation for data-parallel settings
    (EXPERIMENTS.md §Perf iteration 3): a GLOBAL sort must materialize and
    gather every (update, d_model) vector across shards; windows bound the
    live set to (window x F) regardless of stream length, exactly like the
    paper's 16-deep CAM bounds SRAM — here the window is a shard's local
    batch.  Write count lands between naive (no merge) and global merge.
    """
    t, f = table.shape
    m = idx.shape[0]
    pad = (-m) % window
    if pad:
        idx = jnp.concatenate([idx, jnp.full((pad,), t, jnp.int32)])
        vals = jnp.concatenate([vals, jnp.zeros((pad, f), vals.dtype)])
    n_win = idx.shape[0] // window
    idx_w = idx.reshape(n_win, window)
    vals_w = vals.reshape(n_win, window, f).astype(jnp.float32)

    def merge_window(tbl, inp):
        wi, wv = inp
        order = jnp.argsort(wi)
        wi, wv = wi[order], wv[order]
        is_start = jnp.concatenate([jnp.ones((1,), bool), wi[1:] != wi[:-1]])
        seg = jnp.cumsum(is_start) - 1
        summed = jax.ops.segment_sum(wv, seg, num_segments=window)
        seg_idx = jax.ops.segment_min(wi, seg, num_segments=window)
        return tbl.at[seg_idx].add(summed.astype(tbl.dtype), mode="drop"), None

    out, _ = jax.lax.scan(merge_window, table, (idx_w, vals_w))
    return out
