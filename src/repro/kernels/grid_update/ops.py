"""Jitted wrappers for BUM-style merged grid updates.

`merged_scatter_add` is the production path: sort-by-address + run merge +
unique scatter.  It is mathematically identical to the naive duplicate
scatter-add (ref.py) but removes write collisions — the TPU analogue of the
paper's BUM unit (DESIGN.md §3).  On CPU the merge runs in pure XLA; on TPU
the commit stage can be served by the Pallas kernel (`use_pallas=True`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _kernel


def _sort_updates(idx: jnp.ndarray, vals: jnp.ndarray, table_size: int, pad_to: int | None,
                  presorted: bool = False):
    """Sort the update stream by address; pad with spill-row entries.

    presorted=True skips the argsort: the caller guarantees idx is already
    non-decreasing (e.g. the fused-path VJP, which emits the stream through
    the stable order computed once in its forward pass).  Because jnp.argsort
    is stable, sorting an already-sorted stream is the identity permutation,
    so both paths are bit-identical on sorted input.
    """
    if presorted:
        idx_s, vals_s = idx, vals
    else:
        order = jnp.argsort(idx)
        idx_s = idx[order]
        vals_s = vals[order]
    if pad_to is not None and idx.shape[0] % pad_to != 0:
        pad = pad_to - idx.shape[0] % pad_to
        idx_s = jnp.concatenate([idx_s, jnp.full((pad,), table_size, jnp.int32)])
        vals_s = jnp.concatenate([vals_s, jnp.zeros((pad,) + vals.shape[1:], vals.dtype)])
    return idx_s, vals_s


def _segment_commit(table: jnp.ndarray, idx_s: jnp.ndarray, vals_s: jnp.ndarray) -> jnp.ndarray:
    """Segment-merge an address-SORTED stream and scatter once per run.

    The single definition of the XLA merge body: `merged_scatter_add` calls
    it directly and the windowed/stacked commit scans it per window, so a
    one-window stacked commit is bit-identical to one merged commit by
    construction (same ops, same segment count).
    """
    m = idx_s.shape[0]
    is_start = jnp.concatenate([jnp.ones((1,), bool), idx_s[1:] != idx_s[:-1]])
    seg_id = jnp.cumsum(is_start) - 1  # (M,)
    summed = jax.ops.segment_sum(vals_s.astype(jnp.float32), seg_id, num_segments=m)
    # Representative address per run; empty trailing segments get INT32_MAX
    # from segment_min's identity and are dropped by the scatter.
    seg_idx = jax.ops.segment_min(idx_s, seg_id, num_segments=m)
    return table.at[seg_idx].add(summed.astype(table.dtype), mode="drop")


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "backend", "presorted"))
def merged_scatter_add(
    table: jnp.ndarray,
    idx: jnp.ndarray,
    vals: jnp.ndarray,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
    backend=None,
    presorted: bool = False,
) -> jnp.ndarray:
    """table (T,F) += vals (M,F) at rows idx (M,) with BUM-merged writes.

    The XLA segment-merge (default) is the production CPU path; `backend`
    (a `repro.kernels` registry name or KernelBackend) routes the commit
    stage to the Pallas kernel, overriding the use_pallas/interpret pair
    (kernel-level escape hatch kept for direct validation).

    presorted=True promises idx is already non-decreasing and skips the
    argsort — the BUM fast path for callers that control update order (the
    fused compacted-path VJP emits its table-gradient stream pre-sorted).
    """
    if backend is not None:
        from .. import resolve_backend
        be = resolve_backend(backend)
        use_pallas, interpret = be.use_pallas, be.interpret
    t = table.shape[0]
    if use_pallas:
        idx_s, vals_s = _sort_updates(idx, vals, t, _kernel.DEFAULT_BLOCK,
                                      presorted=presorted)
        return _kernel.bum_scatter_pallas(table, idx_s, vals_s, interpret=interpret)

    idx_s, vals_s = _sort_updates(idx, vals, t, None, presorted=presorted)
    return _segment_commit(table, idx_s, vals_s)


@jax.jit
def num_unique_addresses(idx: jnp.ndarray) -> jnp.ndarray:
    """How many unique table rows a batch of updates touches (Fig. 10 stat)."""
    s = jnp.sort(idx)
    return jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]]).sum()


@functools.partial(jax.jit, static_argnames=("window", "presorted", "use_pallas",
                                              "interpret", "backend"))
def windowed_scatter_add(
    table: jnp.ndarray,
    idx: jnp.ndarray,
    vals: jnp.ndarray,
    *,
    window: int = 4096,
    presorted: bool = False,
    use_pallas: bool = False,
    interpret: bool = True,
    backend=None,
) -> jnp.ndarray:
    """BUM with the paper's *sliding window*: merge duplicates only within
    windows of the update stream, then commit each window's merged updates
    in stream order.

    Two window shapes are supported:

    * idx (M,) — legacy fixed-size chunking: one long stream is cut into
      `window`-sized pieces.  The faithful adaptation for data-parallel
      settings (EXPERIMENTS.md §Perf iteration 3): a GLOBAL sort must
      materialize and gather every (update, d_model) vector across shards;
      windows bound the live set to (window x F) regardless of stream
      length, exactly like the paper's 16-deep CAM bounds SRAM.
    * idx (W, M) with vals (W, M, F) — *stacked per-step streams*, the real
      BUM-across-iterations analogue: each row is one training step's
      gradient stream (e.g. the color grid's updates accumulated across an
      F_D:F_C update-frequency window), and the whole window commits as one
      `lax.scan` of the shared `_segment_commit` merge body in step order.
      Because each scan iteration runs exactly the ops `merged_scatter_add`
      would run for that step, the windowed commit is BIT-identical to W
      sequential per-step commits — additivity buys merging, not
      reassociation (property-tested across the {1:1, 1:0.5, 1:0.25}
      schedules in tests/test_grid_update.py).

    presorted=True promises every row of idx is already non-decreasing and
    skips the per-window argsort (the fused-step VJP emits rows through the
    stable order its forward — or recompute-policy backward — planned).
    `backend` routes each window's commit stage to the Pallas kernel, same
    contract as `merged_scatter_add`.
    """
    if backend is not None:
        from .. import resolve_backend
        be = resolve_backend(backend)
        use_pallas, interpret = be.use_pallas, be.interpret
    t = table.shape[0]
    f = table.shape[1]

    if idx.ndim == 1:
        m = idx.shape[0]
        pad = (-m) % window
        if pad:
            idx = jnp.concatenate([idx, jnp.full((pad,), t, jnp.int32)])
            vals = jnp.concatenate([vals, jnp.zeros((pad, f), vals.dtype)])
        n_win = idx.shape[0] // window
        idx = idx.reshape(n_win, -1)
        vals = vals.reshape(n_win, -1, f)

    vals = vals.astype(jnp.float32)

    def commit_window(tbl, inp):
        wi, wv = inp
        if not presorted:
            order = jnp.argsort(wi)
            wi, wv = wi[order], wv[order]
        if use_pallas:
            wi, wv = _sort_updates(wi, wv, t, _kernel.DEFAULT_BLOCK, presorted=True)
            return _kernel.bum_scatter_pallas(tbl, wi, wv, interpret=interpret), None
        return _segment_commit(tbl, wi, wv), None

    # Small static window counts (every per-step caller: the fused-step VJP
    # commits W=1; the F_D:F_C schedules make W<=4) unroll to straightline
    # code — a length-1 lax.scan still lowers to an XLA while loop that
    # dynamic-slices the whole stream per trip.  Same body, same order, so
    # the result stays bit-identical to the scan.
    if idx.shape[0] <= 8:
        out = table
        for w in range(idx.shape[0]):
            out, _ = commit_window(out, (idx[w], vals[w]))
        return out
    out, _ = jax.lax.scan(commit_window, table, (idx, vals))
    return out
