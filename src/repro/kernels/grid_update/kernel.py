"""Pallas TPU kernel: BUM — Back-propagation Update Merger (TPU adaptation).

The paper's BUM is a CAM-like buffer that merges SRAM writes to the same hash
address within a sliding window before committing them.  The TPU has no CAM;
the idiomatic equivalent (DESIGN.md §3) is:

    sort updates by address  ->  merge runs of equal addresses  ->  one
    scatter per unique address.

The sort happens once in XLA (`ops.merged_scatter_add`); this kernel performs
the *merge + commit* stage on sorted input:

* grid steps walk the sorted update stream in blocks (the "sliding window",
  except the window is a whole VMEM block — strictly stronger merging than
  the paper's 16-deep buffer);
* run detection is a shifted compare; the per-run sums are computed with a
  one-hot matmul (segment-id one-hot  @  values), putting the accumulation on
  the MXU instead of a serial CAM;
* each block commits at most one write per unique address; the output table
  is input/output-aliased and blocks accumulate sequentially (TPU grid order
  is sequential, so read-modify-write across steps is sound).

Cross-block duplicate addresses (a run straddling a block edge) cost one
extra commit — same behaviour as the paper's BUM when a run exceeds the
buffer depth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 512


def _bum_kernel(idx_ref, val_ref, tbl_ref, out_ref):
    b = idx_ref.shape[0]
    t_plus_1 = out_ref.shape[0]
    idx = idx_ref[...]  # (B,) int32, sorted; padding rows carry idx == T
    vals = val_ref[...].astype(jnp.float32)  # (B, F)

    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = tbl_ref[...]

    # Run detection on the sorted stream.
    prev = jnp.concatenate([idx[:1] - 1, idx[:-1]])
    is_start = idx != prev  # (B,) — first row of each equal-address run
    seg_id = jnp.cumsum(is_start.astype(jnp.int32)) - 1  # (B,) in [0, B)

    # One-hot matmul segment sum: (B, B) @ (B, F) on the MXU.
    one_hot = (seg_id[None, :] == jnp.arange(b, dtype=jnp.int32)[:, None]).astype(
        jnp.float32
    )
    seg_sums = one_hot @ vals  # (B, F), row s = sum of run s

    # Commit one write per run start; non-starts write +0 to the spill row T.
    write_vals = jnp.where(is_start[:, None], seg_sums[seg_id], 0.0)
    write_idx = jnp.where(is_start, idx, t_plus_1 - 1)

    out_ref[write_idx] = out_ref[write_idx] + write_vals.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def bum_scatter_pallas(
    table: jnp.ndarray,
    idx_sorted: jnp.ndarray,
    vals_sorted: jnp.ndarray,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    """Merged scatter-add of a sorted update stream into table (T, F).

    idx_sorted (M,) int32 ascending; padding entries must equal T (spill row).
    vals_sorted (M, F).  M must be a multiple of `block`.
    Returns the updated (T, F) table.
    """
    t, f = table.shape
    m = idx_sorted.shape[0]
    assert m % block == 0, (m, block)

    table_ext = jnp.concatenate(
        [table.astype(jnp.float32), jnp.zeros((1, f), jnp.float32)], axis=0
    )
    out = pl.pallas_call(
        _bum_kernel,
        grid=(m // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, f), lambda i: (i, 0)),
            pl.BlockSpec((t + 1, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t + 1, f), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t + 1, f), jnp.float32),
        interpret=interpret,
    )(idx_sorted, vals_sorted, table_ext)
    return out[:t].astype(table.dtype)
