"""Pure-jnp oracle for embedding-grid gradient updates (naive scatter-add).

This is what the paper's BUM unit replaces: during back-propagation every
queried point writes 8 corner updates into the hash table, and many of those
writes hit the *same* table entry (paper Fig. 10: ~200 unique addresses per
1000 consecutive accesses).  The oracle applies them as a plain duplicate
scatter-add — on TPU, XLA serializes colliding scatter updates, which is the
analogue of the SRAM write pressure the BUM removes.
"""
from __future__ import annotations

import jax.numpy as jnp


def scatter_add(table: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """table (T,F) += vals (M,F) at rows idx (M,), duplicates accumulated."""
    return table.at[idx].add(vals.astype(table.dtype))


def unique_fraction(idx: jnp.ndarray, window: int = 1000) -> jnp.ndarray:
    """Mean fraction of unique addresses per sliding window (paper Fig. 10 stat)."""
    m = idx.shape[0]
    n_win = max(m // window, 1)
    idx = idx[: n_win * window].reshape(n_win, window)
    s = jnp.sort(idx, axis=1)
    uniq = jnp.concatenate(
        [jnp.ones((n_win, 1), bool), s[:, 1:] != s[:, :-1]], axis=1
    ).sum(axis=1)
    return jnp.mean(uniq / window)
