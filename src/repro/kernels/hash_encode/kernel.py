"""Pallas TPU kernel for multiresolution hash-grid encoding.

TPU adaptation of the paper's grid cores + FRM unit (DESIGN.md §3):

* Each level's full hash table lives in VMEM (<= 2^18 x 2 x f32 = 2 MB per
  level, far below the 16 MB/core VMEM budget) — the analogue of the paper's
  on-chip multi-bank SRAM hash-table storage.
* Points are processed in VREG-aligned blocks; all 8 corner reads of a block
  are issued as one vectorized gather per level — the batch-granularity
  analogue of the FRM mapping many single reads into one multi-bank access.
* The grid iterates (point-block, level); BlockSpec index maps stream one
  level table at a time HBM->VMEM, so the VMEM working set is
  |table_level| + |point block| + |out block| regardless of L.
* Level geometry (resolution, dense flag) is carried in tiny (L,) arrays whose
  per-step (1,)-blocks behave like scalar prefetch.

Layout notes for real TPU lowering: the trailing feature dim F (typically 2)
is below the 128-lane width; production tables should be stored feature-major
padded to the lane width, or multiple levels packed per lane group.  The
kernel is written shape-generically and validated with interpret=True (this
container is CPU-only); `ops.py` routes to the jnp oracle on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_POINTS = 256


def corner_indices_block(pts, resolution, dense, t):
    """Shared in-kernel corner enumeration for one (point-block, level) step.

    pts (B,3) f32, resolution/dense scalars, t = table rows.  Returns
    (idx (B,8) int32, weights (B,8) f32) with sentinel rows (coordinate < 0,
    ops.PAD_SENTINEL padding) pinned to row 0 and zero weight — they must
    not hash into live table cells nor contribute output.  Used by both the
    plain hash_encode kernel and the fused-path kernel, so hashing/sentinel
    semantics cannot diverge between them.
    """
    valid = pts[:, 0] >= 0.0  # (B,)
    scaled = pts * resolution.astype(jnp.float32)
    base = jnp.floor(scaled)
    frac = scaled - base  # (B, 3)

    # Corner offsets {0,1}^3 generated in-kernel (Pallas kernels cannot
    # capture host constants): bit d of corner id c selects dim d's +1.
    cid = jax.lax.broadcasted_iota(jnp.int32, (8, 3), 0)
    dim = jax.lax.broadcasted_iota(jnp.int32, (8, 3), 1)
    offs = (cid >> dim) & 1  # (8, 3) int32; row c = (c&1, c>>1&1, c>>2&1) == ref.CORNERS
    corners = base.astype(jnp.int32)[:, None, :] + offs[None, :, :]  # (B, 8, 3)

    ix, iy, iz = corners[..., 0], corners[..., 1], corners[..., 2]
    # Dense index, computed in uint32 (wraps harmlessly when the level is
    # hashed and the product overflows — the `where` discards it).
    stride = (resolution + 1).astype(jnp.uint32)
    dense_idx = (
        ix.astype(jnp.uint32) + iy.astype(jnp.uint32) * stride
        + iz.astype(jnp.uint32) * stride * stride
    ).astype(jnp.int32)
    hash_idx = (
        (
            ix.astype(jnp.uint32) * ref.PI1
            ^ iy.astype(jnp.uint32) * ref.PI2
            ^ iz.astype(jnp.uint32) * ref.PI3
        )
        & jnp.uint32(t - 1)
    ).astype(jnp.int32)
    idx = jnp.where(dense > 0, dense_idx, hash_idx)  # (B, 8)
    idx = jnp.where(valid[:, None], idx, 0)  # sentinel rows read row 0 only

    offs_f = offs.astype(jnp.float32)  # (8, 3)
    w = jnp.where(offs_f[None, :, :] > 0, frac[:, None, :], 1.0 - frac[:, None, :])
    weights = jnp.prod(w, axis=-1) * valid.astype(jnp.float32)[:, None]  # (B, 8)
    return idx, weights


def _encode_kernel(res_ref, dense_ref, pts_ref, tbl_ref, out_ref):
    """One (point-block, level) grid step."""
    table = tbl_ref[0]  # (T, F)
    pts = pts_ref[...].astype(jnp.float32)  # (B, 3)
    idx, weights = corner_indices_block(pts, res_ref[0], dense_ref[0], table.shape[0])

    # FRM analogue: one vectorized gather for the whole block's 8 corners.
    feats = table[idx.reshape(-1)].reshape(idx.shape + (table.shape[-1],))

    out_ref[...] = jnp.sum(
        weights[..., None] * feats.astype(jnp.float32), axis=1
    )[:, None, :].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_points", "interpret"))
def hash_encode_pallas(
    points: jnp.ndarray,
    tables: jnp.ndarray,
    resolutions: jnp.ndarray,
    dense_flags: jnp.ndarray,
    *,
    block_points: int = DEFAULT_BLOCK_POINTS,
    interpret: bool = True,
) -> jnp.ndarray:
    """points (N,3) f32, tables (L,T,F), resolutions/dense_flags (L,) i32.

    Returns (N, L*F) f32.  N must be a multiple of block_points (ops.py pads).
    """
    n = points.shape[0]
    num_l, t, f = tables.shape
    assert n % block_points == 0, (n, block_points)
    n_blocks = n // block_points

    out = pl.pallas_call(
        _encode_kernel,
        grid=(n_blocks, num_l),
        in_specs=[
            pl.BlockSpec((1,), lambda i, l: (l,)),            # resolution scalar
            pl.BlockSpec((1,), lambda i, l: (l,)),            # dense flag scalar
            pl.BlockSpec((block_points, 3), lambda i, l: (i, 0)),
            pl.BlockSpec((1, t, f), lambda i, l: (l, 0, 0)),  # whole level in VMEM
        ],
        out_specs=pl.BlockSpec((block_points, 1, f), lambda i, l: (i, l, 0)),
        out_shape=jax.ShapeDtypeStruct((n, num_l, f), jnp.float32),
        interpret=interpret,
    )(resolutions, dense_flags, points, tables)
    return out.reshape(n, num_l * f)
