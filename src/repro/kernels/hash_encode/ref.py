"""Pure-jnp oracle for multiresolution hash-grid encoding (Instant-NGP Step 3-1).

This is the bottleneck step Instant-3D accelerates: for every queried 3D point,
fetch the embeddings of its 8 surrounding grid vertices from a 1D hash table
(paper Eq. 3) and trilinearly interpolate them.

Conventions
-----------
* points are in the unit cube [0, 1)^3, float32, shape (N, 3).
* tables has shape (L, T, F): L resolution levels, T hash-table entries per
  level, F features per entry.  T is a power of two.
* per-level resolution R_l: the grid at level l has (R_l + 1)^3 vertices.  If
  (R_l + 1)^3 <= T the level is indexed *densely* (no hashing, no collisions),
  otherwise via the spatial hash of Eq. 3:

      h(x, y, z) = (x * pi1  XOR  y * pi2  XOR  z * pi3)  mod  T
      pi1 = 1, pi2 = 2654435761, pi3 = 805459861

All level geometry (resolutions, dense-vs-hash flags) is static numpy — only
points and tables are traced.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

PI1 = np.uint32(1)
PI2 = np.uint32(2654435761)
PI3 = np.uint32(805459861)

# The 8 corner offsets of a unit cube, ordered 000, 001, ..., 111 (paper Fig. 3;
# bit k of the corner id selects dimension k's +1 offset: id = z<<2 | y<<1 | x).
CORNERS = np.array(
    [[x, y, z] for z in (0, 1) for y in (0, 1) for x in (0, 1)], dtype=np.int32
)  # (8, 3)


def level_resolutions(n_levels: int, base_resolution: int, max_resolution: int) -> np.ndarray:
    """Per-level grid resolutions N_l = floor(N_min * b^l) (Instant-NGP growth rule)."""
    if n_levels == 1:
        return np.array([base_resolution], dtype=np.int32)
    b = np.exp((np.log(max_resolution) - np.log(base_resolution)) / (n_levels - 1))
    return np.floor(base_resolution * b ** np.arange(n_levels) + 1e-6).astype(np.int32)


def level_is_dense(resolutions: np.ndarray, table_size: int) -> np.ndarray:
    """True where the level's full grid fits in the table (no hashing needed)."""
    r = np.asarray(resolutions, dtype=np.int64)
    return (r + 1) ** 3 <= np.int64(table_size)


def spatial_hash(ix: jnp.ndarray, iy: jnp.ndarray, iz: jnp.ndarray, table_size: int) -> jnp.ndarray:
    """Eq. 3 of the paper. int32 coords -> int32 table index in [0, T)."""
    h = (
        ix.astype(jnp.uint32) * PI1
        ^ iy.astype(jnp.uint32) * PI2
        ^ iz.astype(jnp.uint32) * PI3
    )
    return (h & jnp.uint32(table_size - 1)).astype(jnp.int32)


def dense_index(ix, iy, iz, resolution) -> jnp.ndarray:
    """Collision-free index for levels whose full grid fits in the table."""
    stride = resolution + 1
    return (ix + iy * stride + iz * stride * stride).astype(jnp.int32)


def corner_index(coords: jnp.ndarray, resolution: int, table_size: int, dense: bool) -> jnp.ndarray:
    """Table index for integer grid coords (..., 3) at one level (static geometry)."""
    ix, iy, iz = coords[..., 0], coords[..., 1], coords[..., 2]
    if dense:
        return dense_index(ix, iy, iz, resolution)
    return spatial_hash(ix, iy, iz, table_size)


def _level_corners(points: jnp.ndarray, resolution: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Corner integer coords and trilinear weights for one level.

    Returns (corners (N, 8, 3) int32, weights (N, 8) f32).
    """
    scaled = points.astype(jnp.float32) * resolution
    base = jnp.floor(scaled)
    frac = scaled - base  # (N, 3) in [0,1)
    corners = base.astype(jnp.int32)[:, None, :] + CORNERS[None, :, :]  # (N, 8, 3)
    # weight per corner: prod_d (frac_d if offset_d else 1 - frac_d)
    offs = jnp.asarray(CORNERS, dtype=jnp.float32)  # (8, 3)
    w = jnp.where(offs[None, :, :] > 0, frac[:, None, :], 1.0 - frac[:, None, :])
    return corners, jnp.prod(w, axis=-1)


def encode_level(points: jnp.ndarray, table: jnp.ndarray, resolution: int) -> jnp.ndarray:
    """Interpolated features for one level. points (N,3), table (T,F) -> (N,F)."""
    t = table.shape[0]
    dense = bool(level_is_dense(np.array([resolution]), t)[0])
    corners, weights = _level_corners(points, resolution)
    idx = corner_index(corners, resolution, t, dense)  # (N, 8)
    feats = table[idx]  # (N, 8, F) gather
    return jnp.sum(weights[..., None] * feats.astype(jnp.float32), axis=1)


def hash_encode(points: jnp.ndarray, tables: jnp.ndarray, resolutions) -> jnp.ndarray:
    """Full multiresolution encoding. points (N,3), tables (L,T,F) -> (N, L*F)."""
    outs = [
        encode_level(points, tables[l], int(resolutions[l]))
        for l in range(tables.shape[0])
    ]
    return jnp.concatenate(outs, axis=-1)


def hash_encode_vjp_tables(points, tables, resolutions, grad_out):
    """Oracle gradient w.r.t. tables via naive duplicate scatter-add.

    grad_out: (N, L*F).  Returns (L, T, F) float32.
    """
    n, _ = points.shape
    num_l, t, f = tables.shape
    g = grad_out.reshape(n, num_l, f)
    out = jnp.zeros((num_l, t, f), jnp.float32)
    for l in range(num_l):
        res = int(resolutions[l])
        dense = bool(level_is_dense(np.array([res]), t)[0])
        corners, weights = _level_corners(points, res)
        idx = corner_index(corners, res, t, dense)
        upd = weights[..., None] * g[:, l, None, :]  # (N, 8, F)
        out = out.at[l, idx.reshape(-1)].add(upd.reshape(-1, f))
    return out
