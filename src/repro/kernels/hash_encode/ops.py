"""Jitted public API for hash-grid encoding with BUM-merged backward.

`make_hash_encode(...)` returns a differentiable `encode(points, tables)`
whose custom VJP scatters table gradients through the BUM merge
(`kernels.grid_update.ops.merged_scatter_add`) instead of a naive duplicate
scatter-add.  All L levels are merged in one pass by offsetting level-l
addresses by l*T — a merge window covering the whole batch across levels,
strictly stronger than the paper's 16-deep per-core buffer.

Backend routing resolves through the `repro.kernels` KernelBackend registry
('ref' = pure jnp, the production CPU path and the autodiff oracle;
'pallas-interpret'/'pallas-tpu' = the Pallas kernel).  `backend=None` defers
to the process default at encoder-build time.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from . import kernel as _kernel
from ..grid_update import ops as grid_update_ops


# Padding sentinel for point batches that aren't a block multiple.  Real
# points live in [0,1)^3; sentinel rows are detected in-kernel (coordinate
# < 0), routed to table row 0 (one fixed address, no reads scattered into
# live cells) and masked to zero in the output.
PAD_SENTINEL = -1.0


def _pad_to(x: jnp.ndarray, multiple: int, fill=PAD_SENTINEL):
    n = x.shape[0]
    if n % multiple == 0:
        return x, n
    pad = multiple - n % multiple
    pad_block = jnp.full((pad,) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad_block]), n


def _forward(points, tables, resolutions, dense_flags, be, block_points: int):
    if isinstance(be, str) or be is None:  # accept registry names too
        from .. import resolve_backend
        be = resolve_backend(be)
    if be.use_pallas:
        pts, n = _pad_to(points, block_points)
        out = _kernel.hash_encode_pallas(
            pts,
            tables,
            jnp.asarray(resolutions, jnp.int32),
            jnp.asarray(dense_flags, jnp.int32),
            block_points=block_points,
            interpret=be.interpret,
        )
        return out[:n]
    return ref.hash_encode(points, tables, resolutions)


def _corner_updates(points, resolutions, dense_flags, table_size, grad):
    """Flattened (idx, val) update stream across all levels.

    grad: (N, L, F).  Returns idx (N*8*L,) int32 into the flat (L*T) table and
    vals (N*8*L, F) f32.
    """
    num_l = grad.shape[1]
    all_idx, all_val = [], []
    for l in range(num_l):
        res = int(resolutions[l])
        corners, weights = ref._level_corners(points, res)  # (N,8,3), (N,8)
        idx = ref.corner_index(corners, res, table_size, bool(dense_flags[l]))
        upd = weights[..., None] * grad[:, l, None, :]  # (N, 8, F)
        all_idx.append((idx + l * table_size).reshape(-1))
        all_val.append(upd.reshape(-1, grad.shape[-1]))
    return jnp.concatenate(all_idx), jnp.concatenate(all_val)


def make_hash_encode(
    resolutions,
    table_size: int,
    n_features: int,
    *,
    backend=None,
    merged_backward: bool = True,
    block_points: int = _kernel.DEFAULT_BLOCK_POINTS,
) -> Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]:
    """Build a differentiable multires hash encoder for fixed level geometry.

    resolutions: static per-level grid resolutions (from ref.level_resolutions).
    backend: registry name or None (process default, resolved at build time).
    Returns encode(points (N,3), tables (L,T,F)) -> (N, L*F) float32.
    """
    from .. import resolve_backend
    be = resolve_backend(backend)
    resolutions = tuple(int(r) for r in resolutions)
    dense_flags = tuple(
        bool(x) for x in ref.level_is_dense(np.asarray(resolutions), table_size)
    )
    num_l = len(resolutions)

    @jax.custom_vjp
    def encode(points, tables):
        return _forward(points, tables, resolutions, dense_flags, be, block_points)

    def encode_fwd(points, tables):
        out = _forward(points, tables, resolutions, dense_flags, be, block_points)
        # zero-size residual carries tables' dtype (dtypes aren't JAX types)
        return out, (points, jnp.zeros((0,), tables.dtype))

    def encode_bwd(res, g):
        points, tproto = res
        tdtype = tproto.dtype
        grad = g.reshape(points.shape[0], num_l, n_features).astype(jnp.float32)
        idx, vals = _corner_updates(points, resolutions, dense_flags, table_size, grad)
        flat = jnp.zeros((num_l * table_size, n_features), jnp.float32)
        if merged_backward:
            # commit stage follows the encoder's backend: pallas flavors use
            # the BUM scatter kernel, ref stays on the XLA segment merge
            flat = grid_update_ops.merged_scatter_add(flat, idx, vals, backend=be)
        else:
            flat = flat.at[idx].add(vals)
        grad_tables = flat.reshape(num_l, table_size, n_features).astype(tdtype)
        return jnp.zeros_like(points), grad_tables

    encode.defvjp(encode_fwd, encode_bwd)
    return encode


def access_stream(points, resolutions, dense_flags, table_size: int):
    """Forward-order corner address stream (paper Fig. 8-10 instrumentation).

    Not jitted — level geometry stays static python.  Returns (N*8*L,) int32
    addresses into the flat (L*T) table, in forward traversal order.
    """
    grad = jnp.ones((points.shape[0], len(resolutions), 1), jnp.float32)
    idx, _ = _corner_updates(points, tuple(resolutions), tuple(dense_flags), table_size, grad)
    return idx
