"""Pallas TPU kernels for the perf-critical compute of Instant-3D.

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper with backend routing), ref.py (pure-jnp oracle used both for
allclose validation and as the CPU/autodiff path).

Backend selection is centralized here in the `KernelBackend` registry: every
ops module resolves its routing through `resolve_backend(...)` instead of
carrying its own `backend: str` knob.  The one user-facing knob is the
process-wide default, set via `set_backend(...)`, the `REPRO_BACKEND` env
var, or left on "auto" (capability detection picks the best available).

Canonical backends:

  ref              pure jnp — CPU production path and the autodiff oracle
  pallas-interpret Pallas kernels in interpreter mode (validation on CPU)
  pallas-tpu       compiled Pallas kernels (requires a TPU jax backend)

Aliases accepted anywhere a backend name is taken: "pallas" (best pallas
flavor for the platform: tpu if available, else interpret) and "auto" (tpu
kernels on TPU, ref elsewhere).
"""
from __future__ import annotations

import os
from dataclasses import dataclass

import jax


@dataclass(frozen=True)
class KernelBackend:
    """Resolved routing decision shared by every ops module.

    use_pallas: route to the Pallas kernel (vs the jnp reference).
    interpret:  run the Pallas kernel in interpreter mode (non-TPU hosts).
    """
    name: str
    use_pallas: bool
    interpret: bool


REF = KernelBackend("ref", use_pallas=False, interpret=False)
PALLAS_INTERPRET = KernelBackend("pallas-interpret", use_pallas=True, interpret=True)
PALLAS_TPU = KernelBackend("pallas-tpu", use_pallas=True, interpret=False)

_CANONICAL = {b.name: b for b in (REF, PALLAS_INTERPRET, PALLAS_TPU)}


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - jax not initialized
        return False


def available_backends() -> tuple[str, ...]:
    """Capability detection: which canonical backends can run on this host."""
    names = ["ref"]
    try:
        from jax.experimental import pallas  # noqa: F401
        names.append("pallas-interpret")
        if _on_tpu():
            names.append("pallas-tpu")
    except ImportError:  # pragma: no cover - pallas ships with jax
        pass
    return tuple(names)


def resolve_backend(backend: str | KernelBackend | None = None) -> KernelBackend:
    """Map a user-facing name (or None => process default) to a KernelBackend."""
    if backend is None:
        return get_backend()
    if isinstance(backend, KernelBackend):
        return backend
    name = backend.lower()
    if name == "auto":
        return PALLAS_TPU if _on_tpu() else REF
    if name == "pallas":
        b = PALLAS_TPU if _on_tpu() else PALLAS_INTERPRET
        if b.name not in available_backends():
            raise ValueError(
                f"backend 'pallas' resolves to {b.name!r}, unavailable on this "
                f"host; have {available_backends()}"
            )
        return b
    if name in _CANONICAL:
        b = _CANONICAL[name]
        if b.name not in available_backends():
            raise ValueError(
                f"backend {name!r} unavailable on this host; have {available_backends()}"
            )
        return b
    raise ValueError(
        f"unknown backend {backend!r}; expected one of "
        f"{tuple(_CANONICAL)} or aliases ('auto', 'pallas')"
    )


_default: KernelBackend | None = None


def get_backend() -> KernelBackend:
    """The process-wide default backend (the single user-facing knob)."""
    global _default
    if _default is None:
        _default = resolve_backend(os.environ.get("REPRO_BACKEND", "auto"))
    return _default


def set_backend(backend: str | KernelBackend) -> KernelBackend:
    """Set the process-wide default; returns the resolved KernelBackend.

    Binding times differ by op: hash-grid encoders bake routing (forward
    AND merged-backward) at construction, while MLP/composite ops resolve
    at trace time — and already-compiled jitted functions are never
    invalidated by this call.  Changing the backend mid-session therefore
    yields a mix of old and new routing; set it once, before building
    models or tracing any step function.
    """
    global _default
    _default = resolve_backend(backend)
    return _default


from . import hash_encode, grid_update, fused_mlp, volume_render, fused_path  # noqa: F401,E402
