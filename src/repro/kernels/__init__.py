"""Pallas TPU kernels for the perf-critical compute of Instant-3D.

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper with backend routing), ref.py (pure-jnp oracle used both for
allclose validation and as the CPU/autodiff path).
"""
from . import hash_encode, grid_update, fused_mlp, volume_render  # noqa: F401
