"""Jitted wrapper for volume rendering with backend routing + ray padding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref


def composite(sigma, rgb, deltas, ts, *, backend: str = "ref", block_rays: int = _kernel.DEFAULT_BLOCK_RAYS):
    """Render rays. 'ref' returns RenderOut (incl. weights, autodiff path);
    'pallas' returns RenderOut with weights=None (fused inference path)."""
    if backend == "pallas":
        r = sigma.shape[0]
        pad = (-r) % block_rays
        if pad:
            z = lambda x: jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
            sigma, rgb, deltas, ts = z(sigma), z(rgb), z(deltas), z(ts)
        color, depth, opac = _kernel.composite_pallas(
            sigma, rgb, deltas, ts, block_rays=block_rays,
            interpret=jax.default_backend() != "tpu",
        )
        return ref.RenderOut(color[:r], depth[:r], opac[:r], None)
    return ref.composite(sigma, rgb, deltas, ts)
