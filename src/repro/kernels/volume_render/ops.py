"""Jitted wrapper for volume rendering with backend routing + ray padding.

Routing resolves through the `repro.kernels` KernelBackend registry;
`backend=None` uses the process default.

The Pallas compositing kernel is forward-only (and does not materialize
per-sample weights); a custom VJP backs it with the autodiff of the jnp
reference so pallas backends stay trainable.  Callers needing `weights`
(e.g. distortion losses) should route that computation through 'ref'.

`deltas` is a first-class per-sample array on every backend (kernel and
ref alike): the adaptive sampler's variable-spacing quadrature flows
through the same entry point as the uniform sampler's diff-based widths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _composite_pallas(sigma, rgb, deltas, ts, block_rays, interpret):
    r = sigma.shape[0]
    pad = (-r) % block_rays
    if pad:
        z = lambda x: jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
        sigma, rgb, deltas, ts = z(sigma), z(rgb), z(deltas), z(ts)
    color, depth, opac = _kernel.composite_pallas(
        sigma, rgb, deltas, ts, block_rays=block_rays, interpret=interpret,
    )
    return color[:r], depth[:r], opac[:r]


def _composite_fwd(sigma, rgb, deltas, ts, block_rays, interpret):
    out = _composite_pallas(sigma, rgb, deltas, ts, block_rays, interpret)
    return out, (sigma, rgb, deltas, ts)


def _ref_cdo(sigma, rgb, deltas, ts):
    o = ref.composite(sigma, rgb, deltas, ts)
    return o.color, o.depth, o.opacity


def _composite_bwd(block_rays, interpret, res, g):
    _, vjp = jax.vjp(_ref_cdo, *res)
    return vjp(g)


_composite_pallas.defvjp(_composite_fwd, _composite_bwd)


def composite(sigma, rgb, deltas, ts, *, backend=None, block_rays: int = _kernel.DEFAULT_BLOCK_RAYS):
    """Render rays. 'ref' returns RenderOut (incl. weights, autodiff path);
    pallas backends return RenderOut with weights=None (fused kernel)."""
    from .. import resolve_backend
    be = resolve_backend(backend)
    if be.use_pallas:
        color, depth, opac = _composite_pallas(
            sigma, rgb, deltas, ts, block_rays, be.interpret
        )
        return ref.RenderOut(color, depth, opac, None)
    return ref.composite(sigma, rgb, deltas, ts)
