"""Pure-jnp oracle for volume rendering composition (paper Eq. 1, Step 4).

Given per-sample densities sigma_k, colors c_k and segment lengths delta_k
along each ray:

    alpha_k = 1 - exp(-sigma_k * delta_k)
    T_k     = exp(-sum_{j<k} sigma_j * delta_j)      (transmittance)
    w_k     = T_k * alpha_k
    C(r)    = sum_k w_k c_k

Also returns depth (= sum w_k t_k) and opacity (= sum w_k), used for the
paper's Fig. 5 depth-PSNR instrumentation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class RenderOut(NamedTuple):
    color: jnp.ndarray    # (R, 3)
    depth: jnp.ndarray    # (R,)
    opacity: jnp.ndarray  # (R,)
    weights: jnp.ndarray  # (R, S)


def composite(sigma: jnp.ndarray, rgb: jnp.ndarray, deltas: jnp.ndarray, ts: jnp.ndarray) -> RenderOut:
    """sigma (R,S), rgb (R,S,3), deltas (R,S), ts (R,S) -> RenderOut."""
    tau = sigma.astype(jnp.float32) * deltas.astype(jnp.float32)  # (R, S)
    cum = jnp.cumsum(tau, axis=-1)
    transmittance = jnp.exp(-(cum - tau))  # exclusive cumsum: T_k
    alpha = 1.0 - jnp.exp(-tau)
    weights = transmittance * alpha  # (R, S)
    color = jnp.sum(weights[..., None] * rgb.astype(jnp.float32), axis=-2)
    depth = jnp.sum(weights * ts.astype(jnp.float32), axis=-1)
    opacity = jnp.sum(weights, axis=-1)
    return RenderOut(color, depth, opacity, weights)
