"""Pure-jnp oracle for volume rendering composition (paper Eq. 1, Step 4).

Given per-sample densities sigma_k, colors c_k and segment lengths delta_k
along each ray:

    alpha_k = 1 - exp(-sigma_k * delta_k)
    T_k     = exp(-sum_{j<k} sigma_j * delta_j)      (transmittance)
    w_k     = T_k * alpha_k
    C(r)    = sum_k w_k c_k

Also returns depth (= sum w_k t_k) and opacity (= sum w_k), used for the
paper's Fig. 5 depth-PSNR instrumentation.

delta_k is per-sample, not a constant step: the quadrature is exact for any
partition, so callers may pass variable-spacing widths — the adaptive
sampler (pipeline stage 2b) feeds dt_k = live arc length represented by
sample k, under which dead gaps between occupancy segments contribute
exactly zero to the transmittance sum.  `uniform_deltas` builds the
uniform-sampler convention (diff, last stratum padded to the mean width).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class RenderOut(NamedTuple):
    color: jnp.ndarray    # (R, 3)
    depth: jnp.ndarray    # (R,)
    opacity: jnp.ndarray  # (R,)
    weights: jnp.ndarray  # (R, S)


def uniform_deltas(ts: jnp.ndarray, span: float) -> jnp.ndarray:
    """Uniform-sampler segment widths: diff(ts), last sample padded with the
    mean stratum width span/S.  ts (R,S), span = far - near."""
    s = ts.shape[-1]
    return jnp.diff(ts, axis=-1, append=ts[..., -1:] + span / s)


def composite(sigma: jnp.ndarray, rgb: jnp.ndarray, deltas: jnp.ndarray, ts: jnp.ndarray) -> RenderOut:
    """sigma (R,S), rgb (R,S,3), deltas (R,S), ts (R,S) -> RenderOut.

    deltas may be any positive per-sample widths (see module docstring);
    uniform and adaptive partitions share this one compositor."""
    tau = sigma.astype(jnp.float32) * deltas.astype(jnp.float32)  # (R, S)
    cum = jnp.cumsum(tau, axis=-1)
    transmittance = jnp.exp(-(cum - tau))  # exclusive cumsum: T_k
    alpha = 1.0 - jnp.exp(-tau)
    weights = transmittance * alpha  # (R, S)
    color = jnp.sum(weights[..., None] * rgb.astype(jnp.float32), axis=-2)
    depth = jnp.sum(weights * ts.astype(jnp.float32), axis=-1)
    opacity = jnp.sum(weights, axis=-1)
    return RenderOut(color, depth, opacity, weights)
