"""Pallas TPU kernel: ray-march composition (Eq. 1) over ray blocks.

Rays are independent, so the kernel blocks over rays and keeps a whole ray's
sample axis resident in VMEM; the transmittance prefix product is a cumsum on
the VPU.  This keeps the (R, S) intermediates out of HBM — the rendering
analogue of the accelerator doing Step 4 on-chip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_RAYS = 128


def _composite_kernel(sigma_ref, rgb_ref, deltas_ref, ts_ref, color_ref, depth_ref, opac_ref):
    tau = sigma_ref[...].astype(jnp.float32) * deltas_ref[...].astype(jnp.float32)
    cum = jnp.cumsum(tau, axis=-1)
    transmittance = jnp.exp(-(cum - tau))
    alpha = 1.0 - jnp.exp(-tau)
    weights = transmittance * alpha  # (B, S)
    color_ref[...] = jnp.sum(
        weights[..., None] * rgb_ref[...].astype(jnp.float32), axis=-2
    ).astype(color_ref.dtype)
    depth_ref[...] = jnp.sum(
        weights * ts_ref[...].astype(jnp.float32), axis=-1, keepdims=True
    ).astype(depth_ref.dtype)
    opac_ref[...] = jnp.sum(weights, axis=-1, keepdims=True).astype(opac_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rays", "interpret"))
def composite_pallas(sigma, rgb, deltas, ts, *, block_rays: int = DEFAULT_BLOCK_RAYS, interpret: bool = True):
    """sigma (R,S), rgb (R,S,3), deltas (R,S), ts (R,S) -> (color, depth, opacity)."""
    r, s = sigma.shape
    assert r % block_rays == 0
    grid = (r // block_rays,)
    color, depth, opac = pl.pallas_call(
        _composite_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rays, s), lambda i: (i, 0)),
            pl.BlockSpec((block_rays, s, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_rays, s), lambda i: (i, 0)),
            pl.BlockSpec((block_rays, s), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rays, 3), lambda i: (i, 0)),
            pl.BlockSpec((block_rays, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rays, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, 3), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=interpret,
    )(sigma, rgb, deltas, ts)
    return color, depth[:, 0], opac[:, 0]
