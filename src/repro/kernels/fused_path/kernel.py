"""Pallas kernel for the fused compacted-path encode (FMU-style block dedup).

Differences from the plain `hash_encode` kernel (which issues one vectorized
gather of all B*8 corner addresses in point order):

* The caller feeds Morton-sorted points, so a block's corner addresses are
  quasi-sorted and heavily duplicated (points in one grid cell share all 8
  corners).  The kernel sorts the block's address vector and gathers in that
  order — duplicate addresses become *adjacent* lanes of one gather, which
  is the memory-system shape the FMU exploits: one bank read broadcast to
  every lane of a run.  On TPU the sorted gather turns random VMEM banking
  into sequential runs; in interpret mode it is numerically identical to the
  unsorted gather (same rows fetched).
* Corner features are staged entirely in VMEM registers — the (B, 8, F)
  per-point corner tensor never exists in HBM; only the (B, F) per-level
  output block is written out.
* Sentinel-padded rows (coordinate < 0, see hash_encode.ops.PAD_SENTINEL)
  read row 0 only and contribute exactly zero output.

Grid iterates (point-block, level) like the hash_encode kernel, one level
table resident in VMEM per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..hash_encode import kernel as he_kernel

DEFAULT_BLOCK_POINTS = 256


def _fused_encode_kernel(res_ref, dense_ref, pts_ref, tbl_ref, out_ref):
    """One (point-block, level) step with block-sorted (deduped) corner reads."""
    table = tbl_ref[0]  # (T, F)
    pts = pts_ref[...].astype(jnp.float32)  # (B, 3)
    # corner enumeration + sentinel semantics shared with the hash_encode
    # kernel — only the gather strategy below differs
    idx, weights = he_kernel.corner_indices_block(
        pts, res_ref[0], dense_ref[0], table.shape[0]
    )

    # FMU analogue: sort the block's corner addresses so duplicates occupy
    # adjacent lanes of ONE gather (a run of equal addresses = one coalesced
    # table read), then scatter the fetched rows back to point order.  All of
    # this stays in VMEM; the (B, 8, F) corner tensor never reaches HBM.
    flat = idx.reshape(-1)  # (B*8,)
    order = jnp.argsort(flat)
    feats_sorted = table[flat[order]]  # (B*8, F) — duplicate-adjacent reads
    feats = (
        jnp.zeros_like(feats_sorted)
        .at[order]
        .set(feats_sorted)
        .reshape(idx.shape + (table.shape[-1],))
    )

    out_ref[...] = jnp.sum(
        weights[..., None] * feats.astype(jnp.float32), axis=1
    )[:, None, :].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_points", "interpret"))
def fused_encode_pallas(
    points: jnp.ndarray,
    tables: jnp.ndarray,
    resolutions: jnp.ndarray,
    dense_flags: jnp.ndarray,
    *,
    block_points: int = DEFAULT_BLOCK_POINTS,
    interpret: bool = True,
) -> jnp.ndarray:
    """points (N,3) f32, tables (L,T,F), resolutions/dense_flags (L,) i32.

    Returns (N, L*F) f32.  N must be a multiple of block_points (ops pads
    with the sentinel).
    """
    n = points.shape[0]
    num_l, t, f = tables.shape
    assert n % block_points == 0, (n, block_points)
    n_blocks = n // block_points

    out = pl.pallas_call(
        _fused_encode_kernel,
        grid=(n_blocks, num_l),
        in_specs=[
            pl.BlockSpec((1,), lambda i, l: (l,)),
            pl.BlockSpec((1,), lambda i, l: (l,)),
            pl.BlockSpec((block_points, 3), lambda i, l: (i, 0)),
            pl.BlockSpec((1, t, f), lambda i, l: (l, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_points, 1, f), lambda i, l: (i, l, 0)),
        out_shape=jax.ShapeDtypeStruct((n, num_l, f), jnp.float32),
        interpret=interpret,
    )(resolutions, dense_flags, points, tables)
    return out.reshape(n, num_l * f)
