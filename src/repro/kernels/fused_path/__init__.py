"""Fused compacted-path training kernel (FMU coalesced reads + pre-sorted
BUM backward).  See ops.make_fused_encode."""
from . import kernel, ops, ref, reuse  # noqa: F401
