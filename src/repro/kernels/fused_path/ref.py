"""Pure-jnp oracle for the fused compacted-path training kernel.

The paper's FMU (forward mapping unit) coalesces the grid reads of nearby
points — one SRAM bank read serves every point that shares a corner vertex —
and its BUM merges backward grid updates within a sliding window.  Both wins
depend on *spatial adjacency in the processing order*: the compacted point
batch is ours to order, so we sort it by Morton (Z-order) key.  After that,

* points sharing a grid cell sit in the same kernel block, so one corner
  read serves all of them (FMU analogue — realized in kernel.py's block
  staging, counted here by `dedup_stats`);
* the corner-address stream is quasi-sorted, and the *stable* argsort the
  forward pass computes once (to plan the dedup) doubles as the backward
  pass's merge order — the VJP emits its table-gradient stream already
  address-sorted, so `merged_scatter_add(presorted=True)` skips its argsort
  (BUM analogue).

Everything here is geometry shared with `hash_encode.ref` — same corner
enumeration, same hashing, bit-identical encode outputs.  The fused path's
value is *where* the work happens (forward-planned, shared across the
density/color grids, block-deduplicated), not different math.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..hash_encode import ref as he_ref


# --- Morton (Z-order) keys ---------------------------------------------------

MORTON_BITS = 10  # 3*10 = 30 bits, fits uint32; finer than any grid level


def _part1by2(v: jnp.ndarray) -> jnp.ndarray:
    """Spread the low 10 bits of uint32 v so they occupy every 3rd bit."""
    v = v & jnp.uint32(0x3FF)
    v = (v | (v << 16)) & jnp.uint32(0x030000FF)
    v = (v | (v << 8)) & jnp.uint32(0x0300F00F)
    v = (v | (v << 4)) & jnp.uint32(0x030C30C3)
    v = (v | (v << 2)) & jnp.uint32(0x09249249)
    return v


def morton_key(unit_points: jnp.ndarray, bits: int = MORTON_BITS) -> jnp.ndarray:
    """Z-order key for points in [0,1)^3.  (N,3) f32 -> (N,) uint32.

    Out-of-box coordinates are clamped, so dead/padded lanes get a valid
    (edge) key; callers that must keep them last override the key themselves.
    """
    n = 1 << bits
    q = jnp.clip(jnp.floor(unit_points.astype(jnp.float32) * n), 0, n - 1)
    q = q.astype(jnp.int32).astype(jnp.uint32)
    return (
        _part1by2(q[..., 0])
        | (_part1by2(q[..., 1]) << 1)
        | (_part1by2(q[..., 2]) << 2)
    )


# --- shared corner geometry --------------------------------------------------

def corner_geometry(points: jnp.ndarray, resolutions) -> tuple[list, list]:
    """Per-level corner coords and trilinear weights, computed ONCE.

    The density and color grids share level geometry (same resolutions,
    different table sizes), so the fused path runs this single pass where the
    unfused path runs it once per grid per direction (2x forward + 2x
    backward).  Returns ([ (N,8,3) int32 ]*L, [ (N,8) f32 ]*L).
    """
    corners, weights = [], []
    for l in range(len(resolutions)):
        c, w = he_ref._level_corners(points, int(resolutions[l]))
        corners.append(c)
        weights.append(w)
    return corners, weights


def level_indices(corners: list, resolutions, table_size: int, dense_flags) -> list:
    """Per-level table indices for one grid from shared corner coords."""
    return [
        he_ref.corner_index(corners[l], int(resolutions[l]), table_size,
                            bool(dense_flags[l]))
        for l in range(len(corners))
    ]


def address_stream(idx_l: list, table_size: int) -> jnp.ndarray:
    """Flatten per-level indices into the canonical update-stream order.

    Position l*(N*8) + n*8 + c — exactly the layout hash_encode's
    `_corner_updates` emits, so a stable argsort of this stream reproduces
    the unfused backward's merge order bit-for-bit.
    """
    return jnp.concatenate(
        [(idx + l * table_size).reshape(-1) for l, idx in enumerate(idx_l)]
    )


def encode_from_indices(tables: jnp.ndarray, idx_l: list, weights: list) -> jnp.ndarray:
    """Multires encoding from precomputed indices/weights.

    Bit-identical to `hash_encode.ref.hash_encode` (same gathers, same
    weighted sum) — the fused forward just reuses the shared geometry.
    tables (L,T,F) -> (N, L*F) f32.
    """
    outs = [
        jnp.sum(weights[l][..., None] * tables[l][idx_l[l]].astype(jnp.float32), axis=1)
        for l in range(tables.shape[0])
    ]
    return jnp.concatenate(outs, axis=-1)


# --- instrumentation (host-side, numpy) --------------------------------------

def dedup_stats(points, resolutions, dense_flags, table_size: int,
                block_points: int = 256) -> dict:
    """Unique-corner-read accounting for one grid's forward stream.

    `unique_ratio_block` is the FMU figure of merit: within each
    (point-block, level) kernel step, the fraction of corner reads that hit
    distinct addresses — every duplicate is a read the FMU coalesces away.
    `unique_ratio_global` is the whole-batch bound (what a block of
    unbounded size would achieve).
    """
    pts = np.asarray(points)
    n = pts.shape[0]
    corners, _ = corner_geometry(jnp.asarray(pts), resolutions)
    idx_l = level_indices(corners, resolutions, table_size, dense_flags)
    total = 0
    uniq_global = 0
    block_ratios = []
    for l, idx in enumerate(idx_l):
        a = np.asarray(idx).reshape(n, 8)
        total += a.size
        uniq_global += np.unique(a).size
        for s in range(0, n, block_points):
            blk = a[s : s + block_points].reshape(-1)
            block_ratios.append(np.unique(blk).size / blk.size)
    stats = {
        "total_reads": int(total),
        "unique_reads_global": int(uniq_global),
        "unique_ratio_global": uniq_global / total,
        "unique_ratio_block": float(np.mean(block_ratios)),
        "n_blocks": len(block_ratios),
    }
    # fold into the obs registry so traced bench/serve runs export the dedup
    # figures of merit alongside everything else (no-op when obs is off)
    from ...obs import metrics as _obs_metrics
    from ...obs import trace as _obs_trace
    if _obs_trace.enabled():
        _obs_metrics.gauge("fused_path.dedup.unique_ratio_block").set(
            stats["unique_ratio_block"])
        _obs_metrics.gauge("fused_path.dedup.unique_ratio_global").set(
            stats["unique_ratio_global"])
    return stats
