"""Jitted public API for the fused compacted-path encode with pre-sorted BUM
backward.

`make_fused_encode(...)` returns a differentiable

    encode(points, *tables) -> tuple of (N, L*F) features, one per grid

that evaluates every hash grid of a field (density + color share level
geometry — same resolutions, different table sizes) in one fused pass:

* corner coords / trilinear weights are computed ONCE and shared by all
  grids and by both directions (the unfused path recomputes them per grid
  per direction — 4x for a decomposed field); on Pallas backends the
  forward runs one kernel per grid (each with in-block dedup) and the
  shared-geometry pass serves the VJP planning;
* the residuals deliberately trade memory for backward compute: weights
  (L,N,8) plus two (L*N*8,) index streams per grid stay live between
  forward and backward (~a few MB at the compacted budgets used here;
  see ROADMAP for a recompute policy on memory-bound devices);
* the forward plans the backward: it computes the stable argsort of each
  grid's corner-address stream (quasi-sorted already, because the caller
  feeds Morton-ordered points) and stashes it as a residual;
* the custom VJP replays that order to emit each grid's table-gradient
  stream already address-sorted, so `merged_scatter_add(presorted=True)`
  commits it without any backward-pass argsort (the BUM analogue) and with
  no corner/index recomputation.

On the ref backend the fused encode is bit-identical to
`hash_encode.ref.hash_encode` per grid, and — because the stable argsort of
an identical address stream is the same permutation the unfused backward
would compute — its table gradients are bit-identical to the unfused
merged-backward path.  Pallas flavors route the forward through
`kernel.fused_encode_pallas` (block-deduplicated corner reads).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from . import kernel as _kernel
from ..hash_encode import ref as he_ref
from ..hash_encode import ops as he_ops
from ..grid_update import ops as gu_ops

DEFAULT_BLOCK_POINTS = _kernel.DEFAULT_BLOCK_POINTS
RESIDUAL_POLICIES = ("stash", "recompute")


def make_fused_encode(
    resolutions,
    table_sizes,
    n_features: int,
    *,
    residual_policy: str = "recompute",
    backend=None,
    merged_backward: bool = True,
    block_points: int = DEFAULT_BLOCK_POINTS,
) -> Callable:
    """Build the fused multi-grid encoder for fixed level geometry.

    resolutions: static per-level grid resolutions (shared by all grids).
    table_sizes: one table size per grid (e.g. (T_density, T_color)).
    Returns encode(points (N,3), *tables[(L,T_g,F)]) -> tuple[(N, L*F)].

    Contracts the rest of the stack relies on (previously only recorded in
    CHANGES.md):

    * **Input ordering.** `points` should be Morton (Z-order) sorted — the
      pipeline's compact stage guarantees this (uniform or redistributed
      samples alike).  Correctness never depends on it, but both wins do:
      block-level corner-read dedup on Pallas (FMU) and the quasi-sorted
      address streams that make the forward's stable argsort cheap.
    * **Presorted invariant.** The forward stashes, per grid, the *stable*
      argsort of the canonical corner-address stream (level-major, then
      point, then corner).  The VJP replays exactly that permutation and
      commits through `merged_scatter_add(presorted=True)`, which skips its
      own argsort.  Because a stable sort of an identical key stream is an
      identical permutation, the committed gradient is bit-identical to the
      unfused merged-backward path — property-tested in
      tests/test_grid_update.py.
    * **Sentinel invariant.** Pallas block padding uses
      `hash_encode.PAD_SENTINEL` (-1.0): kernels must map sentinel rows to
      zero output while reading row 0 of the table (a harmless in-bounds
      address), so padded lanes neither contribute features nor fault.
      Regression-tested in tests/test_hash_encode.py.
    * **Residual footprint.** Set by `residual_policy`.  "stash" is the
      PR 3 set: weights (L,N,8) plus two (L·N·8,) index streams per grid
      stay live from forward to backward and the VJP does no geometry work.
      "recompute" (default) keeps only the points alias and re-derives
      geometry + streams in the backward with the same deterministic ops —
      BIT-identical gradients (stable argsort of an identical address stream
      is an identical permutation), just traded from residual bandwidth to
      backward FLOPs; the right default at production L=16/100k-point scale.
    """
    if residual_policy not in RESIDUAL_POLICIES:
        raise ValueError(f"residual_policy must be one of {RESIDUAL_POLICIES}")
    from .. import resolve_backend
    be = resolve_backend(backend)
    resolutions = tuple(int(r) for r in resolutions)
    table_sizes = tuple(int(t) for t in table_sizes)
    num_l = len(resolutions)
    n_grids = len(table_sizes)
    dense_flags = tuple(
        tuple(bool(x) for x in he_ref.level_is_dense(np.asarray(resolutions), t))
        for t in table_sizes
    )

    def _forward(points, tables):
        if be.use_pallas:
            pts, n = he_ops._pad_to(points, block_points)
            outs = []
            for g in range(n_grids):
                out = _kernel.fused_encode_pallas(
                    pts,
                    tables[g],
                    jnp.asarray(resolutions, jnp.int32),
                    jnp.asarray(dense_flags[g], jnp.int32),
                    block_points=block_points,
                    interpret=be.interpret,
                )
                outs.append(out[:n])
            return tuple(outs)
        corners, weights = ref.corner_geometry(points, resolutions)
        return tuple(
            ref.encode_from_indices(
                tables[g],
                ref.level_indices(corners, resolutions, table_sizes[g], dense_flags[g]),
                weights,
            )
            for g in range(n_grids)
        )

    def _plan(points):
        """Shared geometry + backward plan: weights (L,N,8) and, per grid,
        the stable argsort of the canonical corner-address stream — the
        unfused backward's merge order."""
        corners, weights = ref.corner_geometry(points, resolutions)
        idx_by_grid = [
            ref.level_indices(corners, resolutions, table_sizes[g], dense_flags[g])
            for g in range(n_grids)
        ]
        streams = []
        for g in range(n_grids):
            addr = ref.address_stream(idx_by_grid[g], table_sizes[g])
            order = jnp.argsort(addr)
            streams.append((addr[order], order))
        return jnp.stack(weights), tuple(streams), idx_by_grid, weights

    @jax.custom_vjp
    def encode(points, *tables):
        return _forward(points, tables)

    def encode_fwd(points, *tables):
        protos = tuple(jnp.zeros((0,), t.dtype) for t in tables)
        if residual_policy == "recompute":
            # Only the points alias crosses to the backward; the plan is
            # re-derived there (bit-identical — same deterministic ops on the
            # same inputs) and pure forwards never pay for it at all.
            return _forward(points, tables), (points, None, None, protos)
        w_stack, streams, idx_by_grid, weights = _plan(points)
        if be.use_pallas:
            outs = _forward(points, tables)
        else:
            outs = tuple(
                ref.encode_from_indices(tables[g], idx_by_grid[g], weights)
                for g in range(n_grids)
            )
        return outs, (points, w_stack, streams, protos)

    def encode_bwd(res_pack, g_out):
        points, w_stack, streams, protos = res_pack
        if streams is None:  # recompute policy
            w_stack, streams, _, _ = _plan(points)
        n = points.shape[0]
        grads = []
        for g in range(n_grids):
            gg = g_out[g].reshape(n, num_l, n_features).astype(jnp.float32)
            # Update values in canonical stream order (level-major, then
            # point, then corner) — identical elementwise products to the
            # unfused `_corner_updates`.
            vals = (
                w_stack[:, :, :, None] * jnp.transpose(gg, (1, 0, 2))[:, :, None, :]
            ).reshape(-1, n_features)
            addr_sorted, order = streams[g]
            flat = jnp.zeros((num_l * table_sizes[g], n_features), jnp.float32)
            if merged_backward:
                flat = gu_ops.merged_scatter_add(
                    flat, addr_sorted, vals[order], presorted=True, backend=be
                )
            else:
                flat = flat.at[addr_sorted].add(vals[order])
            grads.append(
                flat.reshape(num_l, table_sizes[g], n_features).astype(protos[g].dtype)
            )
        return (jnp.zeros_like(points), *grads)

    encode.defvjp(encode_fwd, encode_bwd)
    return encode
