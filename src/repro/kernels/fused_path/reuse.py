"""Cross-step encoding-reuse cache (ASDR-style temporal data reuse).

The encoding stage re-interpolates every queried point from scratch each
step, but large parts of the hash tables are bit-stable between steps:

* a grid frozen by the update-frequency schedule (F_C = 0.5 skips the
  color grid's optimizer update on half the iterations) does not change
  AT ALL between those steps;
* rows the optimizer has never touched (zero gradient traffic AND zero
  Adam moments) keep their init values;
* between occupancy folds the set of live cells — hence the set of rows
  the address streams can even name — is fixed.

For any cell whose 8 corner rows (per level) are bit-stable since the cell
was last encoded, the interpolated feature rows are a pure function of
geometry and can be served from cache instead of re-gathered and
re-interpolated.  This module is the host-side bookkeeping for that reuse:

* rows are named in the fused path's canonical address-stream convention
  (`ref.address_stream`: level-major flat id ``l * T + idx``), so the same
  streams the BUM backward sorts are what invalidate the cache;
* entries are keyed ``(grid, level, cell)`` within a fold epoch — a fold
  (occupancy update) bumps the epoch and drops every entry, since the live
  cell set itself may have moved;
* `note_table_update(grid)` invalidates per-grid on any table update;
  passing the step's touched rows (the backward's address stream) narrows
  the invalidation to exactly the rows that received gradient traffic.

The cache is value-correct by construction, not by luck: a hit replays the
*same* gathered corner rows through the *same* trilinear arithmetic as
`hash_encode.ref.encode_level`, so cached and recomputed encodings are
bit-identical whenever the invalidation contract is honored (property-
tested in tests/test_encoding_reuse.py).  Cohort members viewing the same
scene share one cache instance: the cohort trains bit-identical params
across members, so table rows — and therefore entries — are shared.

This is host-side numpy bookkeeping (dict + version arrays), the CPU twin
of an on-accelerator SRAM cache; it measures and serves reuse for eager
consumers (serving, benchmarks, analysis), not for jitted training steps.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..hash_encode import ref as he_ref


def stream_reuse_mask(addrs: np.ndarray, row_stamp: np.ndarray, since: int) -> np.ndarray:
    """Reuse-aware view of an address stream: True where the named row has
    NOT changed since version ``since`` — i.e. reads a cache written at that
    version may still serve.  ``addrs`` is a level-major flat row stream
    (`ref.address_stream` convention), ``row_stamp`` the per-row last-change
    versions."""
    return np.asarray(row_stamp)[np.asarray(addrs)] <= int(since)


class EncodingReuseCache:
    """(grid, level, cell, fold)-keyed cache of interpolation corner rows.

    Parameters: ``resolutions`` (L,) per-level grid resolutions shared by
    all grids (the decomposed field's convention); ``table_sizes`` maps grid
    name -> per-level table size T.  Feature width is discovered from the
    tables at encode time.
    """

    def __init__(self, resolutions, table_sizes: dict):
        self.resolutions = tuple(int(r) for r in np.asarray(resolutions).reshape(-1))
        self.table_sizes = {g: int(t) for g, t in table_sizes.items()}
        self.dense_flags = {
            g: he_ref.level_is_dense(np.asarray(self.resolutions), t)
            for g, t in self.table_sizes.items()
        }
        self.fold = 0
        self._version = 0
        n_lv = len(self.resolutions)
        # per-row last-change version, level-major flat (l * T + idx)
        self._row_stamp = {
            g: np.zeros(n_lv * t, np.int64) for g, t in self.table_sizes.items()
        }
        # (grid, level) -> {cell_flat: (rows (8,F) np, addrs (8,) np, stamp)}
        self._entries = {
            (g, l): {} for g in self.table_sizes for l in range(n_lv)
        }
        self.hits = 0
        self.misses = 0

    # ---- invalidation events ----

    def note_fold(self) -> None:
        """Occupancy fold: new epoch, the live cell set may have moved —
        every entry is dropped (the fold count is part of the key)."""
        self.fold += 1
        for d in self._entries.values():
            d.clear()

    def note_table_update(self, grid: str, touched_rows=None) -> None:
        """A training step updated ``grid``'s tables.

        With ``touched_rows`` (level-major flat row ids — the backward's
        `address_stream`, or any superset of the rows that changed), only
        those rows' stamps advance; entries over other rows keep serving.
        Without it, the whole grid is conservatively invalidated.
        """
        self._version += 1
        if touched_rows is None:
            self._row_stamp[grid][:] = self._version
        else:
            rows = np.asarray(touched_rows).reshape(-1)
            self._row_stamp[grid][rows] = self._version

    # ---- lookup ----

    def _cells(self, points, resolution: int):
        """Unique base cells + inverse map for one level.  Cell id flattens
        the base corner coords (x-major) — all points in a cell share the
        same 8 corner rows, the unit of caching."""
        scaled = np.asarray(points, np.float32) * np.float32(resolution)
        base = np.floor(scaled).astype(np.int64)
        flat = (base[:, 0] * resolution + base[:, 1]) * resolution + base[:, 2]
        uniq, inverse = np.unique(flat, return_inverse=True)
        return uniq, inverse

    def encode(self, grid: str, points_unit, tables) -> jnp.ndarray:
        """Multires encoding of ``points_unit`` (N,3) against ``tables``
        (L,T,F), serving cached corner rows where valid.

        Bit-identical to `hash_encode.ref.hash_encode` at all times: hits
        and misses alike go through the reference trilinear weighted sum;
        only the (L,T,F) gather is skipped on a hit.  Callers own the
        invalidation contract — `note_table_update` after any optimizer
        update to this grid, `note_fold` at occupancy folds.
        """
        pts = jnp.asarray(points_unit)
        tabs_np = np.asarray(tables)
        t = self.table_sizes[grid]
        stamp = self._row_stamp[grid]
        outs = []
        for l, res in enumerate(self.resolutions):
            store = self._entries[(grid, l)]
            uniq, inverse = self._cells(pts, res)
            n_u = uniq.shape[0]
            f = tabs_np.shape[-1]
            rows_u = np.empty((n_u, 8, f), tabs_np.dtype)
            miss_cells = []
            for ui, cell in enumerate(uniq):
                hit = store.get(int(cell))
                if hit is not None and (stamp[hit[1]] <= hit[2]).all():
                    rows_u[ui] = hit[0]
                    self.hits += 1
                else:
                    miss_cells.append(ui)
                    self.misses += 1
            if miss_cells:
                mi = np.asarray(miss_cells)
                base = np.stack(np.unravel_index(uniq[mi], (res,) * 3), axis=-1)
                corners = base[:, None, :] + he_ref.CORNERS[None, :, :]
                idx = np.asarray(he_ref.corner_index(
                    jnp.asarray(corners), res, t, bool(self.dense_flags[grid][l])
                ))
                rows_u[mi] = tabs_np[l][idx]
                addrs = idx + l * t
                for k, ui in enumerate(mi):
                    store[int(uniq[ui])] = (rows_u[ui], addrs[k], self._version)
            # reference interpolation arithmetic on the (cached or fresh)
            # rows — the weights come from the same jnp geometry as the
            # oracle, so hit and miss paths are bit-identical to it
            _, weights = he_ref._level_corners(pts, res)
            feats = jnp.asarray(rows_u)[inverse]
            outs.append(jnp.sum(weights[..., None] * feats.astype(jnp.float32), axis=1))
        return jnp.concatenate(outs, axis=-1)

    # ---- accounting ----

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def stats(self) -> dict:
        """Reuse accounting in the spirit of `ref.dedup_stats`: each hit is
        8 corner-row reads (per level) the table never sees."""
        return {
            "lookups": int(self.lookups),
            "hits": int(self.hits),
            "misses": int(self.misses),
            "hit_rate": self.hit_rate(),
            "corner_reads_saved": int(self.hits) * 8,
            "fold": int(self.fold),
        }
