"""Procedural 3D scenes with analytic ground truth (NeRF-Synthetic stand-in).

The real NeRF-Synthetic/SILVR/ScanNet datasets cannot ship in this container
(DESIGN.md §9), so scenes are generated: a handful of soft solid primitives
(spheres, boxes, torus) with distinct albedos and mild view-dependent shading.
Ground-truth images are rendered through the *same* volume-rendering equation
the NeRF uses (dense sampling of the analytic field), so a perfect NeRF fit
is well-defined, PSNR is meaningful, and depth images (paper Fig. 5) have an
analytic reference.  Eight seeds stand in for the paper's eight scenes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import rendering
from ..kernels.volume_render import ref as vr_ref


class SceneParams(NamedTuple):
    centers: jnp.ndarray   # (K, 3)
    radii: jnp.ndarray     # (K,)
    kinds: jnp.ndarray     # (K,) 0=sphere 1=box 2=torus
    albedo: jnp.ndarray    # (K, 3)
    density: jnp.ndarray   # (K,) peak density
    sharp: jnp.ndarray     # (K,) edge sharpness


def make_scene(seed: int, n_primitives: int = 5) -> SceneParams:
    rng = np.random.default_rng(seed)
    k = n_primitives
    centers = rng.uniform(-0.8, 0.8, size=(k, 3)).astype(np.float32)
    radii = rng.uniform(0.18, 0.45, size=k).astype(np.float32)
    kinds = rng.integers(0, 3, size=k).astype(np.int32)
    albedo = rng.uniform(0.15, 0.95, size=(k, 3)).astype(np.float32)
    density = rng.uniform(20.0, 40.0, size=k).astype(np.float32)
    sharp = rng.uniform(25.0, 50.0, size=k).astype(np.float32)
    return SceneParams(*(jnp.asarray(a) for a in (centers, radii, kinds, albedo, density, sharp)))


def _sdf(scene: SceneParams, points: jnp.ndarray) -> jnp.ndarray:
    """Signed distance to each primitive. points (N,3) -> (N,K)."""
    d = points[:, None, :] - scene.centers[None, :, :]  # (N, K, 3)
    r = scene.radii[None, :]
    sphere = jnp.linalg.norm(d, axis=-1) - r
    box = jnp.max(jnp.abs(d), axis=-1) - r * 0.8
    ring = jnp.sqrt(jnp.square(jnp.linalg.norm(d[..., :2], axis=-1) - r) + jnp.square(d[..., 2]))
    torus = ring - r * 0.35
    k = scene.kinds[None, :]
    return jnp.where(k == 0, sphere, jnp.where(k == 1, box, torus))


def scene_density(scene: SceneParams, points: jnp.ndarray) -> jnp.ndarray:
    """Analytic density field (N,3) world coords -> (N,)."""
    sd = _sdf(scene, points)  # (N, K)
    occ = jax.nn.sigmoid(-sd * scene.sharp[None, :])  # soft interior indicator
    return jnp.max(scene.density[None, :] * occ, axis=-1)


def scene_color(scene: SceneParams, points: jnp.ndarray, dirs: jnp.ndarray) -> jnp.ndarray:
    """Analytic radiance: dominant primitive's albedo + soft lambert shading."""
    sd = _sdf(scene, points)
    w = jax.nn.softmax(-sd * 20.0, axis=-1)  # (N, K) dominant-primitive weights
    base = w @ scene.albedo  # (N, 3)
    # pseudo-normal = direction from the weighted primitive center
    ctr = w @ scene.centers
    n = points - ctr
    n = n / (jnp.linalg.norm(n, axis=-1, keepdims=True) + 1e-6)
    lam = 0.65 + 0.35 * jnp.clip(jnp.sum(-dirs * n, axis=-1, keepdims=True), 0.0, 1.0)
    return jnp.clip(base * lam, 0.0, 1.0)


def render_gt(
    scene: SceneParams,
    pose: np.ndarray,
    h: int,
    w: int,
    focal: float,
    cfg: rendering.RenderConfig,
    n_samples: int = 192,
    chunk: int = 8192,
):
    """Ground-truth RGB (H,W,3) + depth (H,W) via dense analytic ray marching."""
    py, px = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    px, py = px.reshape(-1), py.reshape(-1)
    rgb_out, depth_out = [], []
    pose_j = jnp.asarray(pose)
    for i in range(0, px.shape[0], chunk):
        o, d = rendering.pixel_rays(pose_j, px[i : i + chunk], py[i : i + chunk], h, w, focal)
        rgb, depth = _render_gt_rays(scene, o, d, cfg, n_samples)
        rgb_out.append(rgb)
        depth_out.append(depth)
    rgb = jnp.concatenate(rgb_out).reshape(h, w, 3)
    depth = jnp.concatenate(depth_out).reshape(h, w)
    return np.asarray(rgb), np.asarray(depth)


@jax.jit
def _gt_fields(scene, pts, dirs):
    return scene_density(scene, pts), scene_color(scene, pts, dirs)


def _render_gt_rays(scene, origins, dirs, cfg: rendering.RenderConfig, n_samples: int):
    b = origins.shape[0]
    ts = jnp.linspace(cfg.near, cfg.far, n_samples)[None, :].repeat(b, 0)
    pts = origins[:, None, :] + ts[..., None] * dirs[:, None, :]
    flat = pts.reshape(-1, 3)
    fdirs = jnp.broadcast_to(dirs[:, None, :], pts.shape).reshape(-1, 3)
    sigma, rgb = _gt_fields(scene, flat, fdirs)
    live = rendering.inside_aabb(flat, cfg)
    sigma = jnp.where(live, sigma, 0.0).reshape(b, n_samples)
    rgb = rgb.reshape(b, n_samples, 3)
    deltas = jnp.diff(ts, axis=-1, append=ts[:, -1:] + (cfg.far - cfg.near) / n_samples)
    out = vr_ref.composite(sigma, rgb, deltas, ts)
    color = out.color + (1.0 - out.opacity[..., None]) if cfg.white_background else out.color
    return color, out.depth


class SceneDataset(NamedTuple):
    """Posed training images + intrinsics for one scene."""
    images: np.ndarray   # (V, H, W, 3)
    depths: np.ndarray   # (V, H, W)
    poses: np.ndarray    # (V, 3, 4)
    focal: float
    h: int
    w: int


def build_dataset(
    seed: int,
    n_views: int = 24,
    h: int = 64,
    w: int = 64,
    fov_deg: float = 50.0,
    cfg: rendering.RenderConfig | None = None,
    gt_samples: int = 192,
) -> tuple[SceneParams, SceneDataset]:
    cfg = cfg or rendering.RenderConfig()
    scene = make_scene(seed)
    poses = rendering.sphere_poses(n_views, seed=seed)
    focal = 0.5 * w / np.tan(np.deg2rad(fov_deg) / 2)
    imgs, deps = [], []
    for v in range(n_views):
        rgb, dep = render_gt(scene, poses[v], h, w, focal, cfg, n_samples=gt_samples)
        imgs.append(rgb)
        deps.append(dep)
    ds = SceneDataset(np.stack(imgs), np.stack(deps), poses, float(focal), h, w)
    return scene, ds
