from .synthetic_scene import SceneParams, SceneDataset, make_scene, build_dataset  # noqa: F401
from .rays_dataset import RaySampler  # noqa: F401
from .lm_data import SyntheticLMStream, LMStreamConfig  # noqa: F401
