"""Deterministic sharded synthetic token streams for LM training.

Tokens follow a fixed random bigram process (learnable structure, so loss
visibly decreases), generated *statelessly* per (seed, step, dp_rank): a
restart at step k reproduces the exact stream — the checkpoint/restart and
elastic-resharding invariant the runtime driver relies on.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class LMStreamConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    branching: int = 8  # bigram successors per token


class SyntheticLMStream:
    def __init__(self, cfg: LMStreamConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # each token has `branching` plausible successors
        self.successors = rng.integers(
            0, cfg.vocab, size=(cfg.vocab, cfg.branching), dtype=np.int64
        )

    def batch(self, step: int, dp_rank: int = 0, dp_size: int = 1) -> np.ndarray:
        """(global_batch/dp_size, seq) int32 for this data shard at this step."""
        cfg = self.cfg
        assert cfg.global_batch % dp_size == 0
        local = cfg.global_batch // dp_size
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, dp_rank])
        )
        toks = np.empty((local, cfg.seq), dtype=np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=local)
        choices = rng.integers(0, cfg.branching, size=(local, cfg.seq - 1))
        for t in range(1, cfg.seq):
            toks[:, t] = self.successors[toks[:, t - 1], choices[:, t - 1]]
        return toks.astype(np.int32)

    def iterator(self, start_step: int = 0, dp_rank: int = 0, dp_size: int = 1):
        step = start_step
        while True:
            yield {"tokens": self.batch(step, dp_rank, dp_size)}
            step += 1
