"""Pixel/ray batch sampling (paper Step 1-2): random pixels across all views."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import rendering
from .synthetic_scene import SceneDataset


class RaySampler:
    """Samples (origins, dirs, rgb_gt) batches from a posed image set.

    Precomputes all rays once (V*H*W rows) and draws uniform batches with a
    jax PRNG — deterministic given the step's key, so training restarts
    reproduce the exact stream (checkpoint/restart invariant).
    """

    def __init__(self, ds: SceneDataset, views=None):
        """views: optional iterable of view indices to draw from (default:
        all).  Restricting the training pool lets benchmarks hold out eval
        views without rebuilding the dataset; the ray stream for a given
        (views, key) is deterministic either way."""
        all_v, h, w = ds.images.shape[:3]
        views = list(range(all_v)) if views is None else sorted(views)
        v = len(views)
        origins = np.zeros((v, h * w, 3), np.float32)
        dirs = np.zeros((v, h * w, 3), np.float32)
        py, px = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
        px, py = px.reshape(-1), py.reshape(-1)
        for i, vi in enumerate(views):
            o, d = rendering.pixel_rays(jnp.asarray(ds.poses[vi]), px, py, h, w, ds.focal)
            origins[i], dirs[i] = np.asarray(o), np.asarray(d)
        self.views = views
        self.origins = jnp.asarray(origins.reshape(-1, 3))
        self.dirs = jnp.asarray(dirs.reshape(-1, 3))
        self.rgb = jnp.asarray(ds.images[views].reshape(-1, 3))
        self.n = self.rgb.shape[0]

    def sample_idx(self, rng: jax.Array, batch: int) -> jnp.ndarray:
        """The batch's ray indices alone — `sample` == gathering these.
        Exposed so a train cohort whose members share a pool size can draw
        ONE index batch and gather every member's rays from stacked pools
        (bit-identical to each member sampling on its own: same key, same
        bound)."""
        return jax.random.randint(rng, (batch,), 0, self.n)

    def sample(self, rng: jax.Array, batch: int) -> rendering.RayBatch:
        idx = self.sample_idx(rng, batch)
        return rendering.RayBatch(self.origins[idx], self.dirs[idx], self.rgb[idx])
