"""Production mesh construction (function, not constant — importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ('data','model'); 2 pods -> (2,16,16) with a
    leading 'pod' axis (DP across pods, DCN hop = gradient all-reduce)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(model: int = 1, data: int = 1):
    """Small mesh over the locally available devices (tests / examples)."""
    n = jax.device_count()
    if model * data > n:
        model, data = 1, 1
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
