"""Mesh construction (function, not constant — importing this module never
touches jax device state).

Two families live here:

* **LM-stack meshes** (`make_production_mesh`, `make_host_mesh`) — the 2D/3D
  data×model meshes the transformer sharding rules in
  `repro.parallel.sharding` partition over.  These use the new-style
  `jax.make_mesh(..., axis_types=...)` API and require a jax with
  `jax.sharding.AxisType`.
* **serve3d session meshes** (`session_devices`, `session_mesh`) — the 1D
  device list/mesh the reconstruction service shards *sessions* (not
  tensors) over.  Each session's whole state lives on one device
  (`serve3d.placement`), so no partition specs are needed and the helpers
  stay compatible with every jax this repo supports.  On CPU,
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` provides N
  virtual devices for tests and benchmarks.
"""
from __future__ import annotations

import jax


def session_devices(n: int | None = None) -> list:
    """The first `n` local devices (all of them when n is None) — the
    substrate `serve3d.placement.DevicePlacement` spreads sessions over.
    Raises when more devices are requested than the platform offers, so a
    misconfigured fleet fails loudly at construction, not mid-serving."""
    devs = list(jax.devices())
    if n is None:
        return devs
    n = int(n)
    if n < 1:
        raise ValueError(f"need at least one device, got n={n}")
    if n > len(devs):
        raise ValueError(
            f"requested {n} devices but only {len(devs)} are available "
            f"(on CPU, set XLA_FLAGS=--xla_force_host_platform_device_count={n})"
        )
    return devs[:n]


def session_mesh(n: int | None = None):
    """1D ('session',) mesh over `session_devices(n)`.  Plain
    `jax.sharding.Mesh` — works on every supported jax version; sessions are
    placed whole-state-per-device, so the mesh is bookkeeping/introspection,
    not a partitioning contract."""
    import numpy as np

    return jax.sharding.Mesh(np.array(session_devices(n)), ("session",))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ('data','model'); 2 pods -> (2,16,16) with a
    leading 'pod' axis (DP across pods, DCN hop = gradient all-reduce)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(model: int = 1, data: int = 1):
    """Small mesh over the locally available devices (tests / examples)."""
    n = jax.device_count()
    if model * data > n:
        model, data = 1, 1
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
