"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report --dir results/dryrun
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def load(dir_: Path):
    rows = []
    for f in sorted(dir_.glob("*.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def fmt_bytes(b):
    if b >= 2**30:
        return f"{b / 2**30:.2f} GiB"
    return f"{b / 2**20:.1f} MiB"


def dryrun_table(rows, multi_pod):
    out = ["| arch | shape | status | compile s | args/dev | temp/dev | peak/dev | collectives |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["multi_pod"] != multi_pod:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | skipped¹ | – | – | – | – | – |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | **{r['status']}** | – | – | – | – | – |")
            continue
        m = r["memory"]
        colls = ", ".join(f"{k}×{v['count']}" for k, v in sorted(r["collectives"].items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} | "
            f"{fmt_bytes(m['argument_bytes_per_device'])} | {fmt_bytes(m['temp_bytes_per_device'])} | "
            f"{m['peak_estimate_gib']} GiB | {colls or '—'} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | flops/dev | wire/dev | compute s | memory s (lb) | collective s | bound | useful |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["multi_pod"] or r["status"] != "ok" or "roofline" not in r:
            continue
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rl['flops_per_device']:.3g} | "
            f"{rl['wire_bytes_per_device']:.3g} | {rl['compute_s']:.4f} | "
            f"{rl['memory_s']:.4f} | {rl['collective_s']:.4f} | "
            f"**{rl['bound']}** | {rl['useful_ratio']:.1%} |")
    return "\n".join(out)


def summarize(rows):
    ok = sum(1 for r in rows if r["status"] == "ok")
    sk = sum(1 for r in rows if r["status"] == "skipped")
    bad = [r for r in rows if r["status"] not in ("ok", "skipped")]
    lines = [f"{ok} compiled ok, {sk} skipped (per applicability rules), {len(bad)} failed."]
    for r in bad:
        lines.append(f"  FAILED: {r['arch']} {r['shape']} pod{2 if r['multi_pod'] else 1}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    rows = load(Path(args.dir))
    print("## Summary\n")
    print(summarize(rows))
    print("\n## Dry-run, single pod (16×16 = 256 chips)\n")
    print(dryrun_table(rows, False))
    print("\n## Dry-run, multi-pod (2×16×16 = 512 chips)\n")
    print(dryrun_table(rows, True))
    print("\n## Roofline (single pod; probe-corrected per-layer costs)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
