"""Step-function builders shared by dryrun / train / serve.

Each builder returns (fn, abstract_args, in_shardings, donate) ready for
jax.jit().lower(*abstract_args) — the dry-run path — or for real execution
with concrete arrays of the same shapes.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config
from ..configs.shapes import SHAPES, Shape, input_specs
from ..models.config import ModelConfig
from ..models.lm import LM
from ..optim import AdamW, AdamWState, schedule
from ..parallel import sharding as shd

# archs big enough that params+opt must shard over 'data' too (ZeRO/FSDP)
FSDP_ARCHS = {
    "qwen3-8b", "yi-9b", "chatglm3-6b", "deepseek-v2-lite-16b",
    "deepseek-v3-671b", "zamba2-7b", "falcon-mamba-7b",
}


def policy_for(cfg: ModelConfig, train: bool, variant: str = "optimized") -> shd.ShardingPolicy:
    """Sharding policy per (arch, step kind).

    baseline  — paper-faithful first cut: Megatron TP over 'model' everywhere,
                FSDP over 'data' for >=7B training.
    optimized — §Perf hillclimbed: train/prefill use the FSDP-pure (ZeRO-3)
                policy (activation all-reduces -> per-layer param gathers,
                10-20x less wire at batch 256x4k); decode keeps TP (params +
                KV cache sharded; per-step compute tiny).
    """
    if variant == "baseline" or not train:
        return shd.ShardingPolicy(tp=True, fsdp=train and cfg.name in FSDP_ARCHS)
    return shd.FSDP_PURE


def make_optimizer(cfg: ModelConfig) -> AdamW:
    return AdamW(
        lr=schedule.warmup_cosine(3e-4, 2000, 100_000),
        b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, clip_norm=1.0,
    )


def opt_state_specs(param_specs_tree):
    return AdamWState(step=P(), m=param_specs_tree, v=param_specs_tree)



def _act_spec(shape: Shape, mesh, policy):
    """(B,S,D) residual-stream PartitionSpec under this policy's batch split."""
    dpa = shd.dp(mesh, policy)
    ax_b, ax_s = shd._split_batch_seq(shape.global_batch, shape.seq, dpa, mesh)
    return P(ax_b, ax_s, None)


def build_train_step(cfg: ModelConfig, mesh, shape: Shape, variant: str = "optimized"):
    policy = policy_for(cfg, train=True, variant=variant)
    model = LM(cfg, mesh=mesh, tp_logits=policy.tp,
               act_spec=None if policy.tp else _act_spec(shape, mesh, policy))
    opt = make_optimizer(cfg)

    abstract_params = model.init_abstract()
    abstract_opt = jax.eval_shape(opt.init, abstract_params)
    batch = input_specs(cfg, shape)

    pspecs = shd.param_specs(cfg, abstract_params, mesh, policy)
    ospecs = opt_state_specs(pspecs)
    bspecs = shd.batch_specs(cfg, batch, mesh, policy)

    def train_step(params, opt_state, b):
        loss, grads = jax.value_and_grad(model.loss)(params, b)
        params, opt_state = opt.apply(params, grads, opt_state)
        return params, opt_state, loss

    named = lambda t: shd.to_named(t, mesh)
    fn = jax.jit(
        train_step,
        in_shardings=(named(pspecs), named(ospecs), named(bspecs)),
        out_shardings=(named(pspecs), named(ospecs), NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    )
    return fn, (abstract_params, abstract_opt, batch)


def build_prefill_step(cfg: ModelConfig, mesh, shape: Shape, variant: str = "optimized"):
    # prefill is token-heavy like training: use the train-side policy
    policy = policy_for(cfg, train=True, variant=variant)
    model = LM(cfg, mesh=mesh, tp_logits=policy.tp,
               act_spec=None if policy.tp else _act_spec(shape, mesh, policy))
    abstract_params = model.init_abstract()
    batch = input_specs(cfg, shape)
    pspecs = shd.param_specs(cfg, abstract_params, mesh, policy)
    bspecs = shd.batch_specs(cfg, batch, mesh, policy)

    def prefill_step(params, b):
        logits, caches, _ = model.prefill(
            params,
            tokens=b.get("tokens"),
            embeds=b.get("embeds"),
            positions=b.get("positions"),
            encoder_embeds=b.get("encoder_embeds"),
        )
        return logits, caches

    named = lambda t: shd.to_named(t, mesh)
    fn = jax.jit(prefill_step, in_shardings=(named(pspecs), named(bspecs)))
    return fn, (abstract_params, batch)


def build_decode_step(cfg: ModelConfig, mesh, shape: Shape, variant: str = "optimized"):
    policy = policy_for(cfg, train=False, variant=variant)
    model = LM(cfg, mesh=mesh, tp_logits=policy.tp)
    abstract_params = model.init_abstract()
    batch = input_specs(cfg, shape)
    pspecs = shd.param_specs(cfg, abstract_params, mesh, policy)
    bspecs = shd.batch_specs(cfg, batch, mesh, policy)

    def decode_step(params, b):
        logits, caches = model.decode_step(
            params, b["caches"], b["tokens"], b["pos"],
            encoder_out=b.get("encoder_out"),
        )
        return logits, caches

    named = lambda t: shd.to_named(t, mesh)
    cache_out = named(bspecs)["caches"]
    dpa = shd.dp(mesh, policy)
    n_dp = int(np.prod([mesh.shape[a] for a in dpa])) if dpa else 1
    batch_ax = dpa if shape.global_batch % max(n_dp, 1) == 0 else None
    vocab_ax = policy.model_axis if cfg.vocab % mesh.shape.get(policy.model_axis, 1) == 0 else None
    fn = jax.jit(
        decode_step,
        in_shardings=(named(pspecs), named(bspecs)),
        out_shardings=(NamedSharding(mesh, P(batch_ax, vocab_ax)), cache_out),
        donate_argnums=(1,),
    )
    return fn, (abstract_params, batch)


def build_step_cfg(cfg: ModelConfig, shape_name: str, mesh, variant: str = "optimized"):
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, variant), cfg, shape
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, variant), cfg, shape
    return build_decode_step(cfg, mesh, shape, variant), cfg, shape


def build_step(arch: str, shape_name: str, mesh, variant: str = "optimized"):
    return build_step_cfg(get_config(arch), shape_name, mesh, variant)
