import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs — no allocation — and record memory/cost/collective
analysis for the roofline table.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
    python -m repro.launch.dryrun --all [--out results/dryrun]

The XLA_FLAGS line above MUST run before any other import (jax locks device
count on first init).  `--all` runs each cell in a subprocess so one cell's
compile memory cannot poison the next.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402


def probe_variants(cfg):
    """Small unrolled config variants whose compiled costs solve for
    per-layer-body costs (XLA counts a lax.scan while-body once, so the
    full-depth compile's cost_analysis undercounts by ~n_layers; probes are
    python-unrolled and exact).  Returns (variants, coeff_rows, full_counts):
    cost(variant_i) = coeff_rows[i] · body_costs;  true = full_counts · body_costs.
    """
    import dataclasses
    r = dataclasses.replace
    if cfg.enc_dec:
        a = r(cfg, n_layers=1, n_encoder_layers=1, unroll_layers=True)
        b = r(cfg, n_layers=1, n_encoder_layers=2, unroll_layers=True)
        c = r(cfg, n_layers=2, n_encoder_layers=1, unroll_layers=True)
        return [a, b, c], [[1, 1, 1], [1, 2, 1], [1, 1, 2]], \
            [1, cfg.n_encoder_layers, cfg.n_layers]
    if cfg.hybrid_attn_every:
        ev = cfg.hybrid_attn_every
        a = r(cfg, n_layers=1, hybrid_attn_every=0, unroll_layers=True)
        b = r(cfg, n_layers=2, hybrid_attn_every=0, unroll_layers=True)
        c = r(cfg, n_layers=ev, hybrid_attn_every=ev, unroll_layers=True)
        return [a, b, c], [[1, 1, 0], [1, 2, 0], [1, ev, 1]], \
            [1, cfg.n_layers, cfg.n_layers // ev]
    if cfg.moe is not None and cfg.moe.n_dense_layers:
        nd = cfg.moe.n_dense_layers
        a = r(cfg, n_layers=2, moe=r(cfg.moe, n_dense_layers=1), unroll_layers=True)
        b = r(cfg, n_layers=3, moe=r(cfg.moe, n_dense_layers=1), unroll_layers=True)
        c = r(cfg, n_layers=3, moe=r(cfg.moe, n_dense_layers=2), unroll_layers=True)
        return [a, b, c], [[1, 1, 1], [1, 1, 2], [1, 2, 1]], \
            [1, nd, cfg.n_layers - nd]
    a = r(cfg, n_layers=1, unroll_layers=True)
    b = r(cfg, n_layers=2, unroll_layers=True)
    return [a, b], [[1, 1], [1, 2]], [1, cfg.n_layers]


def _compile_cell(cfg, shape_name, mesh, variant="optimized"):
    """lower+compile one config; returns (memory_analysis, metrics dict)."""
    import jax
    from .steps import build_step_cfg
    from .roofline import collective_stats

    with jax.set_mesh(mesh):
        (fn, abstract_args), cfg, shape = build_step_cfg(cfg, shape_name, mesh, variant)
        lowered = fn.lower(*abstract_args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
    coll = collective_stats(hlo, default_group=mesh.shape.get("model", 1))
    metrics = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": float(coll["wire_bytes_per_device"]),
    }
    return mem, metrics, coll, shape


def corrected_metrics(cfg, shape_name, mesh, variant="optimized"):
    """Probe-and-extrapolate exact per-step flops/bytes/wire per device."""
    import numpy as np

    variants, rows, full = probe_variants(cfg)
    ys = []
    for v in variants:
        _, m, _, _ = _compile_cell(v, shape_name, mesh, variant)
        ys.append([m["flops"], m["bytes"], m["wire"]])
    a = np.asarray(rows, dtype=np.float64)
    y = np.asarray(ys, dtype=np.float64)
    body, *_ = np.linalg.lstsq(a, y, rcond=None)
    est = np.asarray(full, dtype=np.float64) @ body
    est = np.maximum(est, 0.0)
    return {"flops": float(est[0]), "bytes": float(est[1]), "wire": float(est[2])}


def run_cell(arch: str, shape_name: str, multi_pod: bool, probes: bool = True,
             variant: str = "optimized") -> dict:
    from .mesh import make_production_mesh
    from .roofline import roofline, model_flops_for
    from ..configs import get_config
    from ..configs.shapes import applicable

    cfg = get_config(arch)
    ok, why = applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.devices.size
    t0 = time.time()
    mem, raw, coll, shape = _compile_cell(cfg, shape_name, mesh, variant)
    t_compile = time.time() - t0

    result = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "policy": variant,
        "status": "ok",
        "n_devices": int(n_devices),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": int(mem.argument_size_in_bytes),
            "output_bytes_per_device": int(mem.output_size_in_bytes),
            "temp_bytes_per_device": int(mem.temp_size_in_bytes),
            "alias_bytes_per_device": int(mem.alias_size_in_bytes),
            "peak_estimate_gib": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
        },
        "collectives": coll["ops"],
        "raw_scan_metrics": raw,  # while-body counted once; see probes
    }

    mf = model_flops_for(cfg, shape)
    # analytic HBM-traffic lower bound: every input byte read once, every
    # output byte written once (donated buffers alias, counted once)
    min_bytes = float(mem.argument_size_in_bytes + mem.output_size_in_bytes
                      - mem.alias_size_in_bytes)
    if probes and not multi_pod:
        t1 = time.time()
        est = corrected_metrics(cfg, shape_name, mesh, variant)
        result["probe_s"] = round(time.time() - t1, 1)
        cost = {"flops": est["flops"], "bytes accessed": est["bytes"]}
        coll_est = {"wire_bytes_per_device": est["wire"]}
        result["roofline"] = roofline(cost, coll_est, n_devices, mf, min_bytes).to_dict()
    else:
        cost = {"flops": raw["flops"], "bytes accessed": raw["bytes"]}
        coll_est = {"wire_bytes_per_device": raw["wire"]}
        result["roofline_raw"] = roofline(cost, coll_est, n_devices, mf, min_bytes).to_dict()
    return result


def all_cells():
    from ..configs import list_archs
    from ..configs.shapes import SHAPES
    # smallest archs first so results accumulate fast
    order = ["qwen1_5-0_5b", "qwen2-vl-2b", "whisper-medium", "chatglm3-6b",
             "qwen3-8b", "yi-9b", "falcon-mamba-7b", "zamba2-7b",
             "deepseek-v2-lite-16b", "deepseek-v3-671b"]
    for multi_pod in (False, True):
        for arch in order:
            for shape in SHAPES:
                yield arch, shape, multi_pod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--policy", default="optimized", choices=["baseline", "optimized"])
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        for arch, shape, multi_pod in all_cells():
            tag = f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"
            path = out_dir / f"{tag}.json"
            if path.exists() and not args.force:
                print(f"[skip-cached] {tag}", flush=True)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", str(out_dir),
                   "--policy", args.policy]
            if multi_pod:
                cmd.append("--multi-pod")
            print(f"[run] {tag}", flush=True)
            try:
                r = subprocess.run(cmd, timeout=args.timeout, capture_output=True, text=True)
                if r.returncode != 0:
                    err = (r.stderr or "")[-2000:]
                    path.write_text(json.dumps({
                        "arch": arch, "shape": shape, "multi_pod": multi_pod,
                        "status": "error", "stderr_tail": err}, indent=2))
                    print(f"[FAIL] {tag}: {err.splitlines()[-1] if err else '?'}", flush=True)
            except subprocess.TimeoutExpired:
                path.write_text(json.dumps({
                    "arch": arch, "shape": shape, "multi_pod": multi_pod,
                    "status": "timeout"}, indent=2))
                print(f"[TIMEOUT] {tag}", flush=True)
        return

    result = run_cell(args.arch, args.shape, args.multi_pod, variant=args.policy)
    tag = f"{args.arch}__{args.shape}__{'pod2' if args.multi_pod else 'pod1'}"
    path = out_dir / f"{tag}.json"
    path.write_text(json.dumps(result, indent=2))
    print(json.dumps(result, indent=2))
    if result["status"] == "ok":
        m = result["memory"]
        r = result.get("roofline") or result.get("roofline_raw")
        print(f"\n[{tag}] peak/device={m['peak_estimate_gib']} GiB  "
              f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s -> {r['bound']}-bound  "
              f"useful={r['useful_ratio']:.2%}")


if __name__ == "__main__":
    main()
