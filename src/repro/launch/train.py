"""Production LM training entry point.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --shape train_4k --smoke --steps 50 --ckpt-dir /tmp/run1 --auto-resume

On a real fleet this process runs per host with --coordinator/--process-id
(jax.distributed); in this container it runs single-process on the host mesh.
--smoke swaps in the reduced config so the loop actually executes on CPU;
without it the full config is used (dry-run scale — lower/compile only unless
you are on a pod).

Fault tolerance: atomic checkpoints every --ckpt-every steps, SIGTERM-safe,
--auto-resume restores params/opt/data-cursor, straggler watchdog logs.
Cross-pod gradient compression: --compress-grads (int8 + error feedback).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke_config, list_archs
from ..data import SyntheticLMStream, LMStreamConfig
from ..models.lm import LM
from ..optim import AdamW, schedule
from ..parallel import collectives
from ..runtime import TrainDriver, DriverConfig, resume_or_init
from .mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--auto-resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient sync over the 'pod' axis")
    ap.add_argument("--coordinator", default=None, help="jax.distributed coordinator")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(args.coordinator, args.num_processes, args.process_id)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    model = LM(cfg, mesh=mesh)
    opt = AdamW(lr=schedule.warmup_cosine(args.lr, 10, args.steps),
                clip_norm=1.0, weight_decay=0.01)
    stream = SyntheticLMStream(LMStreamConfig(cfg.vocab, args.seq, args.batch))

    params0 = model.init(jax.random.PRNGKey(0))
    err0 = collectives.init_error_state(params0) if args.compress_grads else None

    @jax.jit
    def train_step(params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        if args.compress_grads and "pod" in mesh.shape:
            grads, err = collectives.compressed_grad_sync(grads, err, mesh, "pod")
        params, opt_state = opt.apply(params, grads, opt_state)
        return params, opt_state, err, loss

    def step_fn(state, batch):
        params, opt_state, err = state
        batch = {"tokens": jnp.asarray(batch["tokens"])}
        params, opt_state, err, loss = train_step(params, opt_state, err, batch)
        return (params, opt_state, err), {"loss": float(loss)}

    ckpt = CheckpointManager(args.ckpt_dir, keep_last=3)
    template = (params0, opt.init(params0), err0)
    if args.auto_resume:
        state, start = resume_or_init(ckpt, template, lambda: template)
    else:
        state, start = template, 0

    drv = TrainDriver(DriverConfig(
        total_steps=args.steps, checkpoint_every=args.ckpt_every, log_every=10,
        metrics_path=f"{args.ckpt_dir}/metrics.jsonl"), ckpt)
    state, summary = drv.run(state, step_fn, stream.iterator(start_step=start),
                             start_step=start)
    print("summary:", summary)


if __name__ == "__main__":
    main()
