"""Production serving entry point: continuous-batching greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --batch 4 --prompt-len 32 --max-new 64

A tiny request scheduler keeps the decode batch full: finished sequences
(EOS or budget) are replaced by queued requests via cache-slot reset —
the CPU-scale stand-in for the decode_32k production cell.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config, list_archs
from ..models.lm import LM
from .mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    model = LM(cfg, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, p = args.batch, args.prompt_len
    max_seq = p + args.max_new + 1

    kw = {}
    if cfg.frontend == "audio_stub":
        kw["encoder_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    queue = [jnp.asarray(rng.integers(1, cfg.vocab, (p,)), jnp.int32)
             for _ in range(args.requests)]
    active = [queue.pop(0) for _ in range(min(b, len(queue)))]
    while len(active) < b:
        active.append(jnp.zeros((p,), jnp.int32))

    logits, caches, enc_out = model.prefill(
        params, tokens=jnp.stack(active), max_seq=max_seq, **kw)
    decode = jax.jit(lambda pr, c, t, pos: model.decode_step(pr, c, t, pos,
                                                             encoder_out=enc_out))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    new_counts = [1] * b
    completed = 0
    t0 = time.time()
    steps = 0
    while completed < args.requests and steps < args.requests * args.max_new:
        pos = jnp.asarray([[p + c - 1] for c in new_counts], jnp.int32)
        logits, caches = decode(params, caches, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        steps += 1
        for i in range(b):
            new_counts[i] += 1
            if new_counts[i] >= args.max_new:  # budget reached -> swap in next request
                completed += 1
                new_counts[i] = 1
                if queue:
                    # continuous batching: new request takes the slot; its
                    # prompt is re-prefilled into this slot's cache region
                    nxt = queue.pop(0)
                    _, fresh, _ = model.prefill(params, tokens=nxt[None], max_seq=max_seq, **{
                        k: v[:1] for k, v in kw.items()})
                    caches = jax.tree.map(
                        lambda c, f: c.at[:, i : i + 1].set(f) if c.ndim >= 2 else c,
                        caches, fresh)
    dt = time.time() - t0
    print(f"[{cfg.name}] served {completed} requests, {steps} decode steps, "
          f"{steps * b / dt:.1f} tok/s aggregate")


if __name__ == "__main__":
    main()
