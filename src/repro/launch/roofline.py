"""Roofline model: three terms (compute / memory / collective) per compiled cell.

TPU v5e constants (assignment-specified): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  `cost_analysis()` on an SPMD executable reports
*per-device* FLOPs/bytes, so terms divide by per-chip peaks directly.

Collective bytes are not in cost_analysis: we sweep the compiled HLO for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(including async -start forms; -done forms are skipped to avoid double
counting), sum operand bytes, parse replica-group sizes, and convert to
wire bytes per device with ring factors:

    all-reduce        2·S·(n-1)/n
    all-gather        S_shard·(n-1)        (operand is the local shard)
    reduce-scatter    S·(n-1)/n
    all-to-all        S·(n-1)/n
    collective-permute S

collective_term = wire_bytes / ICI_BW — a single-link model (a 2D-torus
multi-link schedule would divide by the number of usable links; we report
the conservative number and note it in EXPERIMENTS.md).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<kind>all-reduce-start|all-gather-start|collective-permute-start|"
    r"all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"\((?P<operands>[^)]*)\)(?P<tail>.*)$"
)
_TYPE_RE = re.compile(r"(pred|f8e4m3fn|f8e5m2|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(tail: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(tail)
    if m:
        return int(m.group(2))  # [G, n] -> n ranks per group
    m = _GROUPS_LIST_RE.search(tail)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


# wire bytes per device as a function of the *result* bytes S_res:
#   all-reduce        result == operand       -> 2·S·(n-1)/n
#   all-gather        result is the full buf  -> S·(n-1)/n
#   reduce-scatter    result is the shard     -> S·(n-1)
#   all-to-all        result == operand       -> S·(n-1)/n
#   collective-permute                        -> S
_RING_FACTOR = {
    "all-reduce": lambda s, n: 2.0 * s * (n - 1) / max(n, 1),
    "all-gather": lambda s, n: 1.0 * s * (n - 1) / max(n, 1),
    "reduce-scatter": lambda s, n: 1.0 * s * (n - 1),
    "all-to-all": lambda s, n: 1.0 * s * (n - 1) / max(n, 1),
    "collective-permute": lambda s, n: 1.0 * s,
}


def collective_stats(hlo_text: str, default_group: int) -> dict:
    """Sweep compiled HLO text; returns per-op-kind result/wire byte sums.

    Optimized HLO prints operands as bare %names, so sizes come from the
    instruction's *result* type.  Async -start forms are counted; -done
    forms don't match the result pattern (they return from a tuple) and
    -update forms are excluded by the regex.  For tuple results (-start
    ops), the last tuple element is the output buffer.
    """
    ops: dict[str, dict] = {}
    wire_total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group("kind").replace("-start", "")
        result = m.group("result")
        tail = m.group("tail")
        types = _TYPE_RE.findall(result)
        if not types:
            continue
        rbytes = _shape_bytes(*types[-1])
        n = _group_size(tail, default_group)
        wire = _RING_FACTOR[kind](rbytes, n)
        rec = ops.setdefault(kind, {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
        rec["count"] += 1
        rec["result_bytes"] += rbytes
        rec["wire_bytes"] += wire
        wire_total += wire
    return {"ops": ops, "wire_bytes_per_device": wire_total}


@dataclass
class Roofline:
    flops_per_device: float
    hlo_bytes_per_device: float      # XLA 'bytes accessed' — pre-fusion UPPER bound
    min_bytes_per_device: float      # arguments+outputs traffic — LOWER bound
    wire_bytes_per_device: float
    compute_s: float
    memory_upper_s: float
    memory_s: float                  # from the lower bound; used for the verdict
    collective_s: float
    bound: str
    model_flops: float
    useful_ratio: float

    def to_dict(self):
        return asdict(self)


def roofline(cost: dict, coll: dict, n_devices: int, model_flops: float,
             min_bytes: float = 0.0) -> Roofline:
    """Three-term roofline.  The memory term uses the analytic lower bound
    (inputs read once + outputs written once): XLA:CPU 'bytes accessed' counts
    every instruction's operands pre-TPU-fusion and overstates HBM traffic by
    ~10x; both numbers are reported (EXPERIMENTS.md §Roofline caveat)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    wire = float(coll["wire_bytes_per_device"])
    terms = {
        "compute": flops / PEAK_FLOPS,
        "memory": min_bytes / HBM_BW,
        "collective": wire / ICI_BW,
    }
    bound = max(terms, key=terms.get)
    useful = model_flops / max(flops * n_devices, 1.0)
    return Roofline(
        flops_per_device=flops,
        hlo_bytes_per_device=byts,
        min_bytes_per_device=min_bytes,
        wire_bytes_per_device=wire,
        compute_s=terms["compute"],
        memory_upper_s=byts / HBM_BW,
        memory_s=terms["memory"],
        collective_s=terms["collective"],
        bound=bound,
        model_flops=model_flops,
        useful_ratio=useful,
    )


def model_flops_for(cfg, shape) -> float:
    """6·N_active·D for training, 2·N_active·D for inference."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens
