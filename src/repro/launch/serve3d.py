"""Production serving entry point: multi-scene reconstruction service.

    PYTHONPATH=src python -m repro.launch.serve3d \
        --scenes 4 --iters 128 --slice 16 --renders-per-scene 3

Submits N procedural scene jobs and advances them scene-parallel: sessions
with matching configs form train cohorts that one member-axis compiled step
advances together per quantum (bit-identical to time-slicing — disable with
--max-cohort 1), with round-robin or earliest-deadline-first selection and a
bounded resident set using the continuous-batching slot-reset idiom.
Batched novel-view render requests are served mid-training from atomically
published snapshots through the redistributed render path (--dense-render
for the dense fallback).  A session guard (on by default — docs/ROBUSTNESS.md)
rolls diverged slices back to the last good checkpoint and quarantines
repeat offenders; --chaos demos it by injecting a NaN fault mid-run.
Fleet scale (docs/SERVING.md): --devices N shards sessions across a device
mesh (one cohort per device per quantum; on CPU pair it with
XLA_FLAGS=--xla_force_host_platform_device_count=N), --snapshot-levels k
streams cheap previews before the first full snapshot, and --async-serving
moves render drains onto a dedicated serving thread.
Prints per-session progress plus aggregate scenes/sec, render-latency
percentiles, and guard telemetry.
"""
from __future__ import annotations

import argparse

import numpy as np

from .. import kernels
from ..core import FieldConfig, TrainerConfig, occupancy
from ..core.rendering import RenderConfig, sphere_poses
from ..data import build_dataset
from ..obs import export as obs_export
from ..obs import trace as obs_trace
from ..serve3d import GuardConfig, ReconstructionService
from ..testing import faults


def build_service(args) -> tuple[ReconstructionService, dict]:
    render = RenderConfig(n_samples=args.samples)
    field_cfg = FieldConfig(
        n_levels=4, max_resolution=64,
        log2_table_density=12, log2_table_color=10,
    )
    trainer_cfg = TrainerConfig(
        n_rays=args.rays, render=render,
        occ=occupancy.OccupancyConfig(update_interval=8, warmup_steps=16),
        eval_chunk=args.hw * args.hw,
    )
    guard = (GuardConfig(checkpoint_every=args.guard_ckpt_every,
                         max_retries=args.guard_max_retries)
             if not args.no_guard else None)
    service = ReconstructionService(
        slice_iters=args.slice,
        policy=args.policy,
        max_resident=args.max_resident,
        persist_dir=args.persist_dir,
        max_cohort=args.max_cohort,
        redistributed_render=not args.dense_render,
        render_samples_per_ray=args.render_spr,
        guard=guard,
        render_deadline_s=args.render_deadline,
        shed_threshold=args.shed_threshold,
        devices=args.devices,
        snapshot_levels=args.snapshot_levels,
        async_serving=args.async_serving,
    )
    datasets = {}
    for i in range(args.scenes):
        _scene, ds = build_dataset(
            seed=i, n_views=args.views, h=args.hw, w=args.hw,
            cfg=render, gt_samples=args.gt_samples,
        )
        deadline = None
        if args.policy == "edf":
            # staggered deadlines: earlier scenes are more urgent
            deadline = 30.0 * (i + 1)
        sid = service.submit_scene(
            ds, field_cfg, trainer_cfg, target_iters=args.iters,
            seed=i, deadline=deadline,
        )
        datasets[sid] = ds
    return service, datasets


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenes", type=int, default=4)
    ap.add_argument("--iters", type=int, default=128, help="per-scene iterations")
    ap.add_argument("--slice", type=int, default=16, help="iterations per time slice")
    ap.add_argument("--policy", choices=["round_robin", "edf"], default="round_robin")
    ap.add_argument("--max-resident", type=int, default=None,
                    help="device slots; extra sessions queue (slot-reset admission)")
    ap.add_argument("--max-cohort", type=int, default=None,
                    help="train-cohort cap (default unlimited; 1 = pure time-slicing)")
    ap.add_argument("--dense-render", action="store_true",
                    help="serve renders dense instead of redistributed")
    ap.add_argument("--render-spr", type=int, default=None,
                    help="redistributed samples per ray (default n_samples // 4)")
    ap.add_argument("--renders-per-scene", type=int, default=3,
                    help="novel-view render requests submitted per scene mid-training")
    ap.add_argument("--rays", type=int, default=256)
    ap.add_argument("--samples", type=int, default=16)
    ap.add_argument("--hw", type=int, default=24)
    ap.add_argument("--views", type=int, default=6)
    ap.add_argument("--gt-samples", type=int, default=48)
    ap.add_argument("--persist-dir", default=None,
                    help="persist published snapshots (atomic per-session checkpoints)")
    ap.add_argument("--no-guard", action="store_true",
                    help="disable the session guard (slice errors unwind the run)")
    ap.add_argument("--guard-ckpt-every", type=int, default=4,
                    help="guard last-good checkpoint cadence, in healthy slices")
    ap.add_argument("--guard-max-retries", type=int, default=3,
                    help="consecutive rollbacks before a session is quarantined")
    ap.add_argument("--render-deadline", type=float, default=None,
                    help="per-request render deadline in seconds (expired "
                         "requests return a typed error instead of hanging)")
    ap.add_argument("--shed-threshold", type=int, default=None,
                    help="ready-request queue depth that triggers quality "
                         "shedding (halved samples per ray) before drops")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard sessions across the first N local devices "
                         "(session mesh; default: single-device service). "
                         "On CPU, force a mesh with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--snapshot-levels", type=int, default=0,
                    help="preview snapshot level k: publish cheap h>>k "
                         "previews every healthy slice until a scene's first "
                         "full snapshot lands (0 = full snapshots only)")
    ap.add_argument("--async-serving", action="store_true",
                    help="drive renders from a dedicated serving thread "
                         "instead of draining at the end of each quantum")
    ap.add_argument("--chaos", action="store_true",
                    help="demo fault injection: poison scene-001's params "
                         "with NaN mid-run and watch the guard roll it back")
    ap.add_argument("--backend", default=None)
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace JSON of the run (enables obs)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final metrics snapshot JSON (enables obs)")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="print a serve3d metrics snapshot every N quanta")
    args = ap.parse_args(argv)

    if args.trace_out or args.metrics_out or args.metrics_every:
        obs_trace.configure(enabled=True)

    be = kernels.set_backend(args.backend) if args.backend else kernels.get_backend()
    print(f"kernel backend: {be.name}")

    if args.chaos:
        if args.scenes < 2:
            raise SystemExit("--chaos needs at least 2 scenes")
        faults.configure(enabled=True)
        faults.inject("serve3d.slice", "nan_params", session="scene-001",
                      at_step=args.iters // 2, times=1)
        print("chaos: NaN-params fault armed for scene-001 "
              f"at step {args.iters // 2}")

    service, datasets = build_service(args)
    novel = sphere_poses(max(8, args.renders_per_scene), seed=123)
    # trigger steps must land on actual slice boundaries — event["step"] only
    # ever takes multiples of --slice, clamped to --iters on the final slice
    boundaries = list(range(args.slice, args.iters, args.slice)) + [args.iters]
    picks = np.linspace(0, len(boundaries) - 1,
                        min(args.renders_per_scene, len(boundaries)))
    slice_marks = {boundaries[int(round(i))] for i in picks}
    render_steps = {sid: slice_marks for sid in datasets}

    quanta = [0]

    def hook(svc, event):
        for sid in event["cohort"]:  # cohort members share the slice boundary
            if svc.sessions[sid].step in render_steps[sid]:
                k = svc.renderer.served.get(sid, 0) + svc.renderer.pending
                svc.request_render(sid, novel[k % len(novel)])
        for r in event["results"]:
            print(f"  render {r.session_id} req#{r.request_id} "
                  f"snapshot v{r.snapshot_version}@{r.snapshot_step} "
                  f"latency {r.latency_s * 1e3:.0f} ms")
        quanta[0] += 1
        if args.metrics_every and quanta[0] % args.metrics_every == 0:
            print(f"-- metrics @ quantum {quanta[0]} --")
            print(obs_export.format_metrics(svc.metrics(), prefix="serve3d."))

    tel = service.run(hook=hook)

    if args.trace_out:
        print(f"trace -> {service.dump_trace(args.trace_out)}")
    if args.metrics_out:
        obs_export.dump_metrics(args.metrics_out,
                                extra=service.metrics()["meta"])
        print(f"metrics -> {args.metrics_out}")
    print("\nper-session progress:")
    for p in tel["sessions"]:
        print(f"  {p['session_id']}: {p['status']} step {p['step']}/{p['target_iters']} "
              f"loss {p['loss']:.5f} train {p['train_wall_s']:.1f}s")
    r = tel["render"]
    print(f"\ndevices {tel['devices']}  scenes/sec {tel['scenes_per_sec']:.3f}  "
          f"renders {r.get('count', 0)}  "
          f"p50 {r.get('p50_ms', float('nan')):.0f} ms  p95 {r.get('p95_ms', float('nan')):.0f} ms")
    if tel["placement"] is not None:
        print(f"placement loads {tel['placement']['loads']}")
    g = tel.get("guard")
    if g is not None:
        print(f"guard: rollbacks {g['rollbacks']}  "
              f"quarantined {g['quarantined'] or 'none'}  "
              f"checkpoints {g['checkpoints']}  "
              f"publish retries {tel['publish_failures']}  "
              f"stragglers {tel['stragglers_flagged']}")
        if g["recovery_ms"]["count"]:
            print(f"guard recovery p50 {g['recovery_ms']['p50']:.1f} ms "
                  f"(n={g['recovery_ms']['count']})")
    if args.chaos:
        fired = faults.fired_count("nan_params")
        print(f"chaos: nan_params fired {fired}x, "
              f"guard rollbacks {g['rollbacks'] if g else 0}")
        faults.reset()
    return tel


if __name__ == "__main__":
    main()
