"""Fault-tolerant training driver.

Features (DESIGN.md §6):
  * periodic + final checkpointing through CheckpointManager (atomic, async)
  * preemption safety: SIGTERM/SIGINT triggers checkpoint-then-clean-exit
  * --auto-resume: restores the latest valid checkpoint, including the data
    cursor (deterministic streams restart exactly)
  * straggler watchdog: per-step wall time EWMA + deviation; steps slower
    than `ewma + straggler_sigma * dev` are flagged and counted — on a real
    fleet this hook triggers re-slicing; here it logs and records
  * metrics JSONL for offline analysis
"""
from __future__ import annotations

import json
import signal
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Iterator

from ..checkpoint.manager import CheckpointManager


@dataclass
class DriverConfig:
    total_steps: int = 1000
    checkpoint_every: int = 200
    log_every: int = 20
    straggler_sigma: float = 4.0
    ewma_alpha: float = 0.05
    metrics_path: str | None = None


@dataclass
class StragglerStats:
    ewma: float = 0.0
    dev: float = 0.0
    n_flagged: int = 0
    initialized: bool = False

    def update(self, dt: float, sigma: float, alpha: float) -> bool:
        if not self.initialized:
            self.ewma, self.dev, self.initialized = dt, dt * 0.1, True
            return False
        flagged = dt > self.ewma + sigma * max(self.dev, 1e-9)
        self.dev = (1 - alpha) * self.dev + alpha * abs(dt - self.ewma)
        self.ewma = (1 - alpha) * self.ewma + alpha * dt
        if flagged:
            self.n_flagged += 1
        return flagged


class TrainDriver:
    def __init__(self, cfg: DriverConfig, ckpt: CheckpointManager):
        self.cfg = cfg
        self.ckpt = ckpt
        self.straggler = StragglerStats()
        self._preempted = False
        self._metrics_f = open(cfg.metrics_path, "a") if cfg.metrics_path else None

    def _install_signals(self):
        def handler(signum, frame):
            self._preempted = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not main thread (tests)

    def _log(self, record: dict):
        if self._metrics_f:
            self._metrics_f.write(json.dumps(record) + "\n")
            self._metrics_f.flush()

    def run(
        self,
        state: Any,
        step_fn: Callable[[Any, Any], tuple[Any, dict]],
        batch_iter: Iterator,
        start_step: int = 0,
        state_for_ckpt: Callable[[Any], Any] | None = None,
    ):
        """Generic loop: state, batch -> (state, metrics).  Returns (state, summary)."""
        cfg = self.cfg
        self._install_signals()
        to_ckpt = state_for_ckpt or (lambda s: s)
        step = start_step
        flagged_steps = []

        while step < cfg.total_steps:
            batch = next(batch_iter)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            dt = time.perf_counter() - t0
            step += 1

            if self.straggler.update(dt, cfg.straggler_sigma, cfg.ewma_alpha):
                flagged_steps.append(step)
                self._log({"event": "straggler", "step": step, "dt": dt,
                           "ewma": self.straggler.ewma})

            if step % cfg.log_every == 0:
                self._log({"event": "train", "step": step, "dt": dt, **metrics})

            if step % cfg.checkpoint_every == 0:
                self.ckpt.save(step, to_ckpt(state), extra={"data_cursor": step})

            if self._preempted:
                self.ckpt.save(step, to_ckpt(state), extra={"data_cursor": step,
                                                            "preempted": True}, block=True)
                self._log({"event": "preempt_exit", "step": step})
                return state, {"step": step, "preempted": True,
                               "stragglers": flagged_steps}

        self.ckpt.save(step, to_ckpt(state), extra={"data_cursor": step}, block=True)
        self.ckpt.wait()
        return state, {"step": step, "preempted": False, "stragglers": flagged_steps}


def resume_or_init(ckpt: CheckpointManager, template: Any, init_fn: Callable[[], Any],
                   shardings=None):
    """--auto-resume entry: latest valid checkpoint or fresh init."""
    try:
        state, meta = ckpt.restore(template, shardings=shardings)
        return state, int(meta.get("data_cursor", meta["step"]))
    except FileNotFoundError:
        return init_fn(), 0
