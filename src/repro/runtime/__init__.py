from .driver import TrainDriver, DriverConfig, StragglerStats, resume_or_init  # noqa: F401
