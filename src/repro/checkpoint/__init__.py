from .manager import CheckpointManager, tree_to_flat, flat_to_tree  # noqa: F401
