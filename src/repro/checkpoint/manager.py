"""Checkpointing: atomic, hashed, async, keep-k, elastic across meshes.

Layout:  <dir>/step_{N:08d}/{arrays.npz, meta.json}
Commit protocol: write into `tmp_step_N`, fsync, rename — a crash mid-save
never corrupts the latest checkpoint.  `meta.json` stores a per-file sha256
map (``files``) computed at save and verified at load; a corrupt or torn
checkpoint is rejected and `restore` falls back to the previous valid step
instead of loading bad bytes.  Arrays are stored as plain numpy keyed by
tree path, so a checkpoint written on one mesh restores onto any other mesh
(re-sharding happens at `device_put` with the new sharding) — this is the
elastic-scaling path: 256-chip checkpoints resume on 128 or 512 chips.

Fault sites (``repro.testing.faults``, site ``checkpoint.write``):
``kill_mid_write`` raises after the array file lands but before the atomic
rename — the torn tmp dir must never shadow the previous checkpoint;
``corrupt`` flips bytes in the committed array file after the checksum was
taken — the per-file verification must reject it at restore.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

from ..testing import faults


def _file_digest(path: Path) -> str:
    return hashlib.sha256(path.read_bytes()).hexdigest()


def _path_key(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def tree_to_flat(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {_path_key(p): np.asarray(l) for p, l in flat}


def flat_to_tree(template, flat: dict[str, np.ndarray]):
    """Rebuild a pytree shaped like `template` from flat path->array."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, tmpl in paths:
        key = _path_key(p)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs model {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_last: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ---- save ----

    def save(self, step: int, tree: Any, extra: dict | None = None, block: bool = False):
        """Snapshot to host memory synchronously; write to disk (async by default)."""
        flat = tree_to_flat(jax.device_get(tree))  # host copy happens here
        if self.async_save and not block:
            self.wait()  # one in-flight save at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, flat, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, extra: dict):
        tmp = self.dir / f"tmp_step_{step:08d}"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        npz_path = tmp / "arrays.npz"
        with open(npz_path, "wb") as f:
            np.savez(f, **flat)
            f.flush()
        # per-file checksum map, written at save and verified at restore; the
        # legacy top-level "sha256" is kept so old readers keep working
        files = {"arrays.npz": _file_digest(npz_path)}
        meta = {"step": step, "time": time.time(),
                "sha256": files["arrays.npz"], "files": files, **extra}
        (tmp / "meta.json").write_text(json.dumps(meta, indent=2))
        inj = faults.check("checkpoint.write", step=int(step))
        if inj is not None:
            if inj.kind == "kill_mid_write":
                # simulated crash between data write and atomic rename: the
                # torn tmp dir stays behind, the previous checkpoint stays
                # the latest valid one
                raise faults.InjectedFault(f"kill_mid_write at step {step}")
            if inj.kind == "corrupt":
                # bit-rot after the checksum was taken: the commit succeeds
                # but per-file verification must reject it at restore
                faults.corrupt_file(npz_path,
                                    n_bytes=int(inj.params.get("n_bytes", 64)))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---- restore ----

    def all_steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*") if p.is_dir()
        )

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _verify(self, step: int) -> bool:
        """Checksum-verify every file the checkpoint's meta lists.  Any
        missing/unparseable/mismatching file rejects the whole step —
        `restore` then falls back to the previous valid one."""
        d = self.dir / f"step_{step:08d}"
        try:
            meta = json.loads((d / "meta.json").read_text())
            # legacy checkpoints (pre per-file map) carry one top-level hash
            files = meta.get("files") or {"arrays.npz": meta["sha256"]}
            return all(_file_digest(d / name) == want
                       for name, want in files.items())
        except Exception:
            return False

    def restore(self, template: Any, step: int | None = None, shardings=None):
        """Restore into the structure of `template` (arrays or ShapeDtypeStructs).
        With `shardings` (a matching tree of NamedSharding), leaves are placed
        sharded — this is how a checkpoint moves between mesh sizes."""
        candidates = [step] if step is not None else list(reversed(self.all_steps()))
        for s in candidates:
            if s is None or not self._verify(s):
                continue
            with np.load(self.dir / f"step_{s:08d}" / "arrays.npz") as z:
                flat = {k: z[k] for k in z.files}
            tree = flat_to_tree(template, flat)
            if shardings is not None:
                tree = jax.tree.map(lambda a, sh: jax.device_put(a, sh), tree, shardings)
            meta = json.loads((self.dir / f"step_{s:08d}" / "meta.json").read_text())
            return tree, meta
        raise FileNotFoundError(f"no valid checkpoint in {self.dir}")
