"""Checkpointing: atomic, hashed, async, keep-k, elastic across meshes.

Layout:  <dir>/step_{N:08d}/{arrays.npz, meta.json}
Commit protocol: write into `tmp_step_N`, fsync, rename — a crash mid-save
never corrupts the latest checkpoint.  `meta.json` stores a content hash so a
torn read is detected at restore.  Arrays are stored as plain numpy keyed by
tree path, so a checkpoint written on one mesh restores onto any other mesh
(re-sharding happens at `device_put` with the new sharding) — this is the
elastic-scaling path: 256-chip checkpoints resume on 128 or 512 chips.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _path_key(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def tree_to_flat(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {_path_key(p): np.asarray(l) for p, l in flat}


def flat_to_tree(template, flat: dict[str, np.ndarray]):
    """Rebuild a pytree shaped like `template` from flat path->array."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, tmpl in paths:
        key = _path_key(p)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs model {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_last: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ---- save ----

    def save(self, step: int, tree: Any, extra: dict | None = None, block: bool = False):
        """Snapshot to host memory synchronously; write to disk (async by default)."""
        flat = tree_to_flat(jax.device_get(tree))  # host copy happens here
        if self.async_save and not block:
            self.wait()  # one in-flight save at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, flat, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, extra: dict):
        tmp = self.dir / f"tmp_step_{step:08d}"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        npz_path = tmp / "arrays.npz"
        with open(npz_path, "wb") as f:
            np.savez(f, **flat)
            f.flush()
        digest = hashlib.sha256(npz_path.read_bytes()).hexdigest()
        meta = {"step": step, "time": time.time(), "sha256": digest, **extra}
        (tmp / "meta.json").write_text(json.dumps(meta, indent=2))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---- restore ----

    def all_steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*") if p.is_dir()
        )

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _verify(self, step: int) -> bool:
        d = self.dir / f"step_{step:08d}"
        try:
            meta = json.loads((d / "meta.json").read_text())
            digest = hashlib.sha256((d / "arrays.npz").read_bytes()).hexdigest()
            return digest == meta["sha256"]
        except Exception:
            return False

    def restore(self, template: Any, step: int | None = None, shardings=None):
        """Restore into the structure of `template` (arrays or ShapeDtypeStructs).
        With `shardings` (a matching tree of NamedSharding), leaves are placed
        sharded — this is how a checkpoint moves between mesh sizes."""
        candidates = [step] if step is not None else list(reversed(self.all_steps()))
        for s in candidates:
            if s is None or not self._verify(s):
                continue
            with np.load(self.dir / f"step_{s:08d}" / "arrays.npz") as z:
                flat = {k: z[k] for k in z.files}
            tree = flat_to_tree(template, flat)
            if shardings is not None:
                tree = jax.tree.map(lambda a, sh: jax.device_put(a, sh), tree, shardings)
            meta = json.loads((self.dir / f"step_{s:08d}" / "meta.json").read_text())
            return tree, meta
        raise FileNotFoundError(f"no valid checkpoint in {self.dir}")
