"""The paper's contribution: Instant-3D decomposed hash-grid NeRF training."""
from .encoding import HashEncoding, HashGridConfig, sh_encoding, sh_dim  # noqa: F401
from .field import Field, FieldConfig, trunc_exp  # noqa: F401
from .rendering import RenderConfig, RayBatch, render_rays, sample_ts, pixel_rays, sphere_poses  # noqa: F401
from .pipeline import RenderPipeline, suggest_budget  # noqa: F401
from .trainer import Instant3DTrainer, TrainerConfig, TrainState, train_cohort  # noqa: F401
from . import losses, occupancy  # noqa: F401
