"""Staged render pipeline with occupancy-compacted field queries.

The paper's central bottleneck is hash-grid interpolation traffic
(~200k lookups/iteration); Instant-3D wins by *not issuing* memory traffic
for samples the occupancy grid already culled.  The monolithic
`rendering.render_rays` queried the field at all B×S points and only zeroed
sigma afterward — empty-space skipping saved no compute.  This module splits
rendering into explicit stages so the field only ever sees live points:

    1. generate_samples   rays × ts -> world points, per-sample dirs
    2. cull               AABB test + occupancy-bitfield lookup -> live mask
    3. compact            stable argsort to a fixed, jit-stable `budget` of
                          points, live-first in Morton (Z-order) key order
                          so spatially adjacent points share kernel blocks
                          (overflow accounted)
    4. shade              hash-encode + MLPs on the compacted set only; by
                          default via the fused path (one encode pass over
                          all grids, pre-sorted BUM backward)
    5. scatter/composite  scatter sigma/rgb back to B×S, volume-render

The budget is a *static* python int (it fixes compiled shapes); callers pick
it from a measured live fraction — `suggest_budget` buckets to powers of two
so recompiles are bounded.  With `budget=None` the pipeline runs the dense
path (query everything, mask sigma), which is also the autodiff oracle the
compaction tests compare against.

Compaction is differentiable: gather of points/dirs carries no parameter
gradient, and the scatter of (sigma, rgb) is a permutation `.at[idx].set`
whose VJP is the corresponding gather — gradients w.r.t. field params match
the dense path exactly whenever every live point fits in the budget.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import occupancy as occ_lib
from . import rendering as _r
from ..kernels.volume_render import ops as vr_ops
from ..kernels.fused_path import ref as fp_ref


def _cube_root(n: int) -> int:
    r = round(n ** (1.0 / 3.0))
    for cand in (r - 1, r, r + 1):
        if cand > 0 and cand ** 3 == n:
            return cand
    raise ValueError(f"bitfield length {n} is not a cube")


def suggest_budget(
    live_fraction: float,
    n_total: int,
    *,
    headroom: float = 1.3,
    min_budget: int = 512,
) -> int:
    """Pow2-bucketed point budget for a measured live fraction.

    Bucketing bounds the number of distinct compiled shapes to
    O(log2(n_total / min_budget)); headroom absorbs drift between the
    measurement (e.g. occupancy fraction at the last grid update) and the
    live fraction of the current batch.
    """
    want = int(n_total * min(1.0, max(0.0, live_fraction) * headroom))
    b = min_budget
    while b < want:
        b *= 2
    return min(b, n_total)


class CompactionPlan(NamedTuple):
    idx: jnp.ndarray       # (budget,) unique flat-sample indices, live-first
    keep: jnp.ndarray      # (budget,) bool — False on padded dead lanes
    n_live: jnp.ndarray    # () int32 total live points before compaction
    overflow: jnp.ndarray  # () int32 live points dropped (budget too small)


class RenderPipeline:
    """Callable pipeline; stages are exposed as methods for testing/benching.

    fused_path: route the compacted shade stage through the field's fused
    query (one encode pass over all grids, FMU-deduplicated corner reads,
    pre-sorted BUM backward).  Only the budgeted branch is affected; the
    dense path always uses the plain per-grid query.  On the ref backend the
    fused query is bit-identical to the unfused one, so this knob changes
    where the work happens, never the numbers.
    """

    def __init__(self, field, cfg: _r.RenderConfig, *, fused_path: bool = True):
        self.field = field
        self.cfg = cfg
        self.fused_path = fused_path and hasattr(field, "query_fused")

    # ---- stage 1: sample generation ----

    def generate_samples(self, origins, dirs, ts):
        """-> (flat world points (N,3), flat dirs (N,3), unit coords (N,3))."""
        points = origins[:, None, :] + ts[..., None] * dirs[:, None, :]  # (B,S,3)
        flat_pts = points.reshape(-1, 3)
        flat_dirs = jnp.broadcast_to(dirs[:, None, :], points.shape).reshape(-1, 3)
        unit = _r.normalize_points(flat_pts, self.cfg)
        return flat_pts, flat_dirs, unit

    # ---- stage 2: cull ----

    def cull(self, flat_pts, unit, bitfield=None, mask_fn=None):
        """AABB + occupancy liveness.  bitfield is a (R^3,) bool array (the
        jit-traceable form from occupancy.bitfield); mask_fn is the legacy
        closure hook kept for render_rays compatibility."""
        live = _r.inside_aabb(flat_pts, self.cfg)
        if bitfield is not None:
            r = _cube_root(bitfield.shape[0])
            live = live & occ_lib.point_liveness(bitfield, unit, r)
        if mask_fn is not None:  # composes with the bitfield when both given
            live = live & mask_fn(unit)
        return live

    # ---- stage 3: compact ----

    def compact(self, live, budget: int, unit=None) -> CompactionPlan:
        """Live-first compaction to a fixed budget, padded with dead samples.

        With `unit` coords given, the live set is ordered by Morton (Z-order)
        key instead of flat sample order: spatially adjacent points land in
        the same kernel block, which is what makes the fused path's corner
        reads coalescible (FMU) and its backward update stream quasi-sorted
        (BUM).  Costs nothing — the single stable argsort just sorts a
        different key (dead lanes get the max key, so they still pad the
        tail).  Without `unit`, falls back to the PR 1 flat-order behavior.

        Overflow caveat: when n_live > budget the dropped live points are
        the highest Morton keys (the box corner nearest (1,1,1)) instead of
        flat order's end-of-batch rays — either truncation is systematic,
        and the trainer reacts the same way (widens the next budget bucket).
        """
        if unit is None:
            order = jnp.argsort(jnp.logical_not(live))  # stable: live-first
        else:
            key = fp_ref.morton_key(unit)
            key = jnp.where(live, key, jnp.uint32(0xFFFFFFFF))
            order = jnp.argsort(key)  # stable: live in Z-order, dead last
        idx = order[:budget]
        n_live = jnp.sum(live.astype(jnp.int32))
        keep = live[idx]
        overflow = jnp.maximum(n_live - budget, 0)
        return CompactionPlan(idx, keep, n_live, overflow)

    # ---- stage 4: shade ----

    def shade(self, params, unit, dirs, fused: bool = False):
        if fused:
            return self.field.query_fused(params, unit, dirs)
        return self.field.query(params, unit, dirs)

    # ---- stage 5: scatter + composite ----

    def composite(self, sigma, rgb, ts):
        b, s = ts.shape
        deltas = jnp.diff(ts, axis=-1, append=ts[:, -1:] + (self.cfg.far - self.cfg.near) / s)
        out = vr_ops.composite(sigma.reshape(b, s), rgb.reshape(b, s, 3), deltas, ts)
        color = out.color
        if self.cfg.white_background:
            color = color + (1.0 - out.opacity[..., None])
        return {
            "rgb": color,
            "depth": out.depth,
            "opacity": out.opacity,
            "weights": out.weights,
        }

    # ---- full pipeline ----

    def __call__(
        self,
        params,
        origins,
        dirs,
        ts,
        *,
        bitfield=None,
        mask_fn=None,
        budget: int | None = None,
    ):
        """Render a ray batch.  budget MUST be a static python int (or None
        for the dense path) — it fixes the compiled point-batch shape."""
        b, s = ts.shape
        n = b * s
        flat_pts, flat_dirs, unit = self.generate_samples(origins, dirs, ts)
        live = self.cull(flat_pts, unit, bitfield=bitfield, mask_fn=mask_fn)

        if budget is None:
            sigma, rgb = self.shade(params, unit, flat_dirs)
            sigma = jnp.where(live, sigma, 0.0)
            n_live = jnp.sum(live.astype(jnp.int32))
            overflow = jnp.zeros((), jnp.int32)
            points_queried = n
        else:
            budget = min(int(budget), n)
            plan = self.compact(live, budget, unit)
            sigma_c, rgb_c = self.shade(
                params, unit[plan.idx], flat_dirs[plan.idx], fused=self.fused_path
            )
            sigma = jnp.zeros((n,), sigma_c.dtype).at[plan.idx].set(
                jnp.where(plan.keep, sigma_c, 0.0)
            )
            rgb = jnp.zeros((n, 3), rgb_c.dtype).at[plan.idx].set(
                rgb_c * plan.keep[:, None].astype(rgb_c.dtype)
            )
            n_live, overflow = plan.n_live, plan.overflow
            points_queried = budget

        out = self.composite(sigma, rgb, ts)
        out.update(
            live_fraction=jnp.mean(live.astype(jnp.float32)),
            n_live=n_live,
            overflow=overflow,
            points_queried=jnp.asarray(points_queried, jnp.int32),
        )
        return out
