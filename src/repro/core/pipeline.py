"""Staged render pipeline with occupancy-compacted field queries.

The paper's central bottleneck is hash-grid interpolation traffic
(~200k lookups/iteration); Instant-3D wins by *not issuing* memory traffic
for samples the occupancy grid already culled.  The monolithic
`rendering.render_rays` queried the field at all B×S points and only zeroed
sigma afterward — empty-space skipping saved no compute.  This module splits
rendering into explicit stages so the field only ever sees live points:

    1. generate_samples   rays × ts -> world points, per-sample dirs
    2. cull               AABB test + occupancy-bitfield lookup -> live mask
   2b. redistribute       (optional) re-spend each ray's freed sample budget
                          on its live occupancy segments: inverse-CDF
                          placement over the per-ray live-bin mask, reduced
                          per-ray count S' = budget // B so the total point
                          budget stays at or below the pow2 bucket; emits
                          per-sample quadrature deltas (dt is no longer the
                          uniform stratum width)
    3. compact            stable argsort to a fixed, jit-stable `budget` of
                          points, live-first in Morton (Z-order) key order
                          so spatially adjacent points share kernel blocks
                          (overflow accounted)
    4. shade              hash-encode + MLPs on the compacted set only; by
                          default via the fused path (one encode pass over
                          all grids, pre-sorted BUM backward)
    5. scatter/composite  scatter sigma/rgb back to B×S, volume-render
                          (variable-spacing quadrature when 2b ran)

The budget is a *static* python int (it fixes compiled shapes); callers pick
it from a measured live fraction — `suggest_budget` buckets to powers of two
so recompiles are bounded.  With `budget=None` the pipeline runs the dense
path (query everything, mask sigma), which is also the autodiff oracle the
compaction tests compare against.

Compaction is differentiable: gather of points/dirs carries no parameter
gradient, and the scatter of (sigma, rgb) is a permutation `.at[idx].set`
whose VJP is the corresponding gather — gradients w.r.t. field params match
the dense path exactly whenever every live point fits in the budget.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import occupancy as occ_lib
from . import rendering as _r
from ..kernels.volume_render import ops as vr_ops
from ..kernels.volume_render import ref as vr_ref
from ..kernels.fused_path import ref as fp_ref
from ..obs import trace as _trace


def _cube_root(n: int) -> int:
    r = round(n ** (1.0 / 3.0))
    for cand in (r - 1, r, r + 1):
        if cand > 0 and cand ** 3 == n:
            return cand
    raise ValueError(f"bitfield length {n} is not a cube")


def suggest_budget(
    live_fraction: float,
    n_total: int,
    *,
    headroom: float = 1.3,
    min_budget: int = 512,
    max_budget: int | None = None,
) -> int:
    """Pow2-bucketed point budget for a measured live fraction.

    Bucketing bounds the number of distinct compiled shapes to
    O(log2(n_total / min_budget)); headroom absorbs drift between the
    measurement (e.g. occupancy fraction at the last grid update) and the
    live fraction of the current batch.

    max_budget models a hard per-step point ceiling (on-device memory or
    latency caps).  When it clamps the bucket *below* the live count the
    uniform sampler must drop live points every step (systematic Morton-tail
    truncation — see `compact`); the redistribute stage is the graceful
    alternative, spending exactly the ceiling with zero overflow.
    """
    want = int(n_total * min(1.0, max(0.0, live_fraction) * headroom))
    b = min_budget
    while b < want:
        b *= 2
    b = min(b, n_total)
    if max_budget is not None:
        b = min(b, int(max_budget))
    return b


class CompactionPlan(NamedTuple):
    idx: jnp.ndarray       # (budget,) unique flat-sample indices, live-first
    keep: jnp.ndarray      # (budget,) bool — False on padded dead lanes
    n_live: jnp.ndarray    # () int32 total live points before compaction
    overflow: jnp.ndarray  # () int32 live points dropped (budget too small)


class RenderPipeline:
    """Callable pipeline; stages are exposed as methods for testing/benching.

    fused_path: route the compacted shade stage through the field's fused
    query (one encode pass over all grids, FMU-deduplicated corner reads,
    pre-sorted BUM backward).  Only the budgeted branch is affected; the
    dense path always uses the plain per-grid query.  On the ref backend the
    fused query is bit-identical to the unfused one, so this knob changes
    where the work happens, never the numbers.

    fused_step: with the fused path on, collapse the shade stage further into
    the field's ONE-kernel step (`field.query_step`): encode + both MLP heads
    in a single differentiable op with the residual policy from the field
    config.  Bit-identical to the fused encode + separate MLPs on the ref
    backend; fields without `query_step` (or non-decomposed ones) fall back
    to `query_fused` inside the field, so the knob is always safe to leave on.

    redistribute: adaptive ray marching (stage 2b).  With a bitfield and a
    budget present, each ray's fixed S-sample budget is re-spent on its live
    occupancy segments: S' = budget // B samples per ray, placed by
    inverse-CDF over the per-ray liveness of the uniform candidate samples,
    so every point the compacted shade stage pays for lands in live space
    with finer stratification — and the point budget is spent evenly across
    rays (no overflow ever), instead of Morton-tail truncation when a hard
    budget ceiling bites.  When the knob is off (the default), every code
    path is byte-for-byte the uniform sampler: the stage is never traced,
    deltas fall back to the `jnp.diff` stratum widths, and results are
    bit-identical to a pipeline built without the knob.

    redistribute_v3: density-weighted, workload-balanced stage 2b.  Two
    upgrades over v2, same gating discipline (knob off => never traced):

    * each live stratum is weighted by the *occupancy EMA* of its cell
      (saturating alpha weight, see `v3_stratum_weights`) instead of the
      binary live/dead vote, so in-ray placement concentrates where the
      surface actually is;
    * the fixed per-ray split S' = budget // B becomes a per-ray variable
      S'_i allocated by one global inverse-CDF over the batch's per-ray
      live masses — rays with long live segments get more of the point
      budget, dead-heavy rays keep a floor of 1, and `sum(S'_i) <= budget`
      holds by construction (see `v3_plan`).  The ragged rays live in a
      fixed (B, S_cap) lane grid with a validity mask; the compact stage
      packs the valid lanes Morton-ordered into the caller's exact budget
      with zero overflow, so ragged allocation costs no compiled-shape
      churn.  `v3_oversub` bounds S_cap (the densest ray can take at most
      oversub × the even split).
    """

    def __init__(self, field, cfg: _r.RenderConfig, *, fused_path: bool = True,
                 fused_step: bool = True, redistribute: bool = False,
                 redistribute_v3: bool = False, v3_oversub: int = 4):
        self.field = field
        self.cfg = cfg
        self.fused_path = fused_path and hasattr(field, "query_fused")
        self.fused_step = (
            self.fused_path and fused_step and hasattr(field, "query_step")
        )
        # v3 subsumes v2: it is the same stage slot, so turning it on takes
        # the 2b branch over even if the v2 knob is also set.
        self.redistribute_on = redistribute or redistribute_v3
        self.redistribute_v3_on = redistribute_v3
        self.v3_oversub = int(v3_oversub)

    # ---- stage 1: sample generation ----

    def generate_samples(self, origins, dirs, ts):
        """-> (flat world points (N,3), flat dirs (N,3), unit coords (N,3)).

        N = B·S flattens row-major (ray-major, then sample), so index
        `i*S + k` is ray i's k-th sample — the scatter in stage 5 relies on
        this layout to reshape back to (B, S).  `unit` is the [0,1)^3 coord
        every grid lookup (hash encode, occupancy, Morton key) consumes;
        world points only feed the AABB test.  Works for any ts — uniform
        strata or stage 2b's adaptive placements."""
        points = origins[:, None, :] + ts[..., None] * dirs[:, None, :]  # (B,S,3)
        flat_pts = points.reshape(-1, 3)
        flat_dirs = jnp.broadcast_to(dirs[:, None, :], points.shape).reshape(-1, 3)
        unit = _r.normalize_points(flat_pts, self.cfg)
        return flat_pts, flat_dirs, unit

    # ---- stage 2: cull ----

    def cull(self, flat_pts, unit, bitfield=None, mask_fn=None):
        """AABB + occupancy liveness.  bitfield is a (R^3,) bool array (the
        jit-traceable form from occupancy.bitfield); mask_fn is the legacy
        closure hook kept for render_rays compatibility."""
        live = _r.inside_aabb(flat_pts, self.cfg)
        if bitfield is not None:
            r = _cube_root(bitfield.shape[0])
            live = live & occ_lib.point_liveness(bitfield, unit, r)
        if mask_fn is not None:  # composes with the bitfield when both given
            live = live & mask_fn(unit)
        return live

    # ---- stage 2b: redistribute (adaptive ray marching) ----

    def redistribute(self, ts, live, *, n_out: int | None = None):
        """Inverse-CDF sample redistribution over live occupancy segments.

        `live` (B, S) is the cull-stage liveness of the incoming stratified
        samples (stage 2 on the uniform candidates) — it doubles as the
        per-ray occupancy probe.  Using the *jittered* samples as probes
        (instead of, say, fixed stratum midpoints) matters: a stratum that
        partially overlaps a live cell flickers live/dead with the
        stratified jitter, so every region receives samples in expectation
        across steps.  A deterministic probe would carve permanent per-ray
        blind spots into training — live surface slivers between two dead
        probe points would never be sampled on any step.

        The live mask becomes each ray's piecewise-constant live-length CDF
        over the S strata, and `n_out` stratified samples are placed by
        inverting it.  Rays with no live stratum fall back to the uniform
        CDF (they carry no radiance; compositing still needs monotone ts).
        In-stratum jitter is likewise reused from `ts`, so the stage is a
        pure deterministic function of (ts, live) — no extra rng plumbing,
        and training streams stay reproducible under suspend/resume.

        Returns (ts_new (B, n_out), deltas (B, n_out)):

        * ts_new is ascending per ray and lands only in live strata (up to
          the uniform fallback);
        * deltas are the per-sample quadrature widths dt_k = h / (p_k · S')
          — the live arc length each sample represents; summed per ray they
          equal the ray's live length, so `composite` integrates the same
          transmittance as a dense quadrature over live space (dead gaps
          between segments contribute exactly zero because no sample's dt
          spans them).
        """
        b, s = ts.shape
        n_out = s if n_out is None else int(n_out)
        near, far = self.cfg.near, self.cfg.far
        h = (far - near) / s

        w = live.astype(jnp.float32)                       # (B, S)
        total = jnp.sum(w, axis=-1, keepdims=True)
        w = jnp.where(total > 0, w, 1.0)                   # dead ray -> uniform
        pdf = w / jnp.sum(w, axis=-1, keepdims=True)
        cdf = jnp.cumsum(pdf, axis=-1)

        # stratified u in (0,1): stratum index from n_out, jitter from ts
        jitter = (ts[:, :n_out] - near) / (far - near) * s - jnp.arange(n_out)
        jitter = jnp.clip(jitter, 0.0, 1.0 - 1e-6)
        u = (jnp.arange(n_out) + jitter) / n_out           # (B, n_out) ascending
        u = u * cdf[:, -1:]                                # absorb cumsum rounding

        j = jax.vmap(lambda c, uu: jnp.searchsorted(c, uu, side="right"))(cdf, u)
        j = jnp.clip(j, 0, s - 1)
        cdf_lo = jnp.where(
            j > 0, jnp.take_along_axis(cdf, jnp.maximum(j - 1, 0), axis=-1), 0.0
        )
        p = jnp.maximum(jnp.take_along_axis(pdf, j, axis=-1), 1e-12)
        frac = jnp.clip((u - cdf_lo) / p, 0.0, 1.0 - 1e-6)
        ts_new = near + (j.astype(jnp.float32) + frac) * h
        deltas = h / (p * n_out)
        return ts_new, deltas

    # ---- stage 2b, v3: density-weighted, workload-balanced ----

    # Weight floor for live strata: keeps every live cell sampleable even
    # when its EMA alpha is ~0 (fresh surfaces, warmup), and bounds the
    # concentration ratio between the densest and thinnest live stratum to
    # (floor + 1) / floor ≈ 21 — raw EMA ratios span ~1e4 and would starve
    # low-density live cells entirely.
    V3_WEIGHT_FLOOR = 0.05

    def v3_stratum_weights(self, live, ema_vals):
        """Per-stratum sampling weight (B, S) f32 for the v3 CDF.

        `live` bool (B, S) from the cull probe; `ema_vals` (B, S) the
        occupancy EMA of each candidate's cell (`occupancy.point_density`),
        or None when no EMA is available (serving without state, tests).
        The weight is the stratum's saturating alpha `1 - exp(-ema * h)` —
        the fraction of light a stratum of width h at the cell's EMA
        density would absorb — plus the floor, masked to live strata.
        With ema=None it degrades to `floor * live`: a uniform live-strata
        CDF, i.e. exactly v2's placement density."""
        b, s = live.shape
        h = (self.cfg.far - self.cfg.near) / s
        w = jnp.full((b, s), self.V3_WEIGHT_FLOOR, jnp.float32)
        if ema_vals is not None:
            w = w + 1.0 - jnp.exp(-jnp.maximum(ema_vals, 0.0) * h)
        return live.astype(jnp.float32) * w

    def v3_plan(self, ts, live, ema_vals, budget: int):
        """Global ragged-allocation plan for redistribute v3.

        Returns a dict of (B,·) arrays — exposed separately from the
        placement so the property suite can check the plan's invariants
        directly:

        * ``pdf``/``cdf`` (B, S): each ray's weighted piecewise-constant
          placement density over the S probe strata (dead rays fall back
          to uniform); cdf is monotone non-decreasing with cdf[:, -1] ≈ 1.
        * ``s_ray`` (B,) int32: per-ray sample counts S'_i.  Allocation:
          every ray gets the floor of 1; the extra E = budget − B samples
          are split by stratifying the rays' normalized live-mass CDF at E
          points (`diff(floor(ray_cdf * E + 0.5))` — the edges telescope,
          so `sum(s_ray) <= budget` holds *by construction*, not by test).
          Per-ray counts are clamped to the static lane cap ``s_cap``.
        * ``s_cap`` int (static): lane-grid width, min(oversub × even
          split, budget − B + 1).
        * ``mass`` (B,): the per-ray weighted live masses the allocation is
          proportional to; ``dead`` (B,) bool marks zero-mass rays.
        """
        b, s = ts.shape
        budget = int(budget)
        e = budget - b                       # extra lanes beyond the 1-floor
        s_cap = max(1, min(max(1, budget // b) * self.v3_oversub, e + 1))

        w = self.v3_stratum_weights(live, ema_vals)        # (B, S)
        mass = jnp.sum(w, axis=-1)                         # (B,)
        dead = mass <= 0.0
        w_ray = jnp.where(dead[:, None], jnp.ones_like(w), w)
        pdf = w_ray / jnp.sum(w_ray, axis=-1, keepdims=True)
        cdf = jnp.cumsum(pdf, axis=-1)

        # global workload balance: stratify the batch's live-mass CDF at E
        # points.  Normalizing by the last entry makes ray_cdf[-1] exactly
        # 1.0, so edges[-1] == E and the telescoped sum never exceeds the
        # budget even under f32 cumsum rounding.
        ray_mass = jnp.where(dead, 0.0, mass)
        total = jnp.sum(ray_mass)
        ray_pdf = jnp.where(total > 0.0, ray_mass / jnp.maximum(total, 1e-12),
                            1.0 / b)
        ray_cdf = jnp.cumsum(ray_pdf)
        ray_cdf = ray_cdf / ray_cdf[-1]
        edges = jnp.floor(ray_cdf * e + 0.5).astype(jnp.int32)
        extra = jnp.diff(jnp.concatenate([jnp.zeros((1,), jnp.int32), edges]))
        s_ray = 1 + jnp.clip(extra, 0, s_cap - 1)
        return {"pdf": pdf, "cdf": cdf, "s_ray": s_ray, "s_cap": s_cap,
                "mass": mass, "dead": dead}

    def redistribute_v3(self, ts, live, ema_vals, budget: int):
        """Density-weighted inverse-CDF placement at ragged per-ray S'.

        Same probe/jitter discipline as v2 (`redistribute`): liveness and
        in-stratum jitter both come from the uniform candidates `ts`, so the
        stage stays a pure deterministic function of (ts, live, ema) with no
        rng plumbing.  Returns fixed-shape lanes:

        * ts_new (B, s_cap): ascending per ray; lane k of ray i is a placed
          sample iff ``valid[i, k]`` (k < S'_i), else parked at `far`;
        * deltas (B, s_cap): per-sample quadrature widths, 0 on invalid
          lanes.  Raw widths h / (p_j · S'_i) are renormalized per ray so
          the valid lanes sum *exactly* to the ray's live arc length (for
          uniform weights the factor is 1 and v2's quadrature is
          recovered); dead rays normalize to the full near–far span, v2's
          uniform-fallback convention.
        * valid (B, s_cap) bool: the ragged-ray mask the compact stage
          packs (invalid lanes are culled, so they cost no shade work and
          composite as exactly zero).
        """
        b, s = ts.shape
        near, far = self.cfg.near, self.cfg.far
        h = (far - near) / s
        plan = self.v3_plan(ts, live, ema_vals, budget)
        pdf, cdf, s_ray, s_cap = (
            plan["pdf"], plan["cdf"], plan["s_ray"], plan["s_cap"])

        k = jnp.arange(s_cap)
        valid = k[None, :] < s_ray[:, None]                # (B, s_cap)

        # stratified u in (0,1) at the ray's own S': jitter recycled from
        # the candidate samples (column k mod S keeps every lane jittered)
        tsrc = ts[:, k % s]
        jitter = (tsrc - near) / (far - near) * s
        jitter = jnp.clip(jitter - jnp.floor(jitter), 0.0, 1.0 - 1e-6)
        sr = s_ray.astype(jnp.float32)[:, None]
        u = jnp.clip((k[None, :] + jitter) / sr, 0.0, 1.0 - 1e-9)
        u = u * cdf[:, -1:]                                # absorb rounding

        j = jax.vmap(lambda c, uu: jnp.searchsorted(c, uu, side="right"))(cdf, u)
        j = jnp.clip(j, 0, s - 1)
        cdf_lo = jnp.where(
            j > 0, jnp.take_along_axis(cdf, jnp.maximum(j - 1, 0), axis=-1), 0.0
        )
        p = jnp.maximum(jnp.take_along_axis(pdf, j, axis=-1), 1e-12)
        frac = jnp.clip((u - cdf_lo) / p, 0.0, 1.0 - 1e-6)
        ts_new = near + (j.astype(jnp.float32) + frac) * h
        ts_new = jnp.where(valid, ts_new, far)             # park invalid lanes

        # ragged quadrature: dt = h / (p_j · S'_i) on valid lanes, then a
        # per-ray renormalization pins the row sum to the live arc length
        dt_raw = jnp.where(valid, h / (p * sr), 0.0)
        live_len = jnp.sum(live.astype(jnp.float32), axis=-1) * h
        target = jnp.where(plan["dead"], far - near, live_len)
        deltas = dt_raw * (target / jnp.maximum(jnp.sum(dt_raw, -1), 1e-12))[:, None]
        return ts_new, deltas, valid

    # ---- stage 3: compact ----

    def compact(self, live, budget: int, unit=None) -> CompactionPlan:
        """Live-first compaction to a fixed budget, padded with dead samples.

        With `unit` coords given, the live set is ordered by Morton (Z-order)
        key instead of flat sample order: spatially adjacent points land in
        the same kernel block, which is what makes the fused path's corner
        reads coalescible (FMU) and its backward update stream quasi-sorted
        (BUM).  Costs nothing — the single stable argsort just sorts a
        different key (dead lanes get the max key, so they still pad the
        tail).  Without `unit`, falls back to the PR 1 flat-order behavior.

        Overflow caveat: when n_live > budget the dropped live points are
        the highest Morton keys (the box corner nearest (1,1,1)) instead of
        flat order's end-of-batch rays — either truncation is systematic,
        and the trainer reacts the same way (widens the next budget bucket).
        """
        if unit is None:
            order = jnp.argsort(jnp.logical_not(live))  # stable: live-first
        else:
            key = fp_ref.morton_key(unit)
            key = jnp.where(live, key, jnp.uint32(0xFFFFFFFF))
            order = jnp.argsort(key)  # stable: live in Z-order, dead last
        idx = order[:budget]
        n_live = jnp.sum(live.astype(jnp.int32))
        keep = live[idx]
        overflow = jnp.maximum(n_live - budget, 0)
        return CompactionPlan(idx, keep, n_live, overflow)

    # ---- stage 4: shade ----

    def shade(self, params, unit, dirs, fused: bool = False):
        """Field query on (already compacted) unit coords -> (sigma, rgb).

        fused=True routes through `field.query_fused` (one encode pass over
        all grids, pre-sorted BUM backward) — bit-identical to the per-grid
        query on the ref backend, so the flag is a placement choice, not a
        numerics choice.  With the pipeline's `fused_step` knob also on, the
        stage collapses further into `field.query_step`: encode AND both MLP
        heads in one custom-VJP op (still bit-identical on ref).  The stage
        is agnostic to how `unit` was sampled; it sees only the compacted
        point set."""
        if fused:
            if self.fused_step:
                return self.field.query_step(params, unit, dirs)
            return self.field.query_fused(params, unit, dirs)
        return self.field.query(params, unit, dirs)

    # ---- stage 5: scatter + composite ----

    def composite(self, sigma, rgb, ts, deltas=None):
        """Volume-render (B·S,) sigma / (B·S,3) rgb along ts (B,S).

        deltas: optional per-sample quadrature widths (B,S) — required after
        `redistribute`, where consecutive ts may straddle dead gaps that the
        naive `diff(ts)` spacing would wrongly charge to the preceding
        sample's density.  With deltas=None the uniform-sampler convention
        applies unchanged (diff, last stratum padded with the mean width) —
        bit-identical to the pre-redistribute pipeline.
        """
        b, s = ts.shape
        if deltas is None:
            deltas = vr_ref.uniform_deltas(ts, self.cfg.far - self.cfg.near)
        out = vr_ops.composite(sigma.reshape(b, s), rgb.reshape(b, s, 3), deltas, ts)
        color = out.color
        if self.cfg.white_background:
            color = color + (1.0 - out.opacity[..., None])
        return {
            "rgb": color,
            "depth": out.depth,
            "opacity": out.opacity,
            "weights": out.weights,
        }

    # ---- full pipeline ----

    def __call__(
        self,
        params,
        origins,
        dirs,
        ts,
        *,
        bitfield=None,
        mask_fn=None,
        budget: int | None = None,
        occ_ema=None,
    ):
        """Render a ray batch.  budget MUST be a static python int (or None
        for the dense path) — it fixes the compiled point-batch shape.

        With `redistribute` on (and a bitfield + budget present), stage 2b
        replaces ts by S' = budget // B adaptively placed samples per ray
        before compaction, and the effective budget becomes B·S' ≤ budget —
        the reported `points_queried` can only shrink.  `live_fraction` then
        reports the probe's (uniform-equivalent) live fraction so budget
        controllers keep seeing the quantity they calibrate against.

        With `redistribute_v3` on, stage 2b instead places a *variable*
        S'_i per ray (density-weighted when `occ_ema` — the (R^3,) f32
        occupancy EMA — is given), emitting a ragged (B, S_cap) lane grid
        whose valid lanes the compact stage packs into exactly `budget`
        points, zero overflow by construction.  `occ_ema` is only read by
        the v3 branch; passing it elsewhere changes nothing.
        """
        b, s = ts.shape
        n = b * s
        # stage spans are host-side: under jit they time the *trace* of each
        # stage (the compile-side cost breakdown); in eager use they time
        # execution.  Either way they never touch array values.
        with _trace.span("pipeline/sample", cat="pipeline"):
            flat_pts, flat_dirs, unit = self.generate_samples(origins, dirs, ts)
        with _trace.span("pipeline/cull", cat="pipeline"):
            live = self.cull(flat_pts, unit, bitfield=bitfield, mask_fn=mask_fn)

        deltas = probe_live_frac = None
        # redistribution allocates per ray, so it needs budget >= B for at
        # least one sample each; below that, fall through to plain uniform
        # compaction, which honors sub-B budgets by truncation instead of
        # silently exceeding the ceiling
        if (self.redistribute_on and bitfield is not None
                and budget is not None and int(budget) >= b):
            with _trace.span("pipeline/redistribute", cat="pipeline"):
                # the uniform candidates' liveness doubles as the (jittered)
                # occupancy probe; their mean is exactly the uniform sampler's
                # live fraction — what the budget controller calibrates against
                probe_live_frac = jnp.mean(live.astype(jnp.float32))
                if self.redistribute_v3_on:
                    ema_vals = None
                    if occ_ema is not None:
                        r = _cube_root(occ_ema.shape[0])
                        ema_vals = occ_lib.point_density(
                            occ_ema, unit, r).reshape(b, s)
                    ts, deltas, lane_valid = self.redistribute_v3(
                        ts, live.reshape(b, s), ema_vals, int(budget))
                    n = b * ts.shape[1]
                    flat_pts, flat_dirs, unit = self.generate_samples(
                        origins, dirs, ts)
                    # invalid lanes are dead by decree: they never reach the
                    # shade stage, and sum(S') <= budget makes the compacted
                    # packing overflow-free
                    live = lane_valid.reshape(-1) & self.cull(
                        flat_pts, unit, bitfield=bitfield, mask_fn=mask_fn)
                else:
                    s = min(s, min(int(budget), n) // b)
                    ts, deltas = self.redistribute(ts, live.reshape(b, -1), n_out=s)
                    budget = n = b * s
                    flat_pts, flat_dirs, unit = self.generate_samples(origins, dirs, ts)
                    live = self.cull(flat_pts, unit, bitfield=bitfield, mask_fn=mask_fn)

        if budget is None:
            with _trace.span("pipeline/shade", cat="pipeline",
                             args={"points": n, "dense": True}):
                sigma, rgb = self.shade(params, unit, flat_dirs)
            sigma = jnp.where(live, sigma, 0.0)
            n_live = jnp.sum(live.astype(jnp.int32))
            overflow = jnp.zeros((), jnp.int32)
            points_queried = n
        else:
            budget = min(int(budget), n)
            with _trace.span("pipeline/compact", cat="pipeline",
                             args={"budget": budget}):
                plan = self.compact(live, budget, unit)
            with _trace.span("pipeline/shade", cat="pipeline",
                             args={"points": budget, "dense": False}):
                sigma_c, rgb_c = self.shade(
                    params, unit[plan.idx], flat_dirs[plan.idx],
                    fused=self.fused_path,
                )
            sigma = jnp.zeros((n,), sigma_c.dtype).at[plan.idx].set(
                jnp.where(plan.keep, sigma_c, 0.0)
            )
            rgb = jnp.zeros((n, 3), rgb_c.dtype).at[plan.idx].set(
                rgb_c * plan.keep[:, None].astype(rgb_c.dtype)
            )
            n_live, overflow = plan.n_live, plan.overflow
            points_queried = budget

        with _trace.span("pipeline/composite", cat="pipeline"):
            out = self.composite(sigma, rgb, ts, deltas)
        out.update(
            live_fraction=(
                probe_live_frac if probe_live_frac is not None
                else jnp.mean(live.astype(jnp.float32))
            ),
            n_live=n_live,
            overflow=overflow,
            points_queried=jnp.asarray(points_queried, jnp.int32),
        )
        return out
