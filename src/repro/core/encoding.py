"""Hash-grid encoding modules + spherical-harmonics direction encoding.

`HashEncoding` wraps the kernel stack (repro.kernels.hash_encode) with
parameter management.  `Instant-3D` uses two instances — a density grid and a
smaller color grid (paper §3.2) — built by `core.field`.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.hash_encode import ops as he_ops
from ..kernels.hash_encode import ref as he_ref


@dataclass(frozen=True)
class HashGridConfig:
    n_levels: int = 16
    n_features: int = 2
    log2_table_size: int = 19       # T = 2^19 (Instant-NGP default)
    base_resolution: int = 16
    max_resolution: int = 2048
    merged_backward: bool = True    # BUM merge in the VJP (paper §4.5 analogue)

    @property
    def table_size(self) -> int:
        return 1 << self.log2_table_size

    @property
    def out_dim(self) -> int:
        return self.n_levels * self.n_features


class HashEncoding:
    """Multiresolution hash-grid encoding with learned tables."""

    def __init__(self, cfg: HashGridConfig):
        self.cfg = cfg
        self.resolutions = he_ref.level_resolutions(
            cfg.n_levels, cfg.base_resolution, cfg.max_resolution
        )
        self.dense_flags = he_ref.level_is_dense(self.resolutions, cfg.table_size)
        # kernel routing resolves through the repro.kernels registry default
        self._encode = he_ops.make_hash_encode(
            self.resolutions,
            cfg.table_size,
            cfg.n_features,
            merged_backward=cfg.merged_backward,
        )

    def init(self, rng: jax.Array, dtype=jnp.float32) -> jnp.ndarray:
        """Tables ~ U(-1e-4, 1e-4) as in Instant-NGP."""
        cfg = self.cfg
        return jax.random.uniform(
            rng, (cfg.n_levels, cfg.table_size, cfg.n_features),
            minval=-1e-4, maxval=1e-4, dtype=jnp.float32,
        ).astype(dtype)

    def __call__(self, points: jnp.ndarray, tables: jnp.ndarray) -> jnp.ndarray:
        """points (N,3) in [0,1) -> (N, L*F) float32."""
        return self._encode(points, tables)

    @property
    def param_bytes(self) -> int:
        c = self.cfg
        return c.n_levels * c.table_size * c.n_features * 4


# --- spherical harmonics (degree 4 = 16 coeffs, Instant-NGP's dir encoding) ---

def sh_encoding(dirs: jnp.ndarray, degree: int = 4) -> jnp.ndarray:
    """Real SH basis evaluated at unit directions (N, 3) -> (N, degree^2)."""
    x, y, z = dirs[..., 0], dirs[..., 1], dirs[..., 2]
    xx, yy, zz = x * x, y * y, z * z
    xy, yz, xz = x * y, y * z, x * z
    out = [jnp.full_like(x, 0.28209479177387814)]
    if degree > 1:
        out += [
            -0.48860251190291987 * y,
            0.48860251190291987 * z,
            -0.48860251190291987 * x,
        ]
    if degree > 2:
        out += [
            1.0925484305920792 * xy,
            -1.0925484305920792 * yz,
            0.94617469575755997 * zz - 0.31539156525251999,
            -1.0925484305920792 * xz,
            0.54627421529603959 * (xx - yy),
        ]
    if degree > 3:
        out += [
            0.59004358992664352 * y * (-3.0 * xx + yy),
            2.8906114426405538 * xy * z,
            0.45704579946446572 * y * (1.0 - 5.0 * zz),
            0.3731763325901154 * z * (5.0 * zz - 3.0),
            0.45704579946446572 * x * (1.0 - 5.0 * zz),
            1.4453057213202769 * z * (xx - yy),
            0.59004358992664352 * x * (-xx + 3.0 * yy),
        ]
    return jnp.stack(out, axis=-1)


def sh_dim(degree: int) -> int:
    return degree * degree
