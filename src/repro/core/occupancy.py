"""Occupancy grid: empty-space skipping for ray marching (Instant-NGP §3).

A coarse binary grid over the unit cube.  Periodically, cell densities are
re-queried (cell centers + jitter), folded into an EMA, and thresholded.
During rendering, samples in unoccupied cells are culled before the field
query — on the paper's accelerator this is what keeps the interpolation
count near 200k/iteration instead of |rays| x |samples|.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OccupancyConfig:
    resolution: int = 32
    # Unlike NGP (which refreshes a random subset of cells), `update`
    # re-queries EVERY cell center each time, so the EMA is pure hysteresis
    # against jitter flicker — a fast decay tracks the field's collapse of
    # empty-space density within a few updates instead of ~90.
    ema_decay: float = 0.6
    # Cull only near-empty cells: at delta ~ (far-near)/S the per-sample
    # alpha of sigma=0.05 is ~2/255, below visibility.  Converged empty
    # space plateaus at sigma~0.02 on the synthetic scenes while surface
    # cells sit orders of magnitude higher; a high threshold (the old 0.5)
    # culls moderate-density cells before the field settles and costs PSNR.
    density_threshold: float = 0.05
    update_interval: int = 16
    warmup_steps: int = 64          # all-occupied until the field knows something


class OccupancyState(NamedTuple):
    density_ema: jnp.ndarray  # (R^3,) f32
    step: jnp.ndarray         # int32


def init_state(cfg: OccupancyConfig) -> OccupancyState:
    """EMA starts at zero (NGP convention): the bitfield means nothing until
    the first `update` folds in real densities, so `bitfield` reports
    all-occupied while `state.step == 0`.  The old 1e4 "optimistic" init
    made warmup implicit but took ~190 updates of 0.95-decay to clear truly
    empty cells — skipping never engaged."""
    r3 = cfg.resolution ** 3
    return OccupancyState(jnp.zeros((r3,), jnp.float32), jnp.zeros((), jnp.int32))


def cell_centers(cfg: OccupancyConfig) -> jnp.ndarray:
    r = cfg.resolution
    axis = (jnp.arange(r, dtype=jnp.float32) + 0.5) / r
    gx, gy, gz = jnp.meshgrid(axis, axis, axis, indexing="ij")
    return jnp.stack([gx, gy, gz], axis=-1).reshape(-1, 3)  # (R^3, 3)


def update(field, params: dict, state: OccupancyState, cfg: OccupancyConfig, rng: jax.Array) -> OccupancyState:
    """Requery cell densities at jittered centers, EMA-fold.

    Contract: every cell is re-queried each call (unlike NGP's random
    subset), so the EMA (`max(ema * decay, sigma)`) is pure hysteresis
    against jitter flicker; `field` only needs a `.density(params, pts)`
    method.  Cost is one R^3-point density query — callers amortize it over
    `update_interval` training steps.  Returns a new state with step + 1;
    step > 0 is what arms `bitfield` (and thereby compaction + the
    redistribute stage) after the all-occupied warmup."""
    pts = cell_centers(cfg)
    jitter = (jax.random.uniform(rng, pts.shape) - 0.5) / cfg.resolution
    sigma, _ = field.density(params, jnp.clip(pts + jitter, 0.0, 1.0 - 1e-6))
    ema = jnp.maximum(state.density_ema * cfg.ema_decay, sigma)
    return OccupancyState(ema, state.step + 1)


def bitfield(state: OccupancyState, cfg: OccupancyConfig) -> jnp.ndarray:
    """Thresholded occupancy bits (R^3,) bool — the pipeline's cull-stage input.

    Passed to RenderPipeline as a plain array (jit-traceable), replacing the
    old closure-captured mask.  While step == 0 (no update folded yet) the
    zero-init EMA carries no information, so the field reads all-occupied —
    preserving the "all-occupied until the field knows something" warmup
    semantics for every caller.
    """
    return (state.density_ema > cfg.density_threshold) | (state.step == 0)


def point_liveness(bits: jnp.ndarray, points_unit: jnp.ndarray, resolution: int) -> jnp.ndarray:
    """Pure cull stage: per-point occupancy lookup.

    Contract: ``bits`` is the (R^3,) bool bitfield from :func:`bitfield`
    (x-major flattening — ``flat = x*R*R + y*R + z``), ``points_unit`` is
    (..., 3) in [0,1) (any leading batch shape); returns bool with the
    leading shape.  Points exactly on the upper face clip into the last
    cell, matching :func:`repro.core.rendering.normalize_points`' half-open
    convention.  No gradients flow through the lookup (it is a gather of a
    bool array) — callers use it as a mask, never as a differentiable term.
    """
    r = resolution
    cell = jnp.clip((points_unit * r).astype(jnp.int32), 0, r - 1)
    flat = cell[..., 0] * r * r + cell[..., 1] * r + cell[..., 2]
    return bits[flat]


def ray_segment_mask(bits: jnp.ndarray, unit_midpoints: jnp.ndarray, resolution: int) -> jnp.ndarray:
    """Per-ray live-segment extraction for the redistribute stage (binary form).

    ``unit_midpoints`` (B, M, 3): unit-cube coords of the midpoints of M
    equal-width probe bins along each ray (out-of-box probes should be
    masked by the caller's AABB test — this function only answers the
    occupancy question).  Returns the (B, M) bool live-bin mask: runs of
    True are the ray's live segments, and the mask's row-sums are the
    per-ray live lengths in units of the bin width.  This binary mask is
    the piecewise-constant sampling density that
    ``RenderPipeline.redistribute`` (v2) inverts — every live bin weighs
    the same, regardless of how much density its cell holds.  The v3
    stage instead inverts the EMA-*weighted* mass from
    :func:`ray_segment_mass`, of which this mask is exactly the
    ``mass > 0`` degeneration (same cells, binary weights).  In the
    training hot path the pipeline derives the mask from the cull stage's
    jittered candidate samples (probe == candidates, so coverage is
    unbiased across steps); this standalone form serves offline analysis
    and custom probe placements.  The contract is deliberately a fixed
    (B, M) *mask*, not a start/end run-length list, so consumers stay
    jit-stable at any occupancy.
    """
    return point_liveness(bits, unit_midpoints, resolution)


def point_density(ema: jnp.ndarray, points_unit: jnp.ndarray, resolution: int) -> jnp.ndarray:
    """Per-point occupancy-EMA gather — the float twin of `point_liveness`.

    ``ema`` is the (R^3,) f32 ``density_ema`` from :class:`OccupancyState`
    (same x-major flattening as the bitfield); returns the cell's EMA value
    at each point, leading shape preserved.  The redistribute-v3 stage uses
    this to weight live strata by how much density their cells actually
    hold, instead of the binary live/dead vote."""
    r = resolution
    cell = jnp.clip((points_unit * r).astype(jnp.int32), 0, r - 1)
    flat = cell[..., 0] * r * r + cell[..., 1] * r + cell[..., 2]
    return ema[flat]


def ray_segment_mass(
    ema: jnp.ndarray,
    unit_midpoints: jnp.ndarray,
    resolution: int,
    threshold: float,
) -> jnp.ndarray:
    """EMA-weighted live mass per probe bin — the float form of
    `ray_segment_mask`.

    Same probe contract as the mask ((B, M, 3) midpoints, fixed-shape
    output), but each live bin carries its cell's density EMA instead of a
    binary 1: bins whose cell EMA exceeds ``threshold`` return the EMA
    value, others return 0.  Row-sums are the per-ray EMA-weighted live
    masses that redistribute v3's global ray allocation (per-ray S') is
    proportional to.  Degeneration contract (regression-tested):
    ``ray_segment_mass(...) > 0`` equals ``ray_segment_mask(bits, ...)``
    whenever ``bits = ema > threshold`` — thresholding the weighted mass
    recovers exactly the binary liveness the v2 stage consumes.
    """
    d = point_density(ema, unit_midpoints, resolution)
    return jnp.where(d > threshold, d, 0.0)


def occupied_mask_fn(state: OccupancyState, cfg: OccupancyConfig):
    """Back-compat closure form of the cull stage for render_rays."""
    bits = bitfield(state, cfg)
    return lambda points_unit: point_liveness(bits, points_unit, cfg.resolution)


def occupancy_fraction(state: OccupancyState, cfg: OccupancyConfig) -> jnp.ndarray:
    """Fraction of cells above threshold — the *cell-level* sparsity.  Note
    this is not the same number as the pipeline's per-sample live fraction
    (rays oversample near the camera and the AABB test composes in), which
    is why the trainer budgets from the measured batch fraction instead."""
    return jnp.mean((state.density_ema > cfg.density_threshold).astype(jnp.float32))
