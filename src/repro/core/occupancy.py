"""Occupancy grid: empty-space skipping for ray marching (Instant-NGP §3).

A coarse binary grid over the unit cube.  Periodically, cell densities are
re-queried (cell centers + jitter), folded into an EMA, and thresholded.
During rendering, samples in unoccupied cells are culled before the field
query — on the paper's accelerator this is what keeps the interpolation
count near 200k/iteration instead of |rays| x |samples|.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OccupancyConfig:
    resolution: int = 32
    ema_decay: float = 0.95
    density_threshold: float = 0.5
    update_interval: int = 16
    warmup_steps: int = 64          # all-occupied until the field knows something


class OccupancyState(NamedTuple):
    density_ema: jnp.ndarray  # (R^3,) f32
    step: jnp.ndarray         # int32


def init_state(cfg: OccupancyConfig) -> OccupancyState:
    r3 = cfg.resolution ** 3
    return OccupancyState(jnp.full((r3,), 1e4, jnp.float32), jnp.zeros((), jnp.int32))


def cell_centers(cfg: OccupancyConfig) -> jnp.ndarray:
    r = cfg.resolution
    axis = (jnp.arange(r, dtype=jnp.float32) + 0.5) / r
    gx, gy, gz = jnp.meshgrid(axis, axis, axis, indexing="ij")
    return jnp.stack([gx, gy, gz], axis=-1).reshape(-1, 3)  # (R^3, 3)


def update(field, params: dict, state: OccupancyState, cfg: OccupancyConfig, rng: jax.Array) -> OccupancyState:
    """Requery cell densities at jittered centers, EMA-fold."""
    pts = cell_centers(cfg)
    jitter = (jax.random.uniform(rng, pts.shape) - 0.5) / cfg.resolution
    sigma, _ = field.density(params, jnp.clip(pts + jitter, 0.0, 1.0 - 1e-6))
    ema = jnp.maximum(state.density_ema * cfg.ema_decay, sigma)
    return OccupancyState(ema, state.step + 1)


def occupied_mask_fn(state: OccupancyState, cfg: OccupancyConfig):
    """Returns points_unit (N,3) -> bool (N,) culling closure for render_rays."""
    r = cfg.resolution
    bitfield = state.density_ema > cfg.density_threshold  # (R^3,)

    def mask(points_unit: jnp.ndarray) -> jnp.ndarray:
        cell = jnp.clip((points_unit * r).astype(jnp.int32), 0, r - 1)
        flat = cell[:, 0] * r * r + cell[:, 1] * r + cell[:, 2]
        return bitfield[flat]

    return mask


def occupancy_fraction(state: OccupancyState, cfg: OccupancyConfig) -> jnp.ndarray:
    return jnp.mean((state.density_ema > cfg.density_threshold).astype(jnp.float32))
