"""Radiance fields: Instant-NGP baseline and the Instant-3D decomposition.

Instant-NGP (paper §2.1, Fig. 3): one hash grid -> density MLP -> (sigma,
geo features); color MLP eats (geo features, SH(dir)).

Instant-3D (paper §3, Fig. 6): the grid is decomposed into a *density grid*
and a smaller *color grid* (S_D > S_C).  The density branch is
density-grid -> density MLP -> sigma; the color branch is
color-grid ⊕ SH(dir) -> color MLP -> rgb.  The clean split is what allows
the two branches to use different table sizes and update frequencies.

Both fields are pure-functional: `init` builds a param pytree, `query` maps
(params, points, dirs) -> (sigma, rgb).
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field

import jax
import jax.numpy as jnp

from . import encoding as enc
from ..kernels.fused_mlp import ops as mlp_ops
from ..kernels.fused_path import ops as fp_ops
from ..kernels.fused_step import ops as fs_ops


# --- truncated exp: density activation with clipped-gradient stability ---

@jax.custom_vjp
def trunc_exp(x):
    return jnp.exp(jnp.clip(x, -15.0, 11.0))

def _te_fwd(x):
    return trunc_exp(x), x

def _te_bwd(x, g):
    return (g * jnp.exp(jnp.clip(x, -15.0, 11.0)),)

trunc_exp.defvjp(_te_fwd, _te_bwd)


@dataclass(frozen=True)
class FieldConfig:
    # grid geometry (shared by both branches; table sizes differ)
    n_levels: int = 16
    n_features: int = 2
    base_resolution: int = 16
    max_resolution: int = 1024
    # Instant-3D: S_D : S_C = 1 : 0.25  ->  color table 4x smaller (§5.1)
    log2_table_density: int = 18
    log2_table_color: int = 16
    decomposed: bool = True         # False => Instant-NGP baseline
    # MLPs (Instant-NGP sizes: <=3 layers, 64 hidden)
    hidden: int = 64
    geo_features: int = 15          # density MLP extra outputs (NGP baseline)
    sh_degree: int = 4
    # kernels (routing resolves through the repro.kernels backend registry)
    merged_backward: bool = True
    grid_dtype: str = "float32"
    # what the fused ops keep live between forward and backward: "recompute"
    # re-derives geometry/streams/features in the backward from the inputs
    # (bit-identical gradients, no (L,N,8) residuals — the right default at
    # production L=16/100k-point scale); "stash" is the PR 3 residual set
    # (backward does zero geometry work, costs residual memory).
    residual_policy: str = "recompute"

    def grid_cfg(self, branch: str) -> enc.HashGridConfig:
        log2_t = self.log2_table_density if branch == "density" else self.log2_table_color
        return enc.HashGridConfig(
            n_levels=self.n_levels,
            n_features=self.n_features,
            log2_table_size=log2_t,
            base_resolution=self.base_resolution,
            max_resolution=self.max_resolution,
            merged_backward=self.merged_backward,
        )


def _init_linear(rng, d_in, d_out):
    """He-uniform, as in tiny-cuda-nn's fully-fused MLP init."""
    bound = (6.0 / d_in) ** 0.5
    w = jax.random.uniform(rng, (d_in, d_out), minval=-bound, maxval=bound, dtype=jnp.float32)
    return w, jnp.zeros((d_out,), jnp.float32)


class Field:
    """Shared machinery; `decomposed` flag switches NGP <-> Instant-3D."""

    def __init__(self, cfg: FieldConfig):
        self.cfg = cfg
        self.density_enc = enc.HashEncoding(cfg.grid_cfg("density"))
        self.color_enc = enc.HashEncoding(cfg.grid_cfg("color")) if cfg.decomposed else None
        self.sh_dim = enc.sh_dim(cfg.sh_degree)
        # fused compacted-path encoder: all grids in one pass (shared corner
        # geometry, pre-sorted BUM backward).  Built here so kernel-backend
        # routing binds at the same time as the per-grid encoders'.
        sizes = [cfg.grid_cfg("density").table_size]
        if cfg.decomposed:
            sizes.append(cfg.grid_cfg("color").table_size)
        self._fused_encode = fp_ops.make_fused_encode(
            self.density_enc.resolutions,
            tuple(sizes),
            cfg.n_features,
            merged_backward=cfg.merged_backward,
            residual_policy=cfg.residual_policy,
        )
        # one-kernel training step (encode -> MLP heads in a single op);
        # decomposed fields only — the NGP baseline keeps the PR 3 route
        self._fused_step = fs_ops.make_fused_step(
            self.density_enc.resolutions,
            tuple(sizes),
            cfg.n_features,
            merged_backward=cfg.merged_backward,
            residual_policy=cfg.residual_policy,
        ) if cfg.decomposed else None

    # ---- params ----

    def init(self, rng: jax.Array) -> dict:
        cfg = self.cfg
        keys = jax.random.split(rng, 8)
        dtype = jnp.dtype(cfg.grid_dtype)
        enc_dim = self.density_enc.cfg.out_dim

        params = {"density_grid": self.density_enc.init(keys[0], dtype)}
        # density MLP: enc -> hidden -> 1 + geo
        w1, b1 = _init_linear(keys[1], enc_dim, cfg.hidden)
        w2, b2 = _init_linear(keys[2], cfg.hidden, 1 + cfg.geo_features)
        params["density_mlp"] = {"w1": w1, "b1": b1, "w2": w2, "b2": b2}

        if cfg.decomposed:
            params["color_grid"] = self.color_enc.init(keys[3], dtype)
            color_in = self.color_enc.cfg.out_dim + self.sh_dim
        else:
            color_in = cfg.geo_features + self.sh_dim
        # color MLP: color_in -> hidden -> hidden -> 3
        w1, b1 = _init_linear(keys[4], color_in, cfg.hidden)
        w2, b2 = _init_linear(keys[5], cfg.hidden, cfg.hidden)
        w3, b3 = _init_linear(keys[6], cfg.hidden, 3)
        params["color_mlp"] = {"w1": w1, "b1": b1, "w2": w2, "b2": b2, "w3": w3, "b3": b3}
        return params

    # ---- queries ----

    def density(self, params: dict, points: jnp.ndarray):
        """points (N,3) in [0,1) -> (sigma (N,), geo (N, geo_features))."""
        h = self.density_enc(points, params["density_grid"])
        m = params["density_mlp"]
        out = mlp_ops.mlp2(h, m["w1"], m["b1"], m["w2"], m["b2"])
        return trunc_exp(out[..., 0]), out[..., 1:]

    def _mlp_heads(self, params: dict, hd: jnp.ndarray, hc, dirs: jnp.ndarray):
        """Encodings -> (sigma, rgb).  hd: density features (N, L*F); hc:
        color-grid features, or None for the NGP baseline (color MLP then
        eats the density MLP's geo features)."""
        m = params["density_mlp"]
        out = mlp_ops.mlp2(hd, m["w1"], m["b1"], m["w2"], m["b2"],
                           residual_policy=self.cfg.residual_policy)
        sigma, geo = trunc_exp(out[..., 0]), out[..., 1:]
        sh = enc.sh_encoding(dirs, self.cfg.sh_degree)
        cin = jnp.concatenate([hc if hc is not None else geo, sh], axis=-1)
        m = params["color_mlp"]
        raw = mlp_ops.mlp3(
            cin, m["w1"], m["b1"], m["w2"], m["b2"], m["w3"], m["b3"],
            residual_policy=self.cfg.residual_policy,
        )
        return sigma, jax.nn.sigmoid(raw)

    def query(self, params: dict, points: jnp.ndarray, dirs: jnp.ndarray):
        """-> (sigma (N,), rgb (N,3)).  dirs must be unit-norm."""
        hd = self.density_enc(points, params["density_grid"])
        hc = self.color_enc(points, params["color_grid"]) if self.cfg.decomposed else None
        return self._mlp_heads(params, hd, hc, dirs)

    def query_fused(self, params: dict, points: jnp.ndarray, dirs: jnp.ndarray):
        """Fused compacted-path query: both grids encoded in one pass with
        shared corner geometry, FMU-style deduplicated reads on Pallas
        backends, and a custom VJP whose table-gradient streams commit
        through `merged_scatter_add(presorted=True)`.  Bit-identical to
        `query` on the ref backend (values AND gradients) — callers feed
        Morton-ordered points to realize the data-reuse win.

        The pipeline's compact stage Morton-orders whatever sample
        positions reach it — uniform or redistributed (stage 2b) alike —
        so adaptive placement composes with the fused path for free: the
        denser live-region samples cluster into *fewer* distinct cells,
        which raises block-level corner-read dedup rather than breaking
        it."""
        if self.cfg.decomposed:
            hd, hc = self._fused_encode(
                points, params["density_grid"], params["color_grid"]
            )
        else:
            (hd,) = self._fused_encode(points, params["density_grid"])
            hc = None
        return self._mlp_heads(params, hd, hc, dirs)

    def query_step(self, params: dict, points: jnp.ndarray, dirs: jnp.ndarray):
        """One-kernel query: encode(both grids) + both MLP heads in a single
        differentiable op (`fused_step.make_fused_step`), with the residual
        policy from the config deciding what crosses to the backward.
        Bit-identical to `query_fused` on the ref backend — same primitives,
        same order — and the custom VJP's table grads commit through the
        stacked windowed form of `merged_scatter_add`.  Falls back to
        `query_fused` for the NGP baseline (single grid: the color MLP eats
        the density head's geo features, which only the split path wires)."""
        if self._fused_step is None:
            return self.query_fused(params, points, dirs)
        sh = enc.sh_encoding(dirs, self.cfg.sh_degree)
        out, raw = self._fused_step(
            points, sh,
            params["density_grid"], params["color_grid"],
            params["density_mlp"], params["color_mlp"],
        )
        return trunc_exp(out[..., 0]), jax.nn.sigmoid(raw)

    # ---- bookkeeping ----

    def param_counts(self, params: dict) -> dict:
        return {k: sum(x.size for x in jax.tree_util.tree_leaves(v)) for k, v in params.items()}
