"""Instant-3D training loop (paper §3 + §5.1 settings).

The paper's two algorithm knobs are first-class here:

* different grid sizes: `FieldConfig.log2_table_density/color` (S_D : S_C);
* different update frequencies: `f_density`, `f_color` in [0, 1].  An
  iteration updates branch b iff floor(i*F_b) > floor((i-1)*F_b).  Frozen
  branches are routed through `stop_gradient` (their gradient scatter
  disappears from the backward HLO — the compute saving is real, not masked)
  and the optimizer skips their moments (`AdamW.apply(mask=...)`).

Two jitted step functions are compiled once (freeze_color True/False); the
scheduler picks per-iteration, mirroring the accelerator "skipping one
back-propagation every 1/(1-F) iterations" (paper §4.6).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field as dc_field
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import field as field_lib
from . import losses, occupancy, rendering
from ..optim import AdamW

# note: the sampler/dataset arguments below are duck-typed (repro.data types);
# importing repro.data here would create a package cycle


@dataclass(frozen=True)
class TrainerConfig:
    n_rays: int = 1024
    iters: int = 400
    lr: float = 1e-2
    eps: float = 1e-15              # Instant-NGP's Adam epsilon
    b2: float = 0.99
    mlp_weight_decay: float = 1e-6
    # update frequencies, F_D : F_C = 1 : 0.5 by default (paper §5.1)
    f_density: float = 1.0
    f_color: float = 0.5
    use_occupancy: bool = True
    occ: occupancy.OccupancyConfig = dc_field(default_factory=occupancy.OccupancyConfig)
    render: rendering.RenderConfig = dc_field(default_factory=rendering.RenderConfig)
    seed: int = 0
    eval_chunk: int = 4096


def _branch_update(i: int, freq: float) -> bool:
    """Whether branch with frequency `freq` updates at iteration i (0-based)."""
    if freq >= 1.0:
        return True
    return math.floor((i + 1) * freq) > math.floor(i * freq)


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    occ_state: occupancy.OccupancyState
    step: int


class Instant3DTrainer:
    def __init__(self, field: field_lib.Field, cfg: TrainerConfig):
        self.field = field
        self.cfg = cfg

        def lr_scale(path):
            # grids at full lr, MLPs at 0.1x — the NGP recipe
            return 1.0 if any("grid" in p for p in path) else 0.1

        self.opt = AdamW(
            lr=cfg.lr, b2=cfg.b2, eps=cfg.eps, weight_decay=0.0, lr_scale_fn=lr_scale
        )
        self._step_fns = {}

    # ---- state ----

    def init(self, rng: jax.Array) -> TrainState:
        params = self.field.init(rng)
        return TrainState(
            params=params,
            opt_state=self.opt.init(params),
            occ_state=occupancy.init_state(self.cfg.occ),
            step=0,
        )

    # ---- jitted step ----

    def _make_step(self, freeze_color: bool, freeze_density: bool = False):
        field, cfg, opt = self.field, self.cfg, self.opt
        decomposed = field.cfg.decomposed

        def loss_fn(params, batch: rendering.RayBatch, ts, occ_ema):
            if freeze_color and decomposed:
                params = dict(params)
                params["color_grid"] = jax.lax.stop_gradient(params["color_grid"])
            if freeze_density:
                params = dict(params)
                params["density_grid"] = jax.lax.stop_gradient(params["density_grid"])
            mask_fn = None
            if cfg.use_occupancy:
                state = occupancy.OccupancyState(occ_ema, jnp.zeros((), jnp.int32))
                mask_fn = occupancy.occupied_mask_fn(state, cfg.occ)
            out = rendering.render_rays(
                field, params, batch.origins, batch.dirs, ts, cfg.render, mask_fn
            )
            return losses.mse(out["rgb"], batch.rgb_gt), out["live_fraction"]

        def step(params, opt_state, batch, ts, occ_ema):
            (loss, live), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, ts, occ_ema
            )
            mask = jax.tree.map(lambda _: True, params)
            if freeze_color:
                mask["color_grid"] = False
            if freeze_density:
                mask["density_grid"] = False
            params, opt_state = opt.apply(params, grads, opt_state, mask=mask)
            return params, opt_state, loss, live

        return jax.jit(step, donate_argnums=(0, 1))

    def step_fn(self, freeze_color: bool, freeze_density: bool = False):
        key = (freeze_color, freeze_density)
        if key not in self._step_fns:
            self._step_fns[key] = self._make_step(freeze_color, freeze_density)
        return self._step_fns[key]

    # ---- driver ----

    def train(
        self,
        state: TrainState,
        sampler,
        iters: int | None = None,
        log_every: int = 50,
        callback=None,
    ) -> tuple[TrainState, dict]:
        cfg = self.cfg
        iters = iters if iters is not None else cfg.iters
        key = jax.random.PRNGKey(cfg.seed)
        history = {"step": [], "loss": [], "live_fraction": [], "wall_s": []}
        t0 = time.perf_counter()

        params, opt_state, occ_state = state.params, state.opt_state, state.occ_state
        for local_i in range(iters):
            i = state.step + local_i
            key_batch, key_ts, key_occ = jax.random.split(jax.random.fold_in(key, i), 3)
            batch = sampler.sample(key_batch, cfg.n_rays)
            ts = rendering.sample_ts(key_ts, cfg.n_rays, cfg.render)

            update_color = _branch_update(i, cfg.f_color)
            update_density = _branch_update(i, cfg.f_density)
            freeze_color = (not update_color) and self.field.cfg.decomposed
            freeze_density = not update_density

            step = self.step_fn(freeze_color, freeze_density)
            params, opt_state, loss, live = step(
                params, opt_state, batch, ts, occ_state.density_ema
            )

            if cfg.use_occupancy and i >= cfg.occ.warmup_steps and (i + 1) % cfg.occ.update_interval == 0:
                occ_state = occupancy.update(self.field, params, occ_state, cfg.occ, key_occ)

            if (local_i + 1) % log_every == 0 or local_i == iters - 1:
                history["step"].append(i + 1)
                history["loss"].append(float(loss))
                history["live_fraction"].append(float(live))
                history["wall_s"].append(time.perf_counter() - t0)
                if callback is not None:
                    callback(i + 1, params, history)

        return TrainState(params, opt_state, occ_state, state.step + iters), history

    # ---- evaluation ----

    def render_image(self, params, pose: np.ndarray, ds):
        cfg = self.cfg
        h, w = ds.h, ds.w
        py, px = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
        px, py = px.reshape(-1), py.reshape(-1)
        rgb_out, dep_out = [], []
        for i in range(0, px.shape[0], cfg.eval_chunk):
            o, d = rendering.pixel_rays(
                jnp.asarray(pose), px[i : i + cfg.eval_chunk], py[i : i + cfg.eval_chunk],
                h, w, ds.focal,
            )
            ts = rendering.sample_ts(None, o.shape[0], cfg.render)
            out = rendering.render_rays(self.field, params, o, d, ts, cfg.render)
            rgb_out.append(out["rgb"])
            dep_out.append(out["depth"])
        rgb = jnp.concatenate(rgb_out).reshape(h, w, 3)
        dep = jnp.concatenate(dep_out).reshape(h, w)
        return np.asarray(rgb), np.asarray(dep)

    def evaluate(self, params, ds, views=None) -> dict:
        """PSNR of rendered RGB and depth vs ground truth (paper Fig. 5 stats)."""
        views = views if views is not None else range(min(4, ds.images.shape[0]))
        rgb_ps, dep_ps = [], []
        for v in views:
            rgb, dep = self.render_image(params, ds.poses[v], ds)
            rgb_ps.append(float(losses.psnr(jnp.asarray(rgb), jnp.asarray(ds.images[v]))))
            # depth normalized to [0,1] over the far range for a bounded PSNR
            far = self.cfg.render.far
            dep_ps.append(float(losses.psnr(jnp.asarray(dep / far), jnp.asarray(ds.depths[v] / far))))
        return {"psnr_rgb": float(np.mean(rgb_ps)), "psnr_depth": float(np.mean(dep_ps))}
