"""Instant-3D training loop (paper §3 + §5.1 settings).

The paper's two algorithm knobs are first-class here:

* different grid sizes: `FieldConfig.log2_table_density/color` (S_D : S_C);
* different update frequencies: `f_density`, `f_color` in [0, 1].  An
  iteration updates branch b iff floor(i*F_b) > floor((i-1)*F_b).  Frozen
  branches are routed through `stop_gradient` (their gradient scatter
  disappears from the backward HLO — the compute saving is real, not masked)
  and the optimizer skips their moments (`AdamW.apply(mask=...)`).

Two jitted step functions are compiled once (freeze_color True/False); the
scheduler picks per-iteration, mirroring the accelerator "skipping one
back-propagation every 1/(1-F) iterations" (paper §4.6).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field as dc_field
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import field as field_lib
from . import losses, occupancy, rendering
from .pipeline import RenderPipeline, suggest_budget
from ..optim import AdamW

# note: the sampler/dataset arguments below are duck-typed (repro.data types);
# importing repro.data here would create a package cycle


# ---- shared eval-render compile cache ----
#
# Keyed per (field config, render config, chunk size): concurrent scene
# sessions with the *same* geometry share exactly one compiled function,
# while sessions with different grid sizes get distinct entries instead of
# silently thrashing (or worse, sharing) one trainer's cached jit.  The
# function closes over a Field built from the config, so any caller holding
# only configs (e.g. the serve3d RenderService) can use it too.
_EVAL_RENDER_CACHE: dict[tuple, Any] = {}


def make_render_chunk(field_cfg, render_cfg: rendering.RenderConfig):
    """Unjitted dense-pipeline chunk renderer built purely from configs:
    (params, origins (N,3), dirs (N,3), ts (N,S)) -> (rgb, depth).  The single
    construction point for every eval-render cache (plain and vmapped), so
    their entries always compute the same function."""
    pipeline = RenderPipeline(field_lib.Field(field_cfg), render_cfg)

    def render_chunk(params, origins, dirs, ts):
        out = pipeline(params, origins, dirs, ts)
        return out["rgb"], out["depth"]

    return render_chunk


def eval_render_fn(field_cfg, render_cfg: rendering.RenderConfig, chunk: int):
    """Jitted `make_render_chunk` for (field_cfg, render_cfg, chunk)."""
    key = (field_cfg, render_cfg, int(chunk))
    if key not in _EVAL_RENDER_CACHE:
        _EVAL_RENDER_CACHE[key] = jax.jit(make_render_chunk(field_cfg, render_cfg))
    return _EVAL_RENDER_CACHE[key]


def image_rays(pose, h: int, w: int, focal: float, eval_chunk: int):
    """Full-image rays padded to a chunk quantum.

    Returns (origins, dirs, n, chunk) with origins/dirs of length
    ceil(n/chunk)*chunk — the padding repeats the last ray so dirs stay
    unit-norm; callers trim to n.  Shared by `render_image` and the serve3d
    RenderService so both produce identical chunks (and hit the same
    compile-cache entries)."""
    py, px = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    o, d = rendering.pixel_rays(
        jnp.asarray(pose), px.reshape(-1), py.reshape(-1), h, w, focal
    )
    n = h * w
    chunk = min(int(eval_chunk), n)
    pad = (-n) % chunk
    if pad:
        o = jnp.concatenate([o, jnp.broadcast_to(o[-1:], (pad, 3))])
        d = jnp.concatenate([d, jnp.broadcast_to(d[-1:], (pad, 3))])
    return o, d, n, chunk


@dataclass(frozen=True)
class TrainerConfig:
    n_rays: int = 1024
    iters: int = 400
    lr: float = 1e-2
    eps: float = 1e-15              # Instant-NGP's Adam epsilon
    b2: float = 0.99
    mlp_weight_decay: float = 1e-6
    # update frequencies, F_D : F_C = 1 : 0.5 by default (paper §5.1)
    f_density: float = 1.0
    f_color: float = 0.5
    use_occupancy: bool = True
    occ: occupancy.OccupancyConfig = dc_field(default_factory=occupancy.OccupancyConfig)
    render: rendering.RenderConfig = dc_field(default_factory=rendering.RenderConfig)
    seed: int = 0
    eval_chunk: int = 4096
    # occupancy-compacted field queries (pipeline stage 3): only live points
    # hit the hash grids; the budget tracks the measured live fraction in
    # pow2 buckets (bounded recompiles) with headroom against drift.
    compact: bool = True
    budget_headroom: float = 1.3
    min_budget: int = 512
    # fused compacted-path kernel (default on): the shade stage encodes all
    # grids in one pass over the Morton-ordered budget batch and back-props
    # table gradients through the pre-sorted BUM merge.  Bit-identical to the
    # unfused compacted path on the ref backend; turn off to time/debug the
    # PR 1 per-grid shade.
    fused_path: bool = True
    # occupancy-guided sample redistribution (pipeline stage 2b): re-spend
    # each ray's freed sample budget on its live segments — S' = budget // B
    # samples per ray, inverse-CDF placed, per-sample quadrature deltas.
    # Points per step can only shrink (B*S' <= budget) while live regions
    # get finer stratification.  Enable it when a hard max_budget ceiling
    # bites (uniform compaction then truncates live points; BENCH_sampler
    # measures +1.8 dB held-out at equal points) — at generous budgets keep it off:
    # the uniform sampler is already unbiased there and shares its
    # quadrature with the dense eval renderer.  Off is the bit-exact
    # baseline.  Interaction with the budget-keyed step
    # cache: S' derives from the *static* budget at trace time, so the
    # existing (freeze_color, freeze_density, budget, use_bits) key already
    # pins the redistributed shapes — no new cache dimension.
    redistribute: bool = False
    # hard per-step point ceiling (on-device memory/latency cap).  When it
    # clamps the bucket below the live count, the uniform sampler must drop
    # live points every step (Morton-tail truncation); redistribution
    # spends exactly the ceiling instead, evenly across rays.
    max_budget: int | None = None


def _branch_update(i: int, freq: float) -> bool:
    """Whether branch with frequency `freq` updates at iteration i (0-based)."""
    if freq >= 1.0:
        return True
    return math.floor((i + 1) * freq) > math.floor(i * freq)


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    occ_state: occupancy.OccupancyState
    step: int


class Instant3DTrainer:
    def __init__(self, field: field_lib.Field, cfg: TrainerConfig):
        self.field = field
        self.cfg = cfg

        def lr_scale(path):
            # grids at full lr, MLPs at 0.1x — the NGP recipe
            return 1.0 if any("grid" in p for p in path) else 0.1

        self.opt = AdamW(
            lr=cfg.lr, b2=cfg.b2, eps=cfg.eps, weight_decay=0.0, lr_scale_fn=lr_scale
        )
        self.pipeline = RenderPipeline(
            field, cfg.render, fused_path=cfg.fused_path,
            redistribute=cfg.redistribute,
        )
        self._step_fns = {}
        # host-side live-fraction estimate driving the compaction budget;
        # starts at 1.0 (occupancy warmup = all-occupied => dense)
        self._live_frac = 1.0
        # rolling per-step overflow scalars (device) feeding the budget-widening
        # check; kept on the instance (not per train() call) so time-sliced
        # training — many short train() calls — widens exactly like one long
        # sequential run regardless of where the slice boundaries fall
        self._overflow_window: list = []

    # ---- state ----

    def init(self, rng: jax.Array) -> TrainState:
        params = self.field.init(rng)
        return TrainState(
            params=params,
            opt_state=self.opt.init(params),
            occ_state=occupancy.init_state(self.cfg.occ),
            step=0,
        )

    # ---- jitted step ----

    def _make_step(self, freeze_color: bool, freeze_density: bool = False,
                   budget: int | None = None, use_bits: bool = False):
        cfg, opt, pipeline = self.cfg, self.opt, self.pipeline
        decomposed = self.field.cfg.decomposed

        def loss_fn(params, batch: rendering.RayBatch, ts, occ_ema):
            if freeze_color and decomposed:
                params = dict(params)
                params["color_grid"] = jax.lax.stop_gradient(params["color_grid"])
            if freeze_density:
                params = dict(params)
                params["density_grid"] = jax.lax.stop_gradient(params["density_grid"])
            bits = None
            if use_bits:
                # zero-init EMA is exactly zero until the first update folds
                # (trunc_exp densities are strictly positive afterwards), so
                # max>0 recovers the step for bitfield's all-occupied warmup
                # even when callers invoke step_fn directly on a fresh state
                folded = (jnp.max(occ_ema) > 0.0).astype(jnp.int32)
                state = occupancy.OccupancyState(occ_ema, folded)
                bits = occupancy.bitfield(state, cfg.occ)
            out = pipeline(
                params, batch.origins, batch.dirs, ts, bitfield=bits, budget=budget
            )
            aux = {
                "live_fraction": out["live_fraction"],
                "overflow": out["overflow"],
                "points_queried": out["points_queried"],
            }
            return losses.mse(out["rgb"], batch.rgb_gt), aux

        def step(params, opt_state, batch, ts, occ_ema):
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, ts, occ_ema
            )
            mask = jax.tree.map(lambda _: True, params)
            if freeze_color:
                mask["color_grid"] = False
            if freeze_density:
                mask["density_grid"] = False
            params, opt_state = opt.apply(params, grads, opt_state, mask=mask)
            return params, opt_state, loss, aux

        return jax.jit(step, donate_argnums=(0, 1))

    def step_fn(self, freeze_color: bool, freeze_density: bool = False,
                budget: int | None = None, use_bits: bool | None = None):
        if use_bits is None:
            use_bits = self.cfg.use_occupancy
        key = (freeze_color, freeze_density, budget, use_bits)
        if key not in self._step_fns:
            self._step_fns[key] = self._make_step(
                freeze_color, freeze_density, budget, use_bits
            )
        return self._step_fns[key]

    def _current_budget(self, use_bits: bool) -> int | None:
        """Static point budget for the next step, or None for the dense path.

        Gated on use_bits: before the first occupancy update the bitfield is
        inactive and nearly all in-box samples are live, so a carried-over
        budget (e.g. trainer reused on a fresh state) would silently drop
        live samples."""
        if not (self.cfg.compact and self.cfg.use_occupancy and use_bits):
            return None
        n_total = self.cfg.n_rays * self.cfg.render.n_samples
        budget = suggest_budget(
            self._live_frac, n_total,
            headroom=self.cfg.budget_headroom, min_budget=self.cfg.min_budget,
            max_budget=self.cfg.max_budget,
        )
        return None if budget >= n_total else budget

    # ---- driver ----

    def train(
        self,
        state: TrainState,
        sampler,
        iters: int | None = None,
        log_every: int = 50,
        callback=None,
    ) -> tuple[TrainState, dict]:
        cfg = self.cfg
        iters = iters if iters is not None else cfg.iters
        key = jax.random.PRNGKey(cfg.seed)
        history = {"step": [], "loss": [], "live_fraction": [], "wall_s": [],
                   "points_queried": [], "overflow": []}
        # per-step overflow scalars kept on device (no per-step host sync);
        # folded into history at the end so no overflowing step goes unseen
        overflow_accum = []
        t0 = time.perf_counter()

        params, opt_state, occ_state = state.params, state.opt_state, state.occ_state
        # bitfield is meaningless until the first EMA fold (init is zeros);
        # render dense until then, and budget from the measured live fraction
        occ_updates = int(occ_state.step) if cfg.use_occupancy else 0
        if occ_updates == 0:
            self._live_frac = 1.0  # fresh state: forget any previous run
            self._overflow_window = []
        for local_i in range(iters):
            i = state.step + local_i
            key_batch, key_ts, key_occ = jax.random.split(jax.random.fold_in(key, i), 3)
            batch = sampler.sample(key_batch, cfg.n_rays)
            ts = rendering.sample_ts(key_ts, cfg.n_rays, cfg.render)

            update_color = _branch_update(i, cfg.f_color)
            update_density = _branch_update(i, cfg.f_density)
            freeze_color = (not update_color) and self.field.cfg.decomposed
            freeze_density = not update_density

            use_bits = cfg.use_occupancy and occ_updates > 0
            step = self.step_fn(
                freeze_color, freeze_density, self._current_budget(use_bits), use_bits
            )
            params, opt_state, loss, aux = step(
                params, opt_state, batch, ts, occ_state.density_ema
            )
            overflow_accum.append(aux["overflow"])
            self._overflow_window.append(aux["overflow"])
            del self._overflow_window[: -cfg.occ.update_interval]

            if cfg.use_occupancy and i >= cfg.occ.warmup_steps and (i + 1) % cfg.occ.update_interval == 0:
                occ_state = occupancy.update(self.field, params, occ_state, cfg.occ, key_occ)
                occ_updates += 1
                # re-measure the batch live fraction at the occupancy cadence
                # (one host sync per update, not per step) to size the budget;
                # overflow here means the live set outgrew the bucket between
                # measurements — widen beyond the measurement so the next
                # bucket has room
                if use_bits:
                    measured = float(aux["live_fraction"])
                    # consider every step since the last update, not just this
                    # one — per-step live counts fluctuate with stratified ts.
                    # The window lives on the instance so it spans train()
                    # calls (time-sliced sessions see the same history).
                    recent = self._overflow_window[-cfg.occ.update_interval:]
                    if recent and int(jnp.sum(jnp.stack(recent))) > 0:
                        measured = min(1.0, measured * 2.0)
                    self._live_frac = measured

            if (local_i + 1) % log_every == 0 or local_i == iters - 1:
                history["step"].append(i + 1)
                history["loss"].append(float(loss))
                history["live_fraction"].append(float(aux["live_fraction"]))
                history["points_queried"].append(int(aux["points_queried"]))
                history["overflow"].append(int(aux["overflow"]))
                history["wall_s"].append(time.perf_counter() - t0)
                if callback is not None:
                    callback(i + 1, params, history)

        if overflow_accum:
            all_overflow = jnp.stack(overflow_accum)
            history["overflow_total"] = int(jnp.sum(all_overflow))
            history["overflow_steps"] = int(jnp.sum(all_overflow > 0))
        else:
            history["overflow_total"] = 0
            history["overflow_steps"] = 0
        return TrainState(params, opt_state, occ_state, state.step + iters), history

    # ---- suspend / resume (host-state hooks for time-sliced sessions) ----

    def suspend(self, state: TrainState) -> dict:
        """Device -> host snapshot of everything needed to continue
        bit-identically: model/optimizer/occupancy state plus the trainer's
        host-side compaction bookkeeping (live fraction + overflow window).
        The returned flat-keyed dict is exactly what `CheckpointManager.save`
        expects, and `resume` (or `suspend` of a fresh `init` state, as a
        restore template) round-trips it."""
        win = np.zeros((self.cfg.occ.update_interval,), np.int32)
        recent = [int(x) for x in self._overflow_window[-len(win):]]
        if recent:
            win[-len(recent):] = recent
        return {
            "params": jax.device_get(state.params),
            "opt": jax.device_get(state.opt_state),
            "occ_ema": np.asarray(state.occ_state.density_ema),
            "occ_step": np.asarray(state.occ_state.step),
            "step": np.asarray(state.step, np.int32),
            "live_frac": np.asarray(self._live_frac, np.float32),
            "overflow_window": win,
        }

    def resume(self, tree: dict) -> TrainState:
        """Inverse of `suspend`: restore host state onto the device and
        re-seed the trainer's compaction bookkeeping."""
        self._live_frac = float(tree["live_frac"])
        self._overflow_window = [
            jnp.asarray(v, jnp.int32) for v in np.asarray(tree["overflow_window"])
        ]
        return TrainState(
            params=jax.tree.map(jnp.asarray, tree["params"]),
            opt_state=jax.tree.map(jnp.asarray, tree["opt"]),
            occ_state=occupancy.OccupancyState(
                jnp.asarray(tree["occ_ema"]), jnp.asarray(tree["occ_step"], jnp.int32)
            ),
            step=int(tree["step"]),
        )

    # ---- evaluation ----

    def render_image(self, params, pose: np.ndarray, ds):
        cfg = self.cfg
        h, w = ds.h, ds.w
        o, d, n, chunk = image_rays(pose, h, w, ds.focal, cfg.eval_chunk)
        ts = rendering.sample_ts(None, chunk, cfg.render)
        fn = eval_render_fn(self.field.cfg, cfg.render, chunk)
        rgb_out, dep_out = [], []
        for i in range(0, o.shape[0], chunk):
            rgb_c, dep_c = fn(params, o[i : i + chunk], d[i : i + chunk], ts)
            rgb_out.append(rgb_c)
            dep_out.append(dep_c)
        rgb = jnp.concatenate(rgb_out)[:n].reshape(h, w, 3)
        dep = jnp.concatenate(dep_out)[:n].reshape(h, w)
        return np.asarray(rgb), np.asarray(dep)

    def evaluate(self, params, ds, views=None) -> dict:
        """PSNR of rendered RGB and depth vs ground truth (paper Fig. 5 stats)."""
        views = views if views is not None else range(min(4, ds.images.shape[0]))
        rgb_ps, dep_ps = [], []
        for v in views:
            rgb, dep = self.render_image(params, ds.poses[v], ds)
            rgb_ps.append(float(losses.psnr(jnp.asarray(rgb), jnp.asarray(ds.images[v]))))
            # depth normalized to [0,1] over the far range for a bounded PSNR
            far = self.cfg.render.far
            dep_ps.append(float(losses.psnr(jnp.asarray(dep / far), jnp.asarray(ds.depths[v] / far))))
        return {"psnr_rgb": float(np.mean(rgb_ps)), "psnr_depth": float(np.mean(dep_ps))}
