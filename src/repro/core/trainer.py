"""Instant-3D training loop (paper §3 + §5.1 settings).

The paper's two algorithm knobs are first-class here:

* different grid sizes: `FieldConfig.log2_table_density/color` (S_D : S_C);
* different update frequencies: `f_density`, `f_color` in [0, 1].  An
  iteration updates branch b iff floor(i*F_b) > floor((i-1)*F_b).  Frozen
  branches are routed through `stop_gradient` (their gradient scatter
  disappears from the backward HLO — the compute saving is real, not masked)
  and the optimizer skips their moments (`AdamW.apply(mask=...)`).

Two jitted step functions are compiled once (freeze_color True/False); the
scheduler picks per-iteration, mirroring the accelerator "skipping one
back-propagation every 1/(1-F) iterations" (paper §4.6).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field as dc_field
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import field as field_lib
from . import losses, occupancy, rendering
from .pipeline import RenderPipeline, suggest_budget
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..optim import AdamW

# note: the sampler/dataset arguments below are duck-typed (repro.data types);
# importing repro.data here would create a package cycle


# ---- shared eval-render compile cache ----
#
# Keyed per (field config, render config, chunk size): concurrent scene
# sessions with the *same* geometry share exactly one compiled function,
# while sessions with different grid sizes get distinct entries instead of
# silently thrashing (or worse, sharing) one trainer's cached jit.  The
# function closes over a Field built from the config, so any caller holding
# only configs (e.g. the serve3d RenderService) can use it too.
_EVAL_RENDER_CACHE: dict[tuple, Any] = {}


def make_render_chunk(field_cfg, render_cfg: rendering.RenderConfig):
    """Unjitted dense-pipeline chunk renderer built purely from configs:
    (params, origins (N,3), dirs (N,3), ts (N,S)) -> (rgb, depth).  The single
    construction point for every eval-render cache (plain and vmapped), so
    their entries always compute the same function."""
    pipeline = RenderPipeline(field_lib.Field(field_cfg), render_cfg)

    def render_chunk(params, origins, dirs, ts):
        out = pipeline(params, origins, dirs, ts)
        return out["rgb"], out["depth"]

    return render_chunk


def eval_render_fn(field_cfg, render_cfg: rendering.RenderConfig, chunk: int):
    """Jitted `make_render_chunk` for (field_cfg, render_cfg, chunk)."""
    key = (field_cfg, render_cfg, int(chunk))
    if key not in _EVAL_RENDER_CACHE:
        _EVAL_RENDER_CACHE[key] = jax.jit(make_render_chunk(field_cfg, render_cfg))
    return _EVAL_RENDER_CACHE[key]


def make_redistributed_render_chunk(field_cfg, render_cfg: rendering.RenderConfig,
                                    occ_cfg: occupancy.OccupancyConfig, budget: int,
                                    redistribute_v3: bool = False):
    """Occupancy-redistributed chunk renderer (pipeline stage 2b) built purely
    from configs: (params, origins (N,3), dirs (N,3), ts (N,S), occ_ema,
    occ_step) -> (rgb, depth).

    Instead of shading all N·S dense samples, the cull liveness of the dense
    candidates becomes each ray's occupancy probe and S' = budget // N
    redistributed samples are shaded per ray — the same quadrature the
    redistributing trainer marches, which is what closes the train/eval
    quadrature mismatch for served views.  The occupancy state rides along as
    plain arrays (jit-traceable), so callers holding only a published
    snapshot (params + occ EMA) can render without a live trainer; while
    occ_step == 0 the bitfield reads all-occupied and redistribution
    degrades gracefully to a uniform S'-sample preview.

    fused_path stays OFF here: the fused query's forward-pass corner-stream
    argsort buys its cost back in the pre-sorted backward merge, and a
    render has no backward — the plain per-grid query shades the compacted
    set cheaper.

    redistribute_v3=True serves the density-weighted ragged path instead:
    per-ray sample counts follow the chunk's live-mass distribution (the
    coalescer's compact stage packs the unequal rays Morton-ordered into
    the same static budget), and the published occupancy EMA weights the
    in-ray placement — served views then march the same v3 quadrature a
    redistribute_v3 trainer trains with."""
    pipeline = RenderPipeline(field_lib.Field(field_cfg), render_cfg,
                              fused_path=False, redistribute=True,
                              redistribute_v3=bool(redistribute_v3))

    def render_chunk(params, origins, dirs, ts, occ_ema, occ_step):
        bits = occupancy.bitfield(occupancy.OccupancyState(occ_ema, occ_step), occ_cfg)
        out = pipeline(params, origins, dirs, ts, bitfield=bits,
                       budget=int(budget), occ_ema=occ_ema)
        return out["rgb"], out["depth"]

    return render_chunk


_REDIST_RENDER_CACHE: dict[tuple, Any] = {}


def default_samples_per_ray(n_samples: int) -> int:
    """The serving default for the redistributed per-ray budget: S/4 (the
    PR 4 equal-PSNR point), floored at 4 and capped at S.  One definition
    shared by the serve3d service and `evaluate`, so offline eval and served
    renders march the same quadrature by construction."""
    s = int(n_samples)
    return min(s, max(4, s // 4))


def redistributed_render_fn(field_cfg, render_cfg: rendering.RenderConfig,
                            occ_cfg: occupancy.OccupancyConfig,
                            chunk: int, samples_per_ray: int,
                            redistribute_v3: bool = False):
    """Jitted `make_redistributed_render_chunk`; budget = chunk·samples_per_ray."""
    key = (field_cfg, render_cfg, occ_cfg, int(chunk), int(samples_per_ray),
           bool(redistribute_v3))
    if key not in _REDIST_RENDER_CACHE:
        _REDIST_RENDER_CACHE[key] = jax.jit(make_redistributed_render_chunk(
            field_cfg, render_cfg, occ_cfg, int(chunk) * int(samples_per_ray),
            redistribute_v3=bool(redistribute_v3),
        ))
    return _REDIST_RENDER_CACHE[key]


# vmapped-over-sessions flavor of the eval renderers: same make_render_chunk
# construction, keyed the same way plus the padded group size, so sessions
# with different grid sizes can never share an entry.  Lives here (not in
# serve3d.render) so `evaluate` and the serve3d RenderService hit the same
# compiled functions — on XLA:CPU a vmapped group of 1 differs from the
# unvmapped renderer by ~1 ulp, so sharing one entry point is what makes
# "offline eval == served render" hold bit-for-bit, not just approximately.
_BATCH_RENDER_CACHE: dict[tuple, Any] = {}


def batched_render_fn(field_cfg, render_cfg: rendering.RenderConfig,
                      chunk: int, group: int):
    """(params stacked over G, origins (G,chunk,3), dirs (G,chunk,3),
    ts (chunk,S)) -> (rgb (G,chunk,3), depth (G,chunk))."""
    key = (field_cfg, render_cfg, int(chunk), int(group))
    if key not in _BATCH_RENDER_CACHE:
        _BATCH_RENDER_CACHE[key] = jax.jit(
            jax.vmap(make_render_chunk(field_cfg, render_cfg),
                     in_axes=(0, 0, 0, None))
        )
    return _BATCH_RENDER_CACHE[key]


def batched_redistributed_render_fn(field_cfg, render_cfg: rendering.RenderConfig,
                                    occ_cfg, chunk: int, group: int,
                                    samples_per_ray: int,
                                    redistribute_v3: bool = False):
    """Redistributed flavor of `batched_render_fn`: adds per-session
    occupancy (ema (G,R^3), fold count (G,)) inputs and shades only
    chunk·samples_per_ray points per session instead of chunk·S.

    redistribute_v3=True serves the density-weighted ragged path: the
    coalescer's chunk budget is spent unevenly across the chunk's rays
    (long live segments get more samples, packed Morton-ordered by the
    pipeline's compact stage), with the snapshot EMA weighting in-ray
    placement."""
    key = (field_cfg, render_cfg, occ_cfg, int(chunk), int(group),
           int(samples_per_ray), bool(redistribute_v3))
    if key not in _BATCH_RENDER_CACHE:
        _BATCH_RENDER_CACHE[key] = jax.jit(
            jax.vmap(make_redistributed_render_chunk(
                field_cfg, render_cfg, occ_cfg,
                int(chunk) * int(samples_per_ray),
                redistribute_v3=bool(redistribute_v3)),
                in_axes=(0, 0, 0, None, 0, 0))
        )
    return _BATCH_RENDER_CACHE[key]


def image_rays(pose, h: int, w: int, focal: float, eval_chunk: int):
    """Full-image rays padded to a chunk quantum.

    Returns (origins, dirs, n, chunk) with origins/dirs of length
    ceil(n/chunk)*chunk — the padding repeats the last ray so dirs stay
    unit-norm; callers trim to n.  Shared by `render_image` and the serve3d
    RenderService so both produce identical chunks (and hit the same
    compile-cache entries)."""
    py, px = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    o, d = rendering.pixel_rays(
        jnp.asarray(pose), px.reshape(-1), py.reshape(-1), h, w, focal
    )
    n = h * w
    chunk = min(int(eval_chunk), n)
    pad = (-n) % chunk
    if pad:
        o = jnp.concatenate([o, jnp.broadcast_to(o[-1:], (pad, 3))])
        d = jnp.concatenate([d, jnp.broadcast_to(d[-1:], (pad, 3))])
    return o, d, n, chunk


@dataclass(frozen=True)
class TrainerConfig:
    n_rays: int = 1024
    iters: int = 400
    lr: float = 1e-2
    eps: float = 1e-15              # Instant-NGP's Adam epsilon
    b2: float = 0.99
    mlp_weight_decay: float = 1e-6
    # update frequencies, F_D : F_C = 1 : 0.5 by default (paper §5.1)
    f_density: float = 1.0
    f_color: float = 0.5
    use_occupancy: bool = True
    occ: occupancy.OccupancyConfig = dc_field(default_factory=occupancy.OccupancyConfig)
    render: rendering.RenderConfig = dc_field(default_factory=rendering.RenderConfig)
    seed: int = 0
    eval_chunk: int = 4096
    # occupancy-compacted field queries (pipeline stage 3): only live points
    # hit the hash grids; the budget tracks the measured live fraction in
    # pow2 buckets (bounded recompiles) with headroom against drift.
    compact: bool = True
    budget_headroom: float = 1.3
    min_budget: int = 512
    # fused compacted-path kernel (default on): the shade stage encodes all
    # grids in one pass over the Morton-ordered budget batch and back-props
    # table gradients through the pre-sorted BUM merge.  Bit-identical to the
    # unfused compacted path on the ref backend; turn off to time/debug the
    # PR 1 per-grid shade.
    fused_path: bool = True
    # one-kernel shade (default on, only meaningful with fused_path): the
    # compacted shade runs encode + both MLP heads as ONE custom-VJP op
    # (`field.query_step`) with the field config's residual policy deciding
    # what survives to the backward.  Bit-identical to fused_path with
    # separate MLP dispatches on the ref backend; turn off to time/debug the
    # PR 3 encode-then-MLP split.
    fused_step: bool = True
    # occupancy-guided sample redistribution (pipeline stage 2b): re-spend
    # each ray's freed sample budget on its live segments — S' = budget // B
    # samples per ray, inverse-CDF placed, per-sample quadrature deltas.
    # Points per step can only shrink (B*S' <= budget) while live regions
    # get finer stratification.  Enable it when a hard max_budget ceiling
    # bites (uniform compaction then truncates live points; BENCH_sampler
    # measures +1.8 dB held-out at equal points) — at generous budgets keep it off:
    # the uniform sampler is already unbiased there and shares its
    # quadrature with the dense eval renderer.  Off is the bit-exact
    # baseline.  Interaction with the budget-keyed step
    # cache: S' derives from the *static* budget at trace time, so the
    # existing (freeze_color, freeze_density, budget, use_bits) key already
    # pins the redistributed shapes — no new cache dimension.
    redistribute: bool = False
    # density-weighted, workload-balanced redistribution (stage 2b, v3):
    # live strata are weighted by the occupancy EMA (samples concentrate at
    # surface crossings) and the per-ray sample count S'_i is allocated by
    # one global inverse-CDF over the batch's live masses — rays with long
    # live segments get more of the point budget, sum(S') <= budget by
    # construction, and the compact stage Morton-packs the ragged rays into
    # exactly the budget.  Supersedes `redistribute` when both are set.
    # Budget keying: the ragged lane shapes derive from the *static* budget
    # at trace time and the knob lives on this config, so the existing
    # (cfg, ..., budget, use_bits) step-cache key already pins every v3
    # shape variant — no new cache dimension.  Off (default) is bit-exact:
    # the stage is never traced.
    redistribute_v3: bool = False
    # hard per-step point ceiling (on-device memory/latency cap).  When it
    # clamps the bucket below the live count, the uniform sampler must drop
    # live points every step (Morton-tail truncation); redistribution
    # spends exactly the ceiling instead, evenly across rays.
    max_budget: int | None = None


def autotune_max_budget(
    field_cfg,
    render_cfg: rendering.RenderConfig,
    *,
    memory_bytes: int | None = None,
    latency_ms: float | None = None,
    us_per_point: float | None = None,
    mlp_width: int = 64,
    min_budget: int = 512,
) -> int | None:
    """Derive a `TrainerConfig.max_budget` ceiling from device constraints.

    The on-device caps the paper targets are memory (a headset SoC's working
    set) and per-step latency; this hook turns either into the pow2 point
    ceiling the budget controller (and redistribute v3's exact-spend
    allocation) consumes:

    * memory: bytes/point is modeled from the field config — per grid
      L·F·4 B of features plus 8·4 B of corner indices, point/dir/sigma/rgb
      lanes, and two MLP activation slabs (forward + the recompute-policy
      backward residual).  `memory_bytes // bytes_per_point`, bucketed DOWN
      to a power of two (a ceiling must never round up).
    * latency: `latency_ms` over a measured `us_per_point` (e.g. the
      BENCH_fused_path per-point time) — callers without a measurement can
      pass none and get a memory-only answer.

    Returns the binding (smaller) ceiling, floored at `min_budget`, or None
    when no constraint was given (no ceiling — the suggest_budget default).
    """
    caps = []
    if memory_bytes is not None:
        n_grids = 2 if getattr(field_cfg, "decomposed", True) else 1
        feat = field_cfg.n_levels * field_cfg.n_features * 4 * n_grids
        corners = field_cfg.n_levels * 8 * 4 * n_grids
        lanes = (3 + 3 + 1 + 3) * 4                      # point/dir/sigma/rgb
        acts = 2 * mlp_width * 4                          # fwd + bwd residual
        caps.append(int(memory_bytes) // (feat + corners + lanes + acts))
    if latency_ms is not None and us_per_point:
        caps.append(int(float(latency_ms) * 1e3 / float(us_per_point)))
    if not caps:
        return None
    cap = max(min(caps), int(min_budget))
    b = 1
    while b * 2 <= cap:
        b *= 2
    return b


@jax.jit
def _finite_reduce(trees) -> jax.Array:
    acc = jnp.bool_(True)
    for leaf in jax.tree_util.tree_leaves(trees):
        x = jnp.asarray(leaf)
        if jnp.issubdtype(x.dtype, jnp.inexact):
            acc = acc & jnp.all(jnp.isfinite(x))
    return acc


def tree_all_finite(*trees) -> bool:
    """True iff every inexact leaf of every tree is finite (no NaN/Inf).

    The serve3d divergence guard's deep check: params, optimizer moments and
    the occupancy EMA are reduced to one host bool per call.  Integer leaves
    (opt step counts, occupancy fold counts) are skipped — finiteness is a
    float question.  The reduction is jitted (cached per tree structure, so
    per-slice cost is one dispatch + one scalar sync, the ≤ 1% guard-overhead
    budget) but runs strictly *outside* the training step's compiled path,
    so enabling the guard can never perturb traced training code."""
    acc = _finite_reduce(tuple(trees))
    return bool(acc)


def _branch_update(i: int, freq: float) -> bool:
    """Whether branch with frequency `freq` updates at iteration i (0-based)."""
    if freq >= 1.0:
        return True
    return math.floor((i + 1) * freq) > math.floor(i * freq)


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    occ_state: occupancy.OccupancyState
    step: int


def _make_opt(cfg: TrainerConfig) -> AdamW:
    def lr_scale(path):
        # grids at full lr, MLPs at 0.1x — the NGP recipe
        return 1.0 if any("grid" in p for p in path) else 0.1

    return AdamW(
        lr=cfg.lr, b2=cfg.b2, eps=cfg.eps, weight_decay=0.0, lr_scale_fn=lr_scale
    )


def _make_raw_step(field, opt, pipeline, cfg: TrainerConfig, freeze_color: bool,
                   freeze_density: bool, budget: int | None, use_bits: bool):
    """Unjitted single-member train step: (params, opt_state, batch, ts,
    occ_ema) -> (params, opt_state, loss, aux).  The one construction point
    for both the legacy per-instance jit (`Instant3DTrainer.step_fn`) and the
    member-axis cohort step (`cohort_step_fn`), so they always compute the
    same function."""
    decomposed = field.cfg.decomposed

    def loss_fn(params, batch: rendering.RayBatch, ts, occ_ema):
        if freeze_color and decomposed:
            params = dict(params)
            params["color_grid"] = jax.lax.stop_gradient(params["color_grid"])
        if freeze_density:
            params = dict(params)
            params["density_grid"] = jax.lax.stop_gradient(params["density_grid"])
        bits = None
        if use_bits:
            # zero-init EMA is exactly zero until the first update folds
            # (trunc_exp densities are strictly positive afterwards), so
            # max>0 recovers the step for bitfield's all-occupied warmup
            # even when callers invoke step_fn directly on a fresh state
            folded = (jnp.max(occ_ema) > 0.0).astype(jnp.int32)
            state = occupancy.OccupancyState(occ_ema, folded)
            bits = occupancy.bitfield(state, cfg.occ)
        out = pipeline(
            params, batch.origins, batch.dirs, ts, bitfield=bits, budget=budget,
            # the EMA only feeds redistribute v3's stratum weights; the
            # pipeline ignores it on every other path
            occ_ema=occ_ema if use_bits else None,
        )
        aux = {
            "live_fraction": out["live_fraction"],
            "overflow": out["overflow"],
            "points_queried": out["points_queried"],
        }
        return losses.mse(out["rgb"], batch.rgb_gt), aux

    def step(params, opt_state, batch, ts, occ_ema):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, ts, occ_ema
        )
        mask = jax.tree.map(lambda _: True, params)
        if freeze_color:
            mask["color_grid"] = False
        if freeze_density:
            mask["density_grid"] = False
        params, opt_state = opt.apply(params, grads, opt_state, mask=mask)
        return params, opt_state, loss, aux

    return step


# ---- cohort step / occupancy-update compile caches (module level) ----
#
# Keyed per (field config, trainer config, step variant, cohort size M):
# every trainer instance and every train cohort with the same configs shares
# ONE compiled step — sequential baselines re-built per scene (benchmarks,
# parity checks) stop re-jitting, and a cohort re-formed under a different
# lead session never recompiles.
#
# The member axis is batched with `jax.lax.map` (scan), NOT `jax.vmap`:
# vmapping the step lets XLA:CPU re-tile the batched matmul/reduction
# contractions, which reassociates float accumulation and drifts the cohort
# ~1e-9 from the sequential path per step.  The scan body compiles once at
# singleton shapes and is empirically invariant to the trip count M and the
# member order (asserted by tests/test_serve3d_cohort.py), which is what
# makes cohort == sequential EXACT — `Instant3DTrainer.train` routes through
# the same construction at M=1.
_COHORT_STEP_CACHE: dict[tuple, Any] = {}
_OCC_UPDATE_CACHE: dict[tuple, Any] = {}


def _cohort_step_key(field_cfg, cfg: TrainerConfig, freeze_color: bool,
                     freeze_density: bool, budget: int | None, use_bits: bool,
                     m: int) -> tuple:
    """Cache key for one compiled step variant — also the observable the
    trace layer uses to split trainer/step_compile from trainer/step (a key
    first enters the cache on the same call that compiles it)."""
    return (field_cfg, cfg, bool(freeze_color), bool(freeze_density),
            budget, bool(use_bits), int(m))


def step_variant_cached(field_cfg, cfg: TrainerConfig, freeze_color: bool,
                        freeze_density: bool, budget: int | None,
                        use_bits: bool, m: int) -> bool:
    """Whether this step variant has already been built (and therefore
    compiled on its first call)."""
    return _cohort_step_key(field_cfg, cfg, freeze_color, freeze_density,
                            budget, use_bits, m) in _COHORT_STEP_CACHE


def cohort_step_fn(field_cfg, cfg: TrainerConfig, freeze_color: bool,
                   freeze_density: bool, budget: int | None, use_bits: bool,
                   m: int):
    """Jitted member-axis train step for an M-member cohort.

    (params, opt_state, batch, occ_ema) carry a leading member axis of size
    M; ts is shared (cohort members march the same step-keyed sample
    stream).  Stacked params/opt buffers are donated — the cohort advances
    in place like the per-instance step."""
    key = _cohort_step_key(field_cfg, cfg, freeze_color, freeze_density,
                           budget, use_bits, m)
    if obs_trace.enabled():
        which = "miss" if key not in _COHORT_STEP_CACHE else "hit"
        obs_metrics.counter(f"trainer.step_cache.{which}").inc()
    if key not in _COHORT_STEP_CACHE:
        field = field_lib.Field(field_cfg)
        pipeline = RenderPipeline(
            field, cfg.render, fused_path=cfg.fused_path,
            fused_step=cfg.fused_step, redistribute=cfg.redistribute,
            redistribute_v3=cfg.redistribute_v3,
        )
        raw = _make_raw_step(field, _make_opt(cfg), pipeline, cfg,
                             freeze_color, freeze_density, budget, use_bits)

        def member_steps(params, opt_state, batch, ts, occ_ema):
            return jax.lax.map(
                lambda a: raw(a[0], a[1], a[2], ts, a[3]),
                (params, opt_state, batch, occ_ema),
            )

        _COHORT_STEP_CACHE[key] = jax.jit(member_steps, donate_argnums=(0, 1))
    return _COHORT_STEP_CACHE[key]


def occ_update_fn(field_cfg, occ_cfg: occupancy.OccupancyConfig, m: int):
    """Jitted member-axis occupancy update for an M-member cohort.

    One compiled R^3-point density re-query serves the whole cohort (shared
    jitter rng, per-member params/EMA) instead of M eager op-by-op sweeps —
    the single biggest fixed cost the cohort amortizes.  Bit-identical to
    the eager `occupancy.update` at every M (the update is gather + matmul +
    elementwise max; no batched reassociation)."""
    key = (field_cfg, occ_cfg, int(m))
    if key not in _OCC_UPDATE_CACHE:
        field = field_lib.Field(field_cfg)

        def update_members(params, ema, step, rng):
            return jax.lax.map(
                lambda a: occupancy.update(
                    field, a[0], occupancy.OccupancyState(a[1], a[2]), occ_cfg, rng
                ),
                (params, ema, step),
            )

        _OCC_UPDATE_CACHE[key] = jax.jit(update_members)
    return _OCC_UPDATE_CACHE[key]


class Instant3DTrainer:
    def __init__(self, field: field_lib.Field, cfg: TrainerConfig):
        self.field = field
        self.cfg = cfg
        self.opt = _make_opt(cfg)
        self.pipeline = RenderPipeline(
            field, cfg.render, fused_path=cfg.fused_path,
            fused_step=cfg.fused_step, redistribute=cfg.redistribute,
            redistribute_v3=cfg.redistribute_v3,
        )
        self._step_fns = {}
        # host-side live-fraction estimate driving the compaction budget;
        # starts at 1.0 (occupancy warmup = all-occupied => dense)
        self._live_frac = 1.0
        # rolling per-step overflow scalars (device) feeding the budget-widening
        # check; kept on the instance (not per train() call) so time-sliced
        # training — many short train() calls — widens exactly like one long
        # sequential run regardless of where the slice boundaries fall
        self._overflow_window: list = []

    # ---- state ----

    def init(self, rng: jax.Array) -> TrainState:
        params = self.field.init(rng)
        return TrainState(
            params=params,
            opt_state=self.opt.init(params),
            occ_state=occupancy.init_state(self.cfg.occ),
            step=0,
        )

    # ---- jitted step ----

    def _make_step(self, freeze_color: bool, freeze_density: bool = False,
                   budget: int | None = None, use_bits: bool = False):
        step = _make_raw_step(self.field, self.opt, self.pipeline, self.cfg,
                              freeze_color, freeze_density, budget, use_bits)
        return jax.jit(step, donate_argnums=(0, 1))

    def step_fn(self, freeze_color: bool, freeze_density: bool = False,
                budget: int | None = None, use_bits: bool | None = None):
        if use_bits is None:
            use_bits = self.cfg.use_occupancy
        key = (freeze_color, freeze_density, budget, use_bits)
        if key not in self._step_fns:
            self._step_fns[key] = self._make_step(
                freeze_color, freeze_density, budget, use_bits
            )
        return self._step_fns[key]

    def _current_budget(self, use_bits: bool) -> int | None:
        """Static point budget for the next step, or None for the dense path.

        Gated on use_bits: before the first occupancy update the bitfield is
        inactive and nearly all in-box samples are live, so a carried-over
        budget (e.g. trainer reused on a fresh state) would silently drop
        live samples."""
        if not (self.cfg.compact and self.cfg.use_occupancy and use_bits):
            return None
        n_total = self.cfg.n_rays * self.cfg.render.n_samples
        budget = suggest_budget(
            self._live_frac, n_total,
            headroom=self.cfg.budget_headroom, min_budget=self.cfg.min_budget,
            max_budget=self.cfg.max_budget,
        )
        return None if budget >= n_total else budget

    # ---- driver ----

    def train(
        self,
        state: TrainState,
        sampler,
        iters: int | None = None,
        log_every: int = 50,
        callback=None,
    ) -> tuple[TrainState, dict]:
        """Advance training by `iters` iterations.

        Implemented as a train cohort of one: the exact same member-axis
        compiled step and batched occupancy update that advance an M-scene
        cohort in serve3d run here at M=1, so a session trained inside a
        cohort and one trained alone produce bit-identical streams."""
        states, hists = train_cohort(
            [self], [state], [sampler],
            iters=iters, log_every=log_every, callback=callback,
        )
        return states[0], hists[0]

    def step_cache_keys(self) -> set:
        """Compiled step-variant keys for this trainer's configs (freeze
        flags, budget, use_bits, cohort size) — the observable for "did this
        run recompile?" probes now that step compilation is shared module-
        wide (benchmarks/bench_pipeline.py uses it to detect budget-bucket
        widening)."""
        return {
            k[2:] for k in _COHORT_STEP_CACHE
            if k[0] == self.field.cfg and k[1] == self.cfg
        }

    # ---- suspend / resume (host-state hooks for time-sliced sessions) ----

    def suspend(self, state: TrainState) -> dict:
        """Device -> host snapshot of everything needed to continue
        bit-identically: model/optimizer/occupancy state plus the trainer's
        host-side compaction bookkeeping (live fraction + overflow window).
        The returned flat-keyed dict is exactly what `CheckpointManager.save`
        expects, and `resume` (or `suspend` of a fresh `init` state, as a
        restore template) round-trips it."""
        win = np.zeros((self.cfg.occ.update_interval,), np.int32)
        recent = [int(x) for x in self._overflow_window[-len(win):]]
        if recent:
            win[-len(recent):] = recent
        return {
            "params": jax.device_get(state.params),
            "opt": jax.device_get(state.opt_state),
            "occ_ema": np.asarray(state.occ_state.density_ema),
            "occ_step": np.asarray(state.occ_state.step),
            "step": np.asarray(state.step, np.int32),
            "live_frac": np.asarray(self._live_frac, np.float32),
            "overflow_window": win,
        }

    def resume(self, tree: dict) -> TrainState:
        """Inverse of `suspend`: restore host state onto the device and
        re-seed the trainer's compaction bookkeeping."""
        self._live_frac = float(tree["live_frac"])
        self._overflow_window = [
            jnp.asarray(v, jnp.int32) for v in np.asarray(tree["overflow_window"])
        ]
        return TrainState(
            params=jax.tree.map(jnp.asarray, tree["params"]),
            opt_state=jax.tree.map(jnp.asarray, tree["opt"]),
            occ_state=occupancy.OccupancyState(
                jnp.asarray(tree["occ_ema"]), jnp.asarray(tree["occ_step"], jnp.int32)
            ),
            step=int(tree["step"]),
        )

    # ---- evaluation ----

    def render_image(self, params, pose: np.ndarray, ds, occ=None,
                     samples_per_ray: int | None = None):
        """Render one full view.  Dense by default; pass `occ` (the
        (density EMA, fold count) pair `suspend`/serve3d snapshots carry) to
        render through the configured redistribute variant instead — the
        exact vmapped group-of-1 entry the serve3d RenderService coalesces
        through, so an offline eval render is bit-identical to a served
        render of the same snapshot."""
        cfg = self.cfg
        h, w = ds.h, ds.w
        o, d, n, chunk = image_rays(pose, h, w, ds.focal, cfg.eval_chunk)
        ts = rendering.sample_ts(None, chunk, cfg.render)
        if occ is not None and cfg.use_occupancy:
            spr = (int(samples_per_ray) if samples_per_ray is not None
                   else default_samples_per_ray(cfg.render.n_samples))
            fn_r = batched_redistributed_render_fn(
                self.field.cfg, cfg.render, cfg.occ, chunk, 1, spr,
                redistribute_v3=cfg.redistribute_v3)
            occ_ema = jnp.asarray(occ[0])[None]
            occ_step = jnp.asarray([int(occ[1])], jnp.int32)
            stacked = jax.tree.map(lambda a: jnp.asarray(a)[None], params)
            fn = lambda p, oo, dd, tt: fn_r(  # noqa: E731
                stacked, oo[None], dd[None], tt, occ_ema, occ_step)
        else:
            fn = eval_render_fn(self.field.cfg, cfg.render, chunk)
        rgb_out, dep_out = [], []
        for i in range(0, o.shape[0], chunk):
            rgb_c, dep_c = fn(params, o[i : i + chunk], d[i : i + chunk], ts)
            if rgb_c.ndim == 3:          # strip the group-of-1 axis
                rgb_c, dep_c = rgb_c[0], dep_c[0]
            rgb_out.append(rgb_c)
            dep_out.append(dep_c)
        rgb = jnp.concatenate(rgb_out)[:n].reshape(h, w, 3)
        dep = jnp.concatenate(dep_out)[:n].reshape(h, w)
        return np.asarray(rgb), np.asarray(dep)

    def evaluate(self, params, ds, views=None, occ=None,
                 samples_per_ray: int | None = None) -> dict:
        """PSNR of rendered RGB and depth vs ground truth (paper Fig. 5
        stats).  With `occ`, views render through the redistribute variant
        (see `render_image`) so eval marches the serving quadrature."""
        views = views if views is not None else range(min(4, ds.images.shape[0]))
        rgb_ps, dep_ps = [], []
        for v in views:
            rgb, dep = self.render_image(params, ds.poses[v], ds, occ=occ,
                                         samples_per_ray=samples_per_ray)
            rgb_ps.append(float(losses.psnr(jnp.asarray(rgb), jnp.asarray(ds.images[v]))))
            # depth normalized to [0,1] over the far range for a bounded PSNR
            far = self.cfg.render.far
            dep_ps.append(float(losses.psnr(jnp.asarray(dep / far), jnp.asarray(ds.depths[v] / far))))
        return {"psnr_rgb": float(np.mean(rgb_ps)), "psnr_depth": float(np.mean(dep_ps))}


# ---- cohort driver: lockstep training of M same-config sessions ----


class _CohortGroup:
    """One stacked sub-cohort: members that currently share a compiled step
    variant (same use_bits + point budget).  Holds member-axis-stacked
    params/opt/occupancy plus the stacked ray pools their batches gather
    from.  The partition over groups only shifts when per-member budgets
    drift apart at an occupancy update, so stacked state persists across
    iterations — no per-step stack/unstack traffic."""

    def __init__(self, members, params, opt_state, ema, occ_step, samplers):
        self.members = list(members)          # global member indices, in order
        self.params = params                  # leading axis = len(members)
        self.opt_state = opt_state
        self.ema = ema                        # (G, R^3)
        self.occ_step = occ_step              # (G,) int32
        self.use_bits = False
        self.budget = None
        self.last_aux = None
        ns = {samplers[k].n for k in self.members}
        if len(self.members) > 1 and len(ns) == 1:
            # equal ray pools: one shared index draw gathers every member's
            # batch (identical indices to each member's own sampler.sample —
            # same key, same bound).  Only worth the stacked pool copy for a
            # real cohort; singletons (every plain train() call) gather from
            # the sampler's own arrays with zero extra device residency.
            self.pool = tuple(
                jnp.stack([getattr(samplers[k], f) for k in self.members])
                for f in ("origins", "dirs", "rgb")
            )
        else:
            self.pool = None

    def member_tree(self, tree, k: int):
        r = self.members.index(k)
        return jax.tree.map(lambda x: x[r], tree)

    def sample(self, samplers, key_batch, n_rays: int) -> rendering.RayBatch:
        if self.pool is not None:
            idx = samplers[self.members[0]].sample_idx(key_batch, n_rays)
            o, d, rgb = self.pool
            return rendering.RayBatch(o[:, idx], d[:, idx], rgb[:, idx])
        per = [samplers[k].sample(key_batch, n_rays) for k in self.members]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def _partition_members(trainers, use_occupancy, occ_updates):
    """(use_bits, budget) step-variant key per member -> ordered partition."""
    keys = []
    for k, tr in enumerate(trainers):
        use_bits = use_occupancy and occ_updates[k] > 0
        keys.append((use_bits, tr._current_budget(use_bits)))
    part: list[tuple[tuple, list[int]]] = []
    for k, key in enumerate(keys):
        grouped = next((g for g in part if g[0] == key), None)
        if grouped is None:
            part.append((key, [k]))
        else:
            grouped[1].append(k)
    return part


def train_cohort(
    trainers: list,
    states: list,
    samplers: list,
    iters: int | None = None,
    log_every: int = 50,
    callback=None,
) -> tuple[list, list]:
    """Advance M same-config training sessions in lockstep.

    All members must share (field config, trainer config) and sit at the
    same absolute step; their params, optimizer state, occupancy EMAs and
    ray batches are stacked along a leading member axis and one compiled
    member-axis step (`cohort_step_fn`) advances the whole cohort per
    iteration.  Per-member host bookkeeping (live-fraction estimate,
    overflow window — each `trainers[k]`'s instance state, exactly what
    suspend/resume round-trips) is maintained identically to M sequential
    `Instant3DTrainer.train` runs, and the compiled body is the same one
    `train` itself runs at M=1, so the cohort is bit-identical to
    sequential time-slicing — params, optimizer moments and occupancy EMA
    (asserted in tests and BENCH_serve3d).

    Members whose measured point budgets drift apart at an occupancy update
    split into separately-stacked sub-cohorts (`_CohortGroup`) and keep
    advancing in lockstep; the shared-key sample stream, occupancy cadence
    and freeze schedule depend only on the absolute step, so the split
    changes where the work happens, never the numbers.

    Returns (new_states, histories), parallel to the inputs.
    """
    m = len(trainers)
    assert m == len(states) == len(samplers), "trainers/states/samplers must align"
    lead = trainers[0]
    cfg, field_cfg = lead.cfg, lead.field.cfg
    for t in trainers[1:]:
        if t.cfg != cfg or t.field.cfg != field_cfg:
            raise ValueError("cohort members must share field and trainer configs")
    step0 = states[0].step
    if any(s.step != step0 for s in states):
        raise ValueError("cohort members must be at the same training step")
    iters = iters if iters is not None else cfg.iters
    key = jax.random.PRNGKey(cfg.seed)
    # one clock for history wall_s, spans and benchmarks (repro.obs.trace owns
    # it) — telemetry and bench timings can never disagree on step wall time
    t0 = obs_trace.clock()

    histories = [
        {"step": [], "loss": [], "live_fraction": [], "wall_s": [],
         "points_queried": [], "overflow": []}
        for _ in range(m)
    ]
    # per-step overflow kept on device as stacked (M,) scalars — ONE list
    # append per iteration, no per-member slicing in the hot loop; member
    # columns are materialized only at the occupancy cadence (budget check)
    # and at the end (history totals + each trainer's rolling window)
    overflow_accum: list = []

    def window_sums(recent: list) -> np.ndarray:
        """(M,) per-member sums over stacked window entries (one host sync)."""
        if not recent:
            return np.zeros((m,), np.int64)
        return np.asarray(jnp.sum(jnp.stack(recent), axis=0))

    # bitfield is meaningless until the first EMA fold (init is zeros);
    # render dense until then, and budget from the measured live fraction
    occ_updates = [
        int(s.occ_state.step) if cfg.use_occupancy else 0 for s in states
    ]
    for k, tr in enumerate(trainers):
        if occ_updates[k] == 0:
            tr._live_frac = 1.0  # fresh state: forget any previous run
            tr._overflow_window = []

    # seed the stacked (M,)-per-entry overflow window from the members'
    # per-trainer windows (they advance in lockstep, so equal lengths is the
    # invariant; a ragged mix — cohort formed from sessions with unrelated
    # histories — keeps exactness by degrading to per-member entries)
    prior = [t._overflow_window for t in trainers]
    if len({len(w) for w in prior}) == 1:
        window = [
            jnp.stack([jnp.asarray(w[j], jnp.int32) for w in prior])
            for j in range(len(prior[0]))
        ]
    else:
        window = None

    def build_groups(partition, member_state):
        groups = []
        for (use_bits, budget), members in partition:
            stackit = lambda f: jax.tree.map(
                lambda *xs: jnp.stack(xs), *[f(k) for k in members]
            )
            g = _CohortGroup(
                members,
                stackit(lambda k: member_state[k][0]),
                stackit(lambda k: member_state[k][1]),
                jnp.stack([member_state[k][2] for k in members]),
                jnp.stack([member_state[k][3] for k in members]),
                samplers,
            )
            g.use_bits, g.budget = use_bits, budget
            groups.append(g)
        return groups

    partition = _partition_members(trainers, cfg.use_occupancy, occ_updates)
    groups = build_groups(
        partition,
        [(s.params, s.opt_state, s.occ_state.density_ema, s.occ_state.step)
         for s in states],
    )

    for local_i in range(iters):
        i = step0 + local_i
        key_batch, key_ts, key_occ = jax.random.split(jax.random.fold_in(key, i), 3)
        ts = rendering.sample_ts(key_ts, cfg.n_rays, cfg.render)

        update_color = _branch_update(i, cfg.f_color)
        update_density = _branch_update(i, cfg.f_density)
        freeze_color = (not update_color) and field_cfg.decomposed
        freeze_density = not update_density

        want = _partition_members(trainers, cfg.use_occupancy, occ_updates)
        if [p[0] for p in want] != [(g.use_bits, g.budget) for g in groups] or \
           [p[1] for p in want] != [g.members for g in groups]:
            member_state = {}
            for g in groups:
                for k in g.members:
                    member_state[k] = (
                        g.member_tree(g.params, k), g.member_tree(g.opt_state, k),
                        g.member_tree(g.ema, k), g.member_tree(g.occ_step, k),
                    )
            groups = build_groups(want, member_state)

        where = [None] * m  # member -> (group, row) for this iteration
        obs_on = obs_trace.enabled()
        for g in groups:
            batch = g.sample(samplers, key_batch, cfg.n_rays)
            if obs_on:
                # compile/execute split: a step variant's first-ever call is
                # the one that traces + compiles it (its cache key appears on
                # that call — `step_variant_cached`/`step_cache_keys` is the
                # observable).  The whole probe sits behind the knob so the
                # disabled hot loop never hashes a config tuple.
                fresh = not step_variant_cached(
                    field_cfg, cfg, freeze_color, freeze_density,
                    g.budget, g.use_bits, len(g.members))
                span = obs_trace.span(
                    "trainer/step_compile" if fresh else "trainer/step",
                    cat="trainer",
                    args={"step": int(i), "cohort": len(g.members),
                          "budget": g.budget, "use_bits": g.use_bits})
            else:
                span = obs_trace.NULL
            fn = cohort_step_fn(field_cfg, cfg, freeze_color, freeze_density,
                                g.budget, g.use_bits, len(g.members))
            with span:
                g.params, g.opt_state, loss, aux = fn(
                    g.params, g.opt_state, batch, ts, g.ema
                )
            g.last_aux = aux
            g.last_loss = loss
            for r, k in enumerate(g.members):
                where[k] = (g, r)
        if obs_on:
            obs_metrics.counter("trainer.steps").inc(m)
            obs_metrics.gauge("trainer.cohort_size").set(m)
            obs_metrics.gauge("trainer.cohort_groups").set(len(groups))
        # one stacked (M,) overflow entry per iteration (the single-group
        # common case appends the step's own aux with no regather)
        if len(groups) == 1:
            ov = groups[0].last_aux["overflow"]
        else:
            ov = jnp.stack([where[k][0].last_aux["overflow"][where[k][1]]
                            for k in range(m)])
        overflow_accum.append(ov)
        if window is not None:
            window.append(ov)
            del window[: -cfg.occ.update_interval]
        else:
            for k in range(m):
                trainers[k]._overflow_window.append(ov[k])
                del trainers[k]._overflow_window[: -cfg.occ.update_interval]

        if cfg.use_occupancy and i >= cfg.occ.warmup_steps and \
                (i + 1) % cfg.occ.update_interval == 0:
            # overflow since the last update, summed per member (one host
            # sync): overflow means the live set outgrew the bucket between
            # measurements — widen beyond the measurement so the next bucket
            # has room.  The window spans train()/cohort calls (time-sliced
            # sessions see the same history as one long sequential run).
            if window is not None:
                recent_sums = window_sums(window[-cfg.occ.update_interval:])
            for g in groups:
                upd = occ_update_fn(field_cfg, cfg.occ, len(g.members))
                with obs_trace.span("trainer/occ_update", cat="trainer",
                                    args={"step": int(i),
                                          "cohort": len(g.members)}):
                    new_occ = upd(g.params, g.ema, g.occ_step, key_occ)
                g.ema, g.occ_step = new_occ.density_ema, new_occ.step
                # re-measure the batch live fraction at the occupancy cadence
                # (one host sync per update, not per step) to size the budget
                if g.use_bits:
                    live = np.asarray(g.last_aux["live_fraction"])
                for r, k in enumerate(g.members):
                    occ_updates[k] += 1
                    if g.use_bits:
                        measured = float(live[r])
                        # consider every step since the last update, not just
                        # this one — per-step live counts fluctuate with
                        # stratified ts
                        if window is not None:
                            overflowed = int(recent_sums[k]) > 0
                        else:
                            recent = trainers[k]._overflow_window[-cfg.occ.update_interval:]
                            overflowed = bool(recent) and int(jnp.sum(jnp.stack(recent))) > 0
                        if overflowed:
                            measured = min(1.0, measured * 2.0)
                        trainers[k]._live_frac = measured

        if (local_i + 1) % log_every == 0 or local_i == iters - 1:
            wall = obs_trace.clock() - t0
            for g in groups:
                loss_h = np.asarray(g.last_loss)
                live_h = np.asarray(g.last_aux["live_fraction"])
                pts_h = np.asarray(g.last_aux["points_queried"])
                ov_h = np.asarray(g.last_aux["overflow"])
                for r, k in enumerate(g.members):
                    h = histories[k]
                    h["step"].append(i + 1)
                    h["loss"].append(float(loss_h[r]))
                    h["live_fraction"].append(float(live_h[r]))
                    h["points_queried"].append(int(pts_h[r]))
                    h["overflow"].append(int(ov_h[r]))
                    h["wall_s"].append(wall)
                    if callback is not None:
                        callback(i + 1, g.member_tree(g.params, k), h)
                if obs_on:
                    # strays folded into the registry at the log cadence —
                    # these host syncs already happen for the history above,
                    # so the metrics plane adds no extra device round-trips.
                    # Gauges carry last-step values; per-interval totals stay
                    # in the returned history (overflow_total/overflow_steps).
                    obs_metrics.gauge("trainer.live_fraction").set(
                        float(live_h[-1]))
                    obs_metrics.gauge("trainer.loss").set(float(loss_h[-1]))
                    obs_metrics.gauge("trainer.points_per_step").set(
                        int(np.sum(pts_h)))
                    obs_metrics.gauge("trainer.overflow_last_step").set(
                        int(np.sum(ov_h)))

    new_states = [None] * m
    for g in groups:
        for k in g.members:
            new_states[k] = TrainState(
                g.member_tree(g.params, k),
                g.member_tree(g.opt_state, k),
                occupancy.OccupancyState(
                    g.member_tree(g.ema, k), g.member_tree(g.occ_step, k)
                ),
                step0 + iters,
            )
    if overflow_accum:
        all_overflow = jnp.stack(overflow_accum)          # (iters, M)
        totals = np.asarray(jnp.sum(all_overflow, axis=0))
        steps_ = np.asarray(jnp.sum(all_overflow > 0, axis=0))
    else:
        totals = steps_ = np.zeros((m,), np.int64)
    for k, h in enumerate(histories):
        h["overflow_total"] = int(totals[k])
        h["overflow_steps"] = int(steps_[k])
    if window is not None:
        # hand each trainer back its per-member rolling window (one sync);
        # plain ints sum identically, so suspend/resume and later singleton
        # train() calls see exactly the sequential-path history
        tail = np.asarray(jnp.stack(window)) if window else \
            np.zeros((0, m), np.int64)
        for k, tr in enumerate(trainers):
            tr._overflow_window = [int(v) for v in tail[:, k]]
    return new_states, histories
