"""Reconstruction loss (paper Eq. 2) and PSNR."""
from __future__ import annotations

import jax.numpy as jnp


def mse(pred: jnp.ndarray, gt: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(jnp.square(pred.astype(jnp.float32) - gt.astype(jnp.float32)))


def psnr_from_mse(m: jnp.ndarray) -> jnp.ndarray:
    return -10.0 * jnp.log10(jnp.maximum(m, 1e-10))


def psnr(pred: jnp.ndarray, gt: jnp.ndarray) -> jnp.ndarray:
    return psnr_from_mse(mse(pred, gt))
