"""Rays, sampling, and differentiable volume rendering (paper Steps 1-4).

Scene convention: contents live inside an axis-aligned box `aabb` (default
[-1.5, 1.5]^3); sample positions are normalized to [0,1)^3 before hitting the
hash grids.  Rendering composes with the volume_render kernel stack.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class RenderConfig:
    n_samples: int = 48
    near: float = 2.0
    far: float = 6.0
    aabb_min: float = -1.5
    aabb_max: float = 1.5
    white_background: bool = True
    stratified: bool = True


class RayBatch(NamedTuple):
    origins: jnp.ndarray    # (B, 3)
    dirs: jnp.ndarray       # (B, 3) unit norm
    rgb_gt: jnp.ndarray     # (B, 3) ground-truth pixel colors (training only)


# --- cameras -----------------------------------------------------------------

def look_at_pose(eye: np.ndarray, target: np.ndarray, up=(0.0, 0.0, 1.0)) -> np.ndarray:
    """OpenGL-style camera-to-world (3, 4): columns = [right, up, -forward | eye]."""
    eye = np.asarray(eye, np.float32)
    forward = target - eye
    forward = forward / np.linalg.norm(forward)
    right = np.cross(forward, np.asarray(up, np.float32))
    right = right / np.linalg.norm(right)
    true_up = np.cross(right, forward)
    return np.stack([right, true_up, -forward, eye], axis=1).astype(np.float32)


def sphere_poses(n_views: int, radius: float = 4.0, elevation_deg: float = 30.0, seed: int = 0) -> np.ndarray:
    """(V, 3, 4) poses on a view sphere looking at the origin (NeRF-Synthetic style)."""
    rng = np.random.default_rng(seed)
    poses = []
    for i in range(n_views):
        az = 2 * np.pi * i / n_views + rng.uniform(0, 0.1)
        el = np.deg2rad(elevation_deg + rng.uniform(-12, 12))
        eye = radius * np.array(
            [np.cos(az) * np.cos(el), np.sin(az) * np.cos(el), np.sin(el)], np.float32
        )
        poses.append(look_at_pose(eye, np.zeros(3, np.float32)))
    return np.stack(poses)


def pixel_rays(pose: jnp.ndarray, px: jnp.ndarray, py: jnp.ndarray, h: int, w: int, focal: float):
    """Rays through pixel centers. pose (3,4); px, py (B,) -> origins, dirs (B,3)."""
    x = (px.astype(jnp.float32) + 0.5 - w * 0.5) / focal
    y = -(py.astype(jnp.float32) + 0.5 - h * 0.5) / focal
    dirs_cam = jnp.stack([x, y, -jnp.ones_like(x)], axis=-1)  # (B, 3)
    dirs = dirs_cam @ pose[:3, :3].T
    dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
    origins = jnp.broadcast_to(pose[:3, 3], dirs.shape)
    return origins, dirs


# --- sampling ----------------------------------------------------------------

def sample_ts(rng: jax.Array | None, n_rays: int, cfg: RenderConfig) -> jnp.ndarray:
    """Stratified sample distances (B, S) in [near, far].

    One sample per uniform stratum of width (far-near)/S; with rng=None the
    stratum midpoints (deterministic eval path).  These are the *uniform*
    sampler's positions — the pipeline's redistribute stage (2b) consumes
    their in-stratum jitter to place its adaptive samples, so the two
    samplers share one rng stream and stay reproducible together.
    """
    s = cfg.n_samples
    edges = jnp.linspace(cfg.near, cfg.far, s + 1)
    lo, hi = edges[:-1], edges[1:]
    if cfg.stratified and rng is not None:
        u = jax.random.uniform(rng, (n_rays, s))
    else:
        u = jnp.full((n_rays, s), 0.5)
    return lo[None, :] + u * (hi - lo)[None, :]


def normalize_points(points: jnp.ndarray, cfg: RenderConfig) -> jnp.ndarray:
    """World -> [0,1)^3 grid coords, clipped to the box."""
    unit = (points - cfg.aabb_min) / (cfg.aabb_max - cfg.aabb_min)
    return jnp.clip(unit, 0.0, 1.0 - 1e-6)


def inside_aabb(points: jnp.ndarray, cfg: RenderConfig) -> jnp.ndarray:
    return jnp.all((points >= cfg.aabb_min) & (points <= cfg.aabb_max), axis=-1)


# --- rendering ---------------------------------------------------------------

def render_rays(
    field,
    params: dict,
    origins: jnp.ndarray,
    dirs: jnp.ndarray,
    ts: jnp.ndarray,
    cfg: RenderConfig,
    occupancy_mask_fn=None,
):
    """Differentiable render. origins/dirs (B,3), ts (B,S) -> dict of outputs.

    Compatibility wrapper over the staged `pipeline.RenderPipeline` dense
    path (query all points, mask sigma).  occupancy_mask_fn: optional
    (points_unit (N,3) -> bool (N,)) culling hook; masked samples contribute
    zero density (paper/NGP empty-space skipping).  New code should use
    RenderPipeline directly — with a point budget it skips the culled
    queries instead of just zeroing them.
    """
    from .pipeline import RenderPipeline  # late import: pipeline imports us

    return RenderPipeline(field, cfg)(
        params, origins, dirs, ts, mask_fn=occupancy_mask_fn
    )
