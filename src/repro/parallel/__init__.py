from .sharding import ShardingPolicy, param_specs, batch_specs, to_named, activation_spec  # noqa: F401
from .collectives import compressed_psum_mean, compressed_grad_sync, init_error_state  # noqa: F401
