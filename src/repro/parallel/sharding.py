"""Partition rules: params / batches / caches -> PartitionSpec trees.

Axes: ('pod',) 'data', 'model'.  Policy:
  * TP over 'model' — attention heads, FFN hidden, vocab, SSM inner channels,
    MoE experts (EP; matches the shard_map specs inside models.moe).
  * FSDP over 'data' for large archs — the largest remaining dim of each
    big 2+-D leaf is sharded over 'data'; XLA all-gathers per scanned layer.
  * DP over ('pod','data') for the batch; 'pod' composes with 'data' so the
    cross-pod hop is only the gradient all-reduce.

Rules match on the param path (string fragments) + leaf rank, so they survive
arbitrary nesting (scanned segments add a leading layer axis).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig


@dataclass(frozen=True)
class ShardingPolicy:
    """Two families (EXPERIMENTS.md §Perf):

    * TP policy (tp=True): Megatron-style — heads/ffn/vocab over 'model',
      batch over ('pod','data'), optional FSDP over 'data'.  Best for decode
      (params+cache sharded at tiny per-step compute).
    * FSDP-pure policy (tp=False, fsdp=True): ZeRO-3 — batch over
      ('data','model') [+'pod' as an extra param shard], every large param
      dim sharded over the widest divisible axis combo.  Beats TP for
      train/prefill at large token counts: per-layer param all-gathers cost
      ~3x params/device/step, while TP pays ~2 activation all-reduces per
      layer per pass (tokens x d_model each) — 10-20x more at batch 256x4k.
    """
    tp: bool = True
    fsdp: bool = False
    dp_axes: tuple = ("pod", "data")           # batch-sharding axes
    fsdp_axes: tuple = ("data",)               # param-sharding axes (widest first)
    model_axis: str = "model"


# the optimized train/prefill policy (see EXPERIMENTS.md §Perf iteration 1-2)
FSDP_PURE = ShardingPolicy(
    tp=False, fsdp=True,
    dp_axes=("pod", "data", "model"),   # batch greedily, spill to seq
    fsdp_axes=("pod", "data", "model"),
)


def dp(mesh, policy: ShardingPolicy):
    return tuple(a for a in policy.dp_axes if a in mesh.shape)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _divisible(shape, axis, n) -> bool:
    return n > 0 and shape[axis] % n == 0 and shape[axis] >= n


# name-fragment -> (axis-from-the-right to shard over 'model')
# (negative index into the shape tuple; layer stacking prepends dims so
# counting from the right is stable)
_MODEL_AXIS_RULES = [
    ("attn/wq_b", -2), ("attn/wkv_b", -2),          # MLA head dims
    ("attn/wq_a", None), ("attn/wkv_a", None),
    ("attn/q_a_norm", None), ("attn/kv_a_norm", None),
    ("attn/wq", -2), ("attn/wk", -2), ("attn/wv", -2), ("attn/wo", -3),
    ("attn/bq", -2), ("attn/bk", -2), ("attn/bv", -2),
    ("attn/q_norm", None), ("attn/k_norm", None),
    ("xattn/wq", -2), ("xattn/wk", -2), ("xattn/wv", -2), ("xattn/wo", -3),
    ("xattn/bq", -2), ("xattn/bk", -2), ("xattn/bv", -2),
    ("moe/router", None), ("moe/router_bias", None),
    ("moe/w_gate", -3), ("moe/w_up", -3), ("moe/w_down", -3),  # expert axis (EP)
    ("shared/w_gate", -1), ("shared/w_up", -1), ("shared/w_down", -2),
    ("ffn/w_gate", -1), ("ffn/w_up", -1), ("ffn/w_down", -2),
    ("ffn/b_up", -1), ("ffn/b_down", None),
    ("ssm/in_proj", -1), ("ssm/conv_w", -1), ("ssm/conv_b", -1),
    ("ssm/x_proj", -2), ("ssm/dt_proj", -1), ("ssm/dt_bias", -1),
    ("ssm/A_log", None), ("ssm/D", None), ("ssm/norm", -1),
    ("ssm/out_proj", -2),
    ("mtp/proj", -1),
    ("embed", -2), ("lm_head", -1),
]
# ssm A_log/D are per-channel ((di, n) / (P,)); sharding them must follow
# in_proj's channel split — handled dynamically below for mamba2 head-count
# divisibility; mamba1's (di, n) shards di at axis -2.
_SSM_CHANNEL_RULES = {"ssm/A_log": True, "ssm/D": True}


def _expert_axes(cfg, mesh):
    if cfg.moe is None or cfg.moe.ep_axis is None:
        return None
    axes = cfg.moe.ep_axes if hasattr(cfg.moe, "ep_axes") else (cfg.moe.ep_axis,)
    axes = tuple(a for a in axes if a in mesh.shape)
    return axes or None


def param_specs(cfg: ModelConfig, abstract_params, mesh, policy: ShardingPolicy):
    """PartitionSpec tree matching the params pytree."""
    n_model = mesh.shape.get(policy.model_axis, 1)
    ep_axes = _expert_axes(cfg, mesh)
    n_ep = int(np.prod([mesh.shape[a] for a in ep_axes])) if ep_axes else 1

    # fsdp axis combos, widest first: e.g. ('pod','data','model') -> also try
    # ('data','model'), ('data',), ('model',)
    fsdp_avail = tuple(a for a in policy.fsdp_axes if a in mesh.shape)
    fsdp_combos = []
    for k in range(len(fsdp_avail), 0, -1):
        combo = fsdp_avail[-k:]
        fsdp_combos.append(combo)
    seen = set()
    fsdp_combos = [c for c in fsdp_combos if not (c in seen or seen.add(c))]

    def spec_for(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        rank = len(shape)
        spec = [None] * rank

        # MoE expert leaves: always EP-shard the expert axis (independent of
        # the tp flag — matches the shard_map specs in models.moe)
        is_expert = any(f"moe/{w}" in name for w in ("w_gate", "w_up", "w_down"))
        if is_expert and ep_axes and _divisible(shape, -3, n_ep):
            spec[rank - 3] = ep_axes if len(ep_axes) > 1 else ep_axes[0]

        # Embedding / LM head: shard ONLY the vocab dim (over the widest
        # dividing axis combo).  Generic FSDP must never shard their d_model
        # dim: a contraction-dim shard turns the logits matmul into a
        # (tokens x vocab) psum — catastrophic (§Perf iteration 2 post-mortem).
        if name.endswith("embed") or name.endswith("lm_head"):
            v_ax = -2 if name.endswith("embed") else -1
            if policy.tp:
                combos_v = [(policy.model_axis,)] + fsdp_combos
            else:
                combos_v = fsdp_combos + [(policy.model_axis,)]
            for combo in combos_v:
                n_c = int(np.prod([mesh.shape.get(a, 1) for a in combo]))
                if _divisible(shape, v_ax, n_c):
                    spec[rank + v_ax] = combo if len(combo) > 1 else combo[0]
                    break
            return P(*spec)

        if policy.tp and n_model > 1 and not is_expert:
            hit = None
            for frag, ax in _MODEL_AXIS_RULES:
                if frag in name:
                    hit = ax
                    break
            if name.endswith("ssm/A_log") or name.endswith("ssm/D") or "ssm/dt_bias" in name:
                # per-channel vectors: (di,·)/(P,) — shard the channel dim
                ax = -2 if (name.endswith("A_log") and rank >= 2) else -1
                hit = ax
            if hit is not None and _divisible(shape, hit, n_model):
                spec[rank + hit] = policy.model_axis

        if policy.fsdp and rank >= 2 and int(np.prod(shape)) >= 1 << 16:
            # shard the largest remaining dim over the widest divisible combo
            for combo in fsdp_combos:
                taken = {a for s in spec if s for a in (s if isinstance(s, tuple) else (s,))}
                if any(a in taken for a in combo):
                    continue
                n_c = int(np.prod([mesh.shape[a] for a in combo]))
                cands = [i for i in range(rank)
                         if spec[i] is None and shape[i] % n_c == 0 and shape[i] >= n_c]
                if cands:
                    best = max(cands, key=lambda i: shape[i])
                    spec[best] = combo if len(combo) > 1 else combo[0]
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, abstract_params)


def _split_batch_seq(b_size: int, s_size: int, axes: tuple, mesh):
    """Greedy (batch-axes, seq-axes) split: the largest prefix of `axes`
    whose product divides the batch shards the batch; remaining axes shard
    the sequence if divisible (FSDP-pure prefill: B=32 over 'data', S over
    'model')."""
    for k in range(len(axes), -1, -1):
        ax_b = axes[:k]
        n_b = int(np.prod([mesh.shape[a] for a in ax_b])) if ax_b else 1
        if b_size % n_b == 0:
            rest = axes[k:]
            n_s = int(np.prod([mesh.shape[a] for a in rest])) if rest else 1
            ax_s = rest if (rest and s_size % n_s == 0) else ()
            return (ax_b or None), (ax_s or None)
    return None, None


def batch_specs(cfg: ModelConfig, batch, mesh, policy: ShardingPolicy):
    """PartitionSpec tree for a train/prefill/decode batch dict."""
    dpa = dp(mesh, policy)
    n_model = mesh.shape.get(policy.model_axis, 1)
    n_dp = int(np.prod([mesh.shape[a] for a in dpa])) if dpa else 1

    def spec_for(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if "caches" in name:
            return _cache_spec(name, shape, dpa, n_model, n_dp, policy)
        if name.endswith("positions") and len(shape) == 3:  # (3, B, S) mrope
            ax_b, ax_s = _split_batch_seq(shape[1], shape[2], dpa, mesh)
            return P(None, ax_b, ax_s)
        if (name.endswith("tokens") or "embeds" in name or "encoder_out" in name) \
                and len(shape) >= 2:
            ax_b, ax_s = _split_batch_seq(shape[0], shape[1], dpa, mesh)
            return P(ax_b, ax_s, *([None] * (len(shape) - 2)))
        if name.endswith("tokens") or name.endswith("pos"):
            ax_b, _ = _split_batch_seq(shape[0], 1, dpa, mesh)
            return P(ax_b, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_for, batch)


def _cache_spec(name, shape, dpa, n_model, n_dp, policy: ShardingPolicy):
    """Decode-cache leaves.  Layer-stacked: leading dim = layer.

    kv cache  (L, B, S, K, hd): B->dp if divisible; K->model if divisible,
              else S->model (flash-decode style sequence sharding).
    mla cache (L, B, S, lora):  B->dp, S->model.
    ssm state (L, B, ...channels): B->dp, biggest channel dim -> model.
    """
    rank = len(shape)
    spec = [None] * rank
    m = policy.model_axis
    if rank >= 2 and dpa and shape[1] % max(n_dp, 1) == 0:
        spec[1] = dpa
    batch_unsharded = spec[1] is None
    if rank == 5:  # (L, B, S, K, hd)
        if shape[3] % n_model == 0 and n_model > 1:
            spec[3] = m
        elif shape[2] % n_model == 0:
            spec[2] = m
        if batch_unsharded and dpa and spec[2] is None and shape[2] % max(n_dp, 1) == 0:
            spec[2] = dpa  # long-context batch=1: shard seq over data too
    elif rank == 4 and ("c_kv" in name or "k_rope" in name):
        if shape[2] % n_model == 0 and n_model > 1:
            spec[2] = m
        if batch_unsharded and dpa and shape[2] % max(n_dp, 1) == 0 and spec[2] == m:
            pass
    elif rank >= 3:  # ssm states / conv tails: shard biggest trailing dim
        cands = [i for i in range(2, rank) if shape[i] % n_model == 0 and shape[i] >= n_model]
        if cands and n_model > 1:
            spec[max(cands, key=lambda i: shape[i])] = m
    return P(*spec)


def to_named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def activation_spec(mesh, policy: ShardingPolicy):
    """(B, S, D) activations: batch over dp, rest replicated."""
    return P(dp(mesh, policy), None, None)
