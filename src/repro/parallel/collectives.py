"""Explicit collectives: int8 error-feedback compressed gradient sync.

The cross-pod gradient all-reduce is the only DCN hop in the production mesh
(DESIGN.md §6).  `compressed_psum_mean` implements a quantized ring exchange:

    1. residual-corrected gradient  g' = g + e        (error feedback)
    2. per-leaf symmetric int8 quantization           (scale = max|g'|/127)
    3. reduce-scatter via int8 all_to_all             (wire: S/4 vs f32)
    4. local dequant-sum of the owned chunk
    5. int8 all_gather of the reduced chunks          (wire: S/4)
    6. new residual e = g' - dequant(quant(g'))

Wire bytes: 2·(n-1)/n·S_int8 = ~4x less than an f32 ring all-reduce.  Error
feedback keeps the bias bounded (the classic 1-bit-Adam/PowerSGD argument) —
`tests/test_collectives.py` checks convergence against exact psum.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp


def _quantize(g: jnp.ndarray):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(g: jnp.ndarray, err: jnp.ndarray, axis_name: str):
    """Mean-psum of g over axis_name with int8 wire format + error feedback.

    Must run inside shard_map/pmap over `axis_name`.  Returns (mean_g, new_err).
    """
    n = jax.lax.axis_size(axis_name)
    orig_shape = g.shape
    g = g.astype(jnp.float32) + err.astype(jnp.float32)

    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat_p = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)]) if pad else flat

    q, scale = _quantize(flat_p)
    new_err = (flat_p - _dequantize(q, scale))[: flat.shape[0]].reshape(orig_shape)

    # reduce-scatter: all_to_all my chunk-grid, each rank sums its own chunk
    chunks = q.reshape(n, -1)  # (n, S/n) int8
    recv = jax.lax.all_to_all(chunks, axis_name, split_axis=0, concat_axis=0, tiled=True)
    scales = jax.lax.all_gather(scale, axis_name)  # (n,) f32 — negligible wire
    local_sum = jnp.sum(
        recv.reshape(n, -1).astype(jnp.float32) * scales[:, None], axis=0
    )  # (S/n,)

    # re-quantize the reduced chunk, all-gather int8
    q2, scale2 = _quantize(local_sum)
    gq = jax.lax.all_gather(q2, axis_name)            # (n, S/n) int8
    gs = jax.lax.all_gather(scale2, axis_name)        # (n,)
    summed = (gq.astype(jnp.float32) * gs[:, None]).reshape(-1)[: flat.shape[0]]
    return (summed / n).reshape(orig_shape), new_err


def compressed_grad_sync(grads: Any, err_state: Any, mesh, axis_name: str = "pod"):
    """Tree-wise compressed sync over one mesh axis (the DCN 'pod' hop).

    grads must already be consistent within the other axes (pjit handles the
    intra-pod reduction); this wraps only the cross-pod mean.
    """
    from jax.sharding import PartitionSpec as P

    def mapped(g, e):
        return jax.shard_map(
            lambda gg, ee: compressed_psum_mean(gg, ee, axis_name),
            mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False,
        )(g, e)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    outs = [mapped(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_e = treedef.unflatten([o[1] for o in outs])
    return new_g, new_e


def init_error_state(params: Any):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
