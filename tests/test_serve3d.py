"""serve3d: session lifecycle, scheduling parity, snapshots, batched renders."""
import numpy as np
import jax
import pytest

from repro.core import Field, FieldConfig, Instant3DTrainer, TrainerConfig, occupancy
from repro.core import trainer as trainer_mod
from repro.core.rendering import RenderConfig
from repro.data import build_dataset, RaySampler
from repro.serve3d import (
    ACTIVE, DONE, PENDING, SUSPENDED,
    ReconstructionService, SceneSession, SessionScheduler, SnapshotStore,
)

RCFG = RenderConfig(n_samples=8)
FIELD_CFG = FieldConfig(n_levels=2, max_resolution=32, log2_table_density=10,
                        log2_table_color=8, hidden=16)
OCFG = occupancy.OccupancyConfig(resolution=16, update_interval=4, warmup_steps=2)
TRAIN_CFG = TrainerConfig(n_rays=64, render=RCFG, occ=OCFG, eval_chunk=144)


@pytest.fixture(scope="module")
def datasets():
    out = []
    for seed in range(2):
        _scene, ds = build_dataset(seed=seed, n_views=2, h=12, w=12,
                                   cfg=RCFG, gt_samples=24)
        out.append(ds)
    return out


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


# ---- SceneSession lifecycle ----


def test_session_suspend_snapshot_resume_bit_identical(datasets, tmp_path):
    """Checkpoint round-trip of decomposed field params + occupancy EMA
    through suspend -> snapshot -> resume: renders must be bit-identical."""
    ds = datasets[0]
    sess = SceneSession("s0", ds, FIELD_CFG, TRAIN_CFG, target_iters=32,
                        ckpt_dir=str(tmp_path / "ckpt"))
    sess.start()
    sess.run_slice(12)  # past warmup: occupancy EMA has folded real updates
    assert int(sess.state.occ_state.step) > 0

    img_before, dep_before = sess.trainer.render_image(
        sess.state.params, ds.poses[0], ds)
    ema_before = np.asarray(sess.state.occ_state.density_ema)
    occ_step_before = int(sess.state.occ_state.step)

    sess.suspend(block=True)
    assert sess.status == SUSPENDED and not sess.resident

    # fresh-process path: a brand-new session object restores from disk only
    sess2 = SceneSession("s0", ds, FIELD_CFG, TRAIN_CFG, target_iters=32,
                         ckpt_dir=str(tmp_path / "ckpt"))
    sess2._host_tree = None
    sess2.resume()
    assert sess2.status == ACTIVE and sess2.step == 12

    np.testing.assert_array_equal(
        np.asarray(sess2.state.occ_state.density_ema), ema_before)
    assert int(sess2.state.occ_state.step) == occ_step_before
    img_after, dep_after = sess2.trainer.render_image(
        sess2.state.params, ds.poses[0], ds)
    np.testing.assert_array_equal(img_after, img_before)
    np.testing.assert_array_equal(dep_after, dep_before)

    # and training continues identically to the never-suspended session
    sess3 = SceneSession("s0-ref", ds, FIELD_CFG, TRAIN_CFG, target_iters=32)
    sess3.start()
    sess3.run_slice(12)
    sess2.run_slice(8)
    sess3.run_slice(8)
    assert _leaves_equal(sess2.state.params, sess3.state.params)


def test_interleaved_matches_sequential(datasets):
    """Round-robin time-slicing reproduces sequential single-scene training
    bit-for-bit at equal per-scene iteration counts."""
    svc = ReconstructionService(slice_iters=4)
    for seed, ds in enumerate(datasets):
        svc.submit_scene(ds, FIELD_CFG, TRAIN_CFG, target_iters=16, seed=seed)
    svc.run()

    for seed, ds in enumerate(datasets):
        tr = Instant3DTrainer(Field(FIELD_CFG), TRAIN_CFG)
        st = tr.init(jax.random.PRNGKey(seed))
        st, _ = tr.train(st, RaySampler(ds), iters=16, log_every=16)
        sess = svc.sessions[f"scene-{seed:03d}"]
        assert sess.status == DONE and sess.step == 16
        assert _leaves_equal(st.params, sess.state.params), f"scene {seed}"


def test_scheduler_round_robin_fair(datasets):
    sched = SessionScheduler(slice_iters=4, policy="round_robin")
    sessions = [
        SceneSession(f"s{i}", datasets[i % 2], FIELD_CFG, TRAIN_CFG, target_iters=8)
        for i in range(3)
    ]
    for s in sessions:
        sched.add(s)
    order = [sched.step().session_id for _ in range(6)]
    assert order == ["s0", "s1", "s2", "s0", "s1", "s2"]
    assert sched.all_done
    assert sched.step() is None


def test_scheduler_edf_prefers_urgent(datasets):
    sched = SessionScheduler(slice_iters=4, policy="edf")
    slack = SceneSession("slack", datasets[0], FIELD_CFG, TRAIN_CFG,
                         target_iters=4, deadline=1e6)
    urgent = SceneSession("urgent", datasets[1], FIELD_CFG, TRAIN_CFG,
                          target_iters=4, deadline=1.0)
    sched.add(slack)
    sched.add(urgent)
    assert sched.step().session_id == "urgent"
    assert sched.step().session_id == "slack"


def test_scheduler_edf_admission_order(datasets):
    """With bounded slots, EDF admits the most urgent *queued* session when a
    slot frees — not whichever was submitted first."""
    sched = SessionScheduler(slice_iters=4, policy="edf", max_resident=1)
    first = SceneSession("first", datasets[0], FIELD_CFG, TRAIN_CFG,
                         target_iters=4, deadline=1e6)
    lazy = SceneSession("lazy", datasets[1], FIELD_CFG, TRAIN_CFG,
                        target_iters=4)             # no deadline
    urgent = SceneSession("urgent", datasets[0], FIELD_CFG, TRAIN_CFG,
                          target_iters=4, deadline=1.0)
    for s in (first, lazy, urgent):                 # urgent submitted last
        sched.add(s)
    assert first.status == ACTIVE                   # residents not preempted
    assert sched.step().session_id == "first"       # finishes its 4 iters
    assert urgent.status == ACTIVE and lazy.status == PENDING
    assert sched.step().session_id == "urgent"
    assert sched.step().session_id == "lazy"
    assert sched.all_done


def test_scheduler_slot_reset_admission(datasets):
    """Continuous-batching idiom: with one device slot, the queued session is
    admitted exactly when the resident one finishes."""
    sched = SessionScheduler(slice_iters=4, policy="round_robin", max_resident=1)
    a = SceneSession("a", datasets[0], FIELD_CFG, TRAIN_CFG, target_iters=8)
    b = SceneSession("b", datasets[1], FIELD_CFG, TRAIN_CFG, target_iters=4)
    sched.add(a)
    sched.add(b)
    assert a.status == ACTIVE and b.status == PENDING  # only one slot
    assert sched.step().session_id == "a"
    assert b.status == PENDING                         # a still live
    assert sched.step().session_id == "a"              # a finishes here
    assert a.status == DONE and b.status == ACTIVE     # slot reset -> b admitted
    assert not a.resident                              # device footprint released
    assert a._current_params() is not None             # but still publishable
    assert sched.step().session_id == "b"
    assert sched.all_done


# ---- SnapshotStore ----


def test_snapshot_store_atomic_publish(datasets):
    store = SnapshotStore()
    sess = SceneSession("s0", datasets[0], FIELD_CFG, TRAIN_CFG, target_iters=8)
    sess.start()
    snap1 = sess.publish(store)
    assert (snap1.version, snap1.step) == (1, 0)
    sess.run_slice(4)
    snap2 = sess.publish(store)
    assert (snap2.version, snap2.step) == (2, 4)
    assert store.latest("s0") is snap2           # pointer swap, newest wins
    assert store.latest("missing") is None
    assert store.sessions() == ["s0"]
    # snapshots are host-side copies, decoupled from later training
    assert not _leaves_equal(snap1.params, snap2.params)
    sess.run_slice(4)
    assert store.latest("s0") is snap2           # unaffected until next publish


def test_snapshot_store_persistence_roundtrip(datasets, tmp_path):
    store = SnapshotStore(persist_dir=str(tmp_path))
    sess = SceneSession("sceneX", datasets[0], FIELD_CFG, TRAIN_CFG, target_iters=4)
    sess.start()
    sess.run_slice(4)
    snap = sess.publish(store)
    store.wait()
    from repro.checkpoint import CheckpointManager
    ckpt = CheckpointManager(tmp_path / "sceneX")
    tree, meta = ckpt.restore({"params": snap.params})
    assert meta["version"] == 1 and meta["step"] == 4
    assert _leaves_equal(tree["params"], snap.params)


# ---- RenderService ----


def test_batched_render_matches_render_image(datasets):
    """Coalesced cross-session renders == each session's own render_image
    (on the dense serving path; the redistributed default is covered by
    tests/test_serve3d_cohort.py)."""
    svc = ReconstructionService(slice_iters=4, redistributed_render=False)
    sids = [svc.submit_scene(ds, FIELD_CFG, TRAIN_CFG, target_iters=8, seed=i)
            for i, ds in enumerate(datasets)]
    svc.run()

    for sid, ds in zip(sids, datasets):          # both target the same pose
        svc.request_render(sid, ds.poses[1])
    results = svc.renderer.drain()
    assert [r.session_id for r in results] == sids
    assert svc.renderer.pending == 0

    for r, ds in zip(results, datasets):
        sess = svc.sessions[r.session_id]
        rgb_ref, dep_ref = sess.trainer.render_image(
            sess.state.params, ds.poses[1], ds)
        np.testing.assert_allclose(r.rgb, rgb_ref, atol=1e-5)
        np.testing.assert_allclose(r.depth, dep_ref, atol=1e-5)
        assert r.snapshot_step == 8


def test_render_waits_for_first_snapshot(datasets):
    """Requests against a session that never published stay queued."""
    store = SnapshotStore()
    from repro.serve3d import RenderService
    rs = RenderService(store)
    rs.register_session("s0", FIELD_CFG, RCFG, 12, 12, datasets[0].focal,
                        eval_chunk=144)
    rs.submit("s0", datasets[0].poses[0])
    assert rs.drain() == [] and rs.pending == 1
    sess = SceneSession("s0", datasets[0], FIELD_CFG, TRAIN_CFG, target_iters=4)
    sess.start()
    sess.publish(store)
    results = rs.drain()
    assert len(results) == 1 and rs.pending == 0
    assert results[0].snapshot_version == 1
    with pytest.raises(KeyError):
        rs.submit("unregistered", datasets[0].poses[0])


# ---- eval-render compile cache ----


def test_eval_render_cache_keyed_per_config():
    """Two sessions with the same grids share ONE compiled render fn; a
    different grid size or chunk gets its own entry (no silent sharing)."""
    trainer_mod._EVAL_RENDER_CACHE.clear()
    a = trainer_mod.eval_render_fn(FIELD_CFG, RCFG, 144)
    b = trainer_mod.eval_render_fn(FIELD_CFG, RCFG, 144)
    assert a is b
    bigger = FieldConfig(n_levels=2, max_resolution=32, log2_table_density=12,
                         log2_table_color=8, hidden=16)
    assert trainer_mod.eval_render_fn(bigger, RCFG, 144) is not a
    assert trainer_mod.eval_render_fn(FIELD_CFG, RCFG, 72) is not a
    assert len(trainer_mod._EVAL_RENDER_CACHE) == 3
