"""MoE: EP shard_map path vs dense oracle; SSM: chunked scan vs step recurrence."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _jax_compat import requires_new_sharding_api

from repro.models.config import ModelConfig, MoEConfig, SSMConfig
from repro.models import moe, ssm


def _moe_cfg(n_routed=8, top_k=2, n_shared=1, ep=True):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab=64, dtype="float32",
        moe=MoEConfig(n_routed=n_routed, n_shared=n_shared, top_k=top_k,
                      d_expert_ff=64, ep_axis="model" if ep else None),
    )


@requires_new_sharding_api
def test_moe_ep_matches_dense_single_shard(rng):
    """With model-axis size 1 the EP path must agree with the dense oracle
    exactly (no drops possible)."""
    cfg = _moe_cfg()
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, 32)).astype(np.float32))
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    dense = moe.moe_dense(params, x, cfg)
    ep = moe.moe_ep(params, x, cfg, mesh, capacity_factor=100.0)  # no drops
    np.testing.assert_allclose(np.asarray(ep), np.asarray(dense), atol=1e-4, rtol=1e-4)


@requires_new_sharding_api
def test_moe_decode_path(rng):
    cfg = _moe_cfg()
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 1, 32)).astype(np.float32))
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    dense = moe.moe_dense(params, x, cfg)
    ep = moe.moe_ep(params, x, cfg, mesh)
    np.testing.assert_allclose(np.asarray(ep), np.asarray(dense), atol=1e-4, rtol=1e-4)


def test_router_topk_gates_normalized(rng):
    cfg = _moe_cfg(top_k=3)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(10, 32)).astype(np.float32))
    gates, ids = moe.route(params, x, cfg.moe)
    assert gates.shape == (10, 3) and ids.shape == (10, 3)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    assert (np.asarray(ids) >= 0).all() and (np.asarray(ids) < 8).all()


def _ssm_cfg(kind):
    return ModelConfig(
        name="t", family="ssm", n_layers=1, d_model=16, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=64, dtype="float32",
        ssm=SSMConfig(kind=kind, d_state=8, d_conv=4, expand=2, headdim=8, chunk=4),
    )


@pytest.mark.parametrize("kind", ["mamba1", "mamba2"])
def test_ssm_chunked_equals_tokenwise(kind, rng):
    """Chunked parallel scan over a sequence == feeding tokens one by one
    through the recurrent decode path (state-space correctness)."""
    cfg = _ssm_cfg(kind)
    params = ssm.init_ssm(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 12
    x = jnp.asarray(rng.normal(size=(b, s, 16)).astype(np.float32) * 0.5)

    y_par, state_par = ssm.ssm_block(params, cfg, x)

    state = ssm.init_ssm_state(cfg, b, jnp.float32)
    ys = []
    for t in range(s):
        y_t, state = ssm.ssm_block(params, cfg, x[:, t : t + 1], state)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state_par.h), np.asarray(state.h),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("kind", ["mamba1", "mamba2"])
def test_ssm_state_continuation(kind, rng):
    """Splitting a sequence across two calls with carried state == one call."""
    cfg = _ssm_cfg(kind)
    params = ssm.init_ssm(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 16, 16)).astype(np.float32) * 0.5)
    y_full, _ = ssm.ssm_block(params, cfg, x)
    y1, st = ssm.ssm_block(params, cfg, x[:, :8])
    y2, _ = ssm.ssm_block(params, cfg, x[:, 8:], st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y_full),
        atol=1e-3, rtol=1e-3)
