"""Fused compacted-path kernel: forward/gradient equivalence, Morton order,
presorted BUM backward, pipeline wiring."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import Field, FieldConfig, occupancy
from repro.core.pipeline import RenderPipeline
from repro.core.rendering import RenderConfig, sample_ts
from repro.kernels.hash_encode import ref as he_ref, ops as he_ops
from repro.kernels.fused_path import ref as fp_ref, ops as fp_ops

L, F = 4, 2
TD, TC = 1 << 12, 1 << 10
RES = he_ref.level_resolutions(L, 8, 64)


def _points(rng, n=400, sort=True):
    pts = jnp.asarray(rng.uniform(0, 0.999, (n, 3)).astype(np.float32))
    if sort:
        pts = pts[jnp.argsort(fp_ref.morton_key(pts))]
    return pts


def _tables(rng):
    td = jnp.asarray(rng.normal(size=(L, TD, F)).astype(np.float32) * 0.1)
    tc = jnp.asarray(rng.normal(size=(L, TC, F)).astype(np.float32) * 0.1)
    return td, tc


# ---- Morton keys ----

def test_morton_key_interleave():
    """Key of quantized (x,y,z) == python-int bit interleave."""
    bits = fp_ref.MORTON_BITS
    n = 1 << bits
    pts = np.array([[0.0, 0.0, 0.0], [0.5, 0.25, 0.75], [0.999, 0.001, 0.4]],
                   np.float32)
    got = np.asarray(fp_ref.morton_key(jnp.asarray(pts)))
    for p, k in zip(pts, got):
        q = np.clip(np.floor(p * n), 0, n - 1).astype(np.uint64)
        expect = 0
        for b in range(bits):
            for d in range(3):
                expect |= ((int(q[d]) >> b) & 1) << (3 * b + d)
        assert int(k) == expect


def test_morton_sort_groups_cells(rng):
    """After Morton sort, points sharing a fine grid cell are contiguous."""
    pts = _points(rng, 512, sort=True)
    cell = np.asarray(jnp.floor(pts * 16).astype(np.int32))
    key = cell[:, 0] + 16 * cell[:, 1] + 256 * cell[:, 2]
    # each cell id appears in exactly one contiguous run
    changes = (np.diff(key) != 0).sum()
    assert changes + 1 == len(np.unique(key))


# ---- forward equivalence ----

def test_fused_forward_bit_matches_ref(rng):
    td, tc = _tables(rng)
    pts = _points(rng)
    enc = fp_ops.make_fused_encode(RES, (TD, TC), F, backend="ref")
    fd, fc = enc(pts, td, tc)
    np.testing.assert_array_equal(np.asarray(fd), np.asarray(he_ref.hash_encode(pts, td, RES)))
    np.testing.assert_array_equal(np.asarray(fc), np.asarray(he_ref.hash_encode(pts, tc, RES)))


def test_fused_forward_pallas_matches_ref(rng):
    td, tc = _tables(rng)
    pts = _points(rng, n=513)  # non-multiple of block => sentinel padding
    enc = fp_ops.make_fused_encode(RES, (TD, TC), F, backend="pallas-interpret",
                                   block_points=256)
    fd, fc = enc(pts, td, tc)
    np.testing.assert_allclose(np.asarray(fd), np.asarray(he_ref.hash_encode(pts, td, RES)),
                               atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(fc), np.asarray(he_ref.hash_encode(pts, tc, RES)),
                               atol=1e-6, rtol=1e-6)


# ---- gradient equivalence (satellite: fused vs ref encode + oracle) ----

@pytest.mark.parametrize("merged", [True, False])
def test_fused_table_grads_match_unfused(merged, rng):
    """Table grads must be bit-identical to the unfused merged backward: the
    stable argsort the fused forward stashes is exactly the permutation the
    unfused backward's merged_scatter_add would compute."""
    td, tc = _tables(rng)
    pts = _points(rng)
    enc = fp_ops.make_fused_encode(RES, (TD, TC), F, backend="ref", merged_backward=merged)
    enc_d = he_ops.make_hash_encode(RES, TD, F, backend="ref", merged_backward=merged)
    enc_c = he_ops.make_hash_encode(RES, TC, F, backend="ref", merged_backward=merged)

    def loss_fused(a, b):
        fd, fc = enc(pts, a, b)
        return (fd ** 2).sum() + (fc * 1.7).sum()

    def loss_unfused(a, b):
        return (enc_d(pts, a) ** 2).sum() + (enc_c(pts, b) * 1.7).sum()

    gf = jax.jit(jax.grad(loss_fused, argnums=(0, 1)))(td, tc)
    gu = jax.jit(jax.grad(loss_unfused, argnums=(0, 1)))(td, tc)
    if merged:
        np.testing.assert_array_equal(np.asarray(gf[0]), np.asarray(gu[0]))
        np.testing.assert_array_equal(np.asarray(gf[1]), np.asarray(gu[1]))
    else:
        # unmerged scatter accumulates duplicates in a different order
        np.testing.assert_allclose(np.asarray(gf[0]), np.asarray(gu[0]), atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(gf[1]), np.asarray(gu[1]), atol=1e-4, rtol=1e-4)


def test_fused_table_grads_match_autodiff_oracle(rng):
    """Against the naive duplicate scatter-add oracle (hash_encode.ref)."""
    td, tc = _tables(rng)
    pts = _points(rng)
    enc = fp_ops.make_fused_encode(RES, (TD, TC), F, backend="ref")
    g = jax.grad(lambda a: (enc(pts, a, tc)[0] ** 2).sum())(td)
    g_oracle = jax.grad(lambda a: (he_ref.hash_encode(pts, a, RES) ** 2).sum())(td)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_oracle), atol=1e-4, rtol=1e-4)


def test_fused_field_query_grads_match(rng):
    """Full field: query_fused vs query on a random compacted batch — forward
    <=1e-5 (bit-equal on ref), table grads bit-comparable, MLP grads tight."""
    cfg = FieldConfig(n_levels=L, max_resolution=64, log2_table_density=12,
                      log2_table_color=10)
    field = Field(cfg)
    params = field.init(jax.random.PRNGKey(0))
    pts = _points(rng, 300)
    dirs = jnp.asarray(rng.normal(size=(300, 3)).astype(np.float32))
    dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
    target = jnp.asarray(rng.uniform(0, 1, (300, 3)).astype(np.float32))

    def loss(p, fused):
        q = field.query_fused if fused else field.query
        sigma, rgb = q(p, pts, dirs)
        return jnp.mean((rgb - target) ** 2) + jnp.mean(sigma) * 1e-3

    sf, su = loss(params, True), loss(params, False)
    np.testing.assert_allclose(float(sf), float(su), atol=1e-7)
    gf = jax.jit(lambda p: jax.grad(loss)(p, True))(params)
    gu = jax.jit(lambda p: jax.grad(loss)(p, False))(params)
    for grid in ("density_grid", "color_grid"):
        np.testing.assert_array_equal(np.asarray(gf[grid]), np.asarray(gu[grid]),
                                      err_msg=f"{grid} grads diverge")
    for mlp in ("density_mlp", "color_mlp"):
        for k in gf[mlp]:
            np.testing.assert_allclose(np.asarray(gf[mlp][k]), np.asarray(gu[mlp][k]),
                                       atol=1e-6, rtol=1e-6, err_msg=f"{mlp}.{k}")


def test_fused_non_decomposed_field(rng):
    """NGP baseline (single grid) also routes through the fused encode."""
    cfg = FieldConfig(n_levels=L, max_resolution=64, log2_table_density=12,
                      decomposed=False)
    field = Field(cfg)
    params = field.init(jax.random.PRNGKey(0))
    pts = _points(rng, 128)
    dirs = jnp.asarray(rng.normal(size=(128, 3)).astype(np.float32))
    dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
    sf, cf = field.query_fused(params, pts, dirs)
    su, cu = field.query(params, pts, dirs)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(su), atol=1e-6)
    np.testing.assert_allclose(np.asarray(cf), np.asarray(cu), atol=1e-6)


# ---- pipeline wiring ----

def test_pipeline_fused_matches_unfused(rng):
    """Compacted render + gradients identical with the fused shade stage."""
    fcfg = FieldConfig(n_levels=L, max_resolution=64, log2_table_density=12,
                       log2_table_color=10)
    rcfg = RenderConfig(n_samples=16)
    field = Field(fcfg)
    params = field.init(jax.random.PRNGKey(0))
    b = 32
    origins = jnp.asarray(rng.uniform(-0.5, 0.5, (b, 3)).astype(np.float32))
    origins = origins.at[:, 2].set(4.0)
    dirs = jnp.asarray(rng.normal(size=(b, 3)).astype(np.float32))
    dirs = dirs.at[:, 2].set(-jnp.abs(dirs[:, 2]) - 1.0)
    dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
    ts = sample_ts(jax.random.PRNGKey(1), b, rcfg)
    bits = jnp.ones((occupancy.OccupancyConfig().resolution ** 3,), bool)

    pipe_f = RenderPipeline(field, rcfg, fused_path=True)
    pipe_u = RenderPipeline(field, rcfg, fused_path=False)
    budget = 256
    target = jnp.asarray(rng.uniform(0, 1, (b, 3)).astype(np.float32))

    def loss(p, pipe):
        out = pipe(p, origins, dirs, ts, bitfield=bits, budget=budget)
        return jnp.mean((out["rgb"] - target) ** 2)

    of = pipe_f(params, origins, dirs, ts, bitfield=bits, budget=budget)
    ou = pipe_u(params, origins, dirs, ts, bitfield=bits, budget=budget)
    np.testing.assert_array_equal(np.asarray(of["rgb"]), np.asarray(ou["rgb"]))
    gf = jax.grad(loss)(params, pipe_f)
    gu = jax.grad(loss)(params, pipe_u)
    for (path, a), bb in zip(jax.tree_util.tree_leaves_with_path(gf),
                             jax.tree_util.tree_leaves(gu)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb),
                                      err_msg=f"grad mismatch at {path}")


def test_compact_morton_order_is_live_first(rng):
    """Morton-keyed compaction keeps the live-first/dead-last contract."""
    fcfg = FieldConfig(n_levels=2, max_resolution=16, log2_table_density=10,
                       log2_table_color=8)
    pipe = RenderPipeline(Field(fcfg), RenderConfig(n_samples=8))
    n = 256
    live = jnp.asarray(rng.uniform(size=n) < 0.3)
    unit = jnp.asarray(rng.uniform(0, 1, (n, 3)).astype(np.float32))
    n_live = int(live.sum())
    plan = pipe.compact(live, n_live + 8, unit)
    assert bool(plan.keep[:n_live].all()) and not bool(plan.keep[n_live:].any())
    assert int(plan.overflow) == 0
    # live prefix is in Morton order
    keys = np.asarray(fp_ref.morton_key(unit[plan.idx[:n_live]]))
    assert (np.diff(keys.astype(np.int64)) >= 0).all()


# ---- dedup instrumentation ----

def test_dedup_stats_counts(rng):
    """Morton-sorted batches must dedup strictly better per block, and a
    batch of identical points collapses to ~8 unique reads per level."""
    dense = tuple(bool(x) for x in he_ref.level_is_dense(RES, TD))
    same = jnp.broadcast_to(jnp.asarray([[0.3, 0.4, 0.5]], jnp.float32), (64, 3))
    s = fp_ref.dedup_stats(same, RES, dense, TD, block_points=64)
    assert s["unique_reads_global"] == 8 * L
    assert s["unique_ratio_block"] == pytest.approx(8 / (64 * 8))

    pts = jnp.asarray(rng.uniform(0, 1, (512, 3)).astype(np.float32))
    unsorted = fp_ref.dedup_stats(pts, RES, dense, TD, block_points=128)
    srt = fp_ref.dedup_stats(pts[jnp.argsort(fp_ref.morton_key(pts))], RES, dense,
                             TD, block_points=128)
    assert srt["unique_ratio_block"] <= unsorted["unique_ratio_block"]
    assert srt["unique_ratio_global"] == pytest.approx(unsorted["unique_ratio_global"])
