"""Adaptive sample redistribution (pipeline stage 2b) + variable-dt rendering.

Covers the ISSUE 4 contracts:

* redistribute places samples only in live strata, monotone in t, with
  positive per-sample quadrature deltas summing to the ray's live length;
* with every stratum live the stage degenerates to the uniform sampler;
* variable-dt compositing matches a dense uniform quadrature (and the
  analytic transmittance) on a piecewise-constant density;
* with the knob off the stage is never traced and training is bit-identical
  run-to-run on the ref backend (the uniform-fallback equivalence);
* the full pipeline keeps the compacted point budget at or below the
  caller's budget with zero overflow, and reports the uniform-equivalent
  live fraction;
* suggest_budget honors a hard max_budget ceiling.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import Field, FieldConfig, Instant3DTrainer, TrainerConfig, occupancy
from repro.core.pipeline import RenderPipeline, suggest_budget
from repro.core import rendering
from repro.core.rendering import RenderConfig, sample_ts
from repro.data import build_dataset, RaySampler
from repro.kernels.volume_render import ref as vr_ref

FIELD_CFG = FieldConfig(n_levels=4, max_resolution=64, log2_table_density=12,
                        log2_table_color=10)
RCFG = RenderConfig(n_samples=16)
OCFG = occupancy.OccupancyConfig(resolution=8)


def _rays(rng, b):
    origins = jnp.asarray(rng.uniform(-0.5, 0.5, (b, 3)).astype(np.float32))
    origins = origins.at[:, 2].set(4.0)  # look down at the box from above
    dirs = jnp.asarray(rng.normal(size=(b, 3)).astype(np.float32))
    dirs = dirs.at[:, 2].set(-jnp.abs(dirs[:, 2]) - 1.0)
    return origins, dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)


def _half_occupied():
    centers = occupancy.cell_centers(OCFG)
    return (centers[:, 2] < 0.5).reshape(-1)


def _candidate_liveness(pipe, origins, dirs, ts, bits):
    """Stage 1+2 on the uniform candidates, reshaped per ray (B, S)."""
    flat_pts, _, unit = pipe.generate_samples(origins, dirs, ts)
    live = pipe.cull(flat_pts, unit, bitfield=bits)
    return live.reshape(ts.shape)


def test_redistribute_places_samples_in_live_strata(rng):
    field = Field(FIELD_CFG)
    b = 48
    origins, dirs = _rays(rng, b)
    ts = sample_ts(jax.random.PRNGKey(1), b, RCFG)
    bits = _half_occupied()
    pipe = RenderPipeline(field, RCFG, redistribute=True)
    live = _candidate_liveness(pipe, origins, dirs, ts, bits)

    n_out = 8
    ts_new, deltas = pipe.redistribute(ts, live, n_out=n_out)
    assert ts_new.shape == deltas.shape == (b, n_out)
    assert bool(jnp.all(jnp.diff(ts_new, axis=-1) >= 0)), "ts must stay sorted"
    assert bool(jnp.all(deltas > 0))
    assert bool(jnp.all((ts_new >= RCFG.near) & (ts_new <= RCFG.far)))

    has_live = np.asarray(jnp.any(live, axis=-1))
    assert has_live.any(), "test geometry should give some rays live strata"

    # exact invariant: every sample of a ray with live strata lands in a
    # live stratum (the CDF's support)
    h = (RCFG.far - RCFG.near) / RCFG.n_samples
    stratum = jnp.clip(((ts_new - RCFG.near) / h).astype(jnp.int32), 0,
                       RCFG.n_samples - 1)
    in_live = jnp.take_along_axis(live, stratum, axis=-1)
    assert bool(jnp.all(in_live[has_live])), "sample placed outside live strata"

    # per-sample quadrature widths sum to the ray's live arc length
    live_len = jnp.sum(live.astype(jnp.float32), axis=-1) * h
    np.testing.assert_allclose(
        np.asarray(jnp.sum(deltas, axis=-1))[has_live],
        np.asarray(live_len)[has_live], rtol=1e-4,
    )


def test_ray_segment_mask_contract(rng):
    """The standalone per-ray probe API agrees with the flat cull lookup and
    its row-sums are the per-ray live lengths in bin-width units."""
    bits = _half_occupied()
    b, m = 8, 12
    unit = jnp.asarray(rng.uniform(0, 1, (b, m, 3)).astype(np.float32))
    mask = occupancy.ray_segment_mask(bits, unit, OCFG.resolution)
    assert mask.shape == (b, m) and mask.dtype == jnp.bool_
    flat = occupancy.point_liveness(bits, unit.reshape(-1, 3), OCFG.resolution)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(flat).reshape(b, m))
    # row-sums * bin width = live arc length, the quantity redistribute's
    # CDF normalizes by
    np.testing.assert_array_equal(
        np.asarray(jnp.sum(mask, axis=-1)),
        np.asarray(flat).reshape(b, m).sum(-1),
    )


def test_redistribute_uniform_when_all_live(rng):
    """All strata live => the adaptive placement IS the uniform stratified
    placement, with uniform deltas."""
    field = Field(FIELD_CFG)
    b, s = 16, RCFG.n_samples
    ts = sample_ts(jax.random.PRNGKey(2), b, RCFG)
    pipe = RenderPipeline(field, RCFG, redistribute=True)

    ts_new, deltas = pipe.redistribute(ts, jnp.ones((b, s), bool))
    np.testing.assert_allclose(np.asarray(ts_new), np.asarray(ts), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(deltas), (RCFG.far - RCFG.near) / s, rtol=1e-5
    )


def test_variable_dt_composite_matches_dense_quadrature():
    """Piecewise-constant sigma on [a, b]: adaptive non-uniform samples with
    per-sample deltas must agree with a dense uniform quadrature and the
    analytic transmittance."""
    near, far = 2.0, 6.0
    a, b, c = 3.0, 4.0, 1.7          # density c inside [a, b], zero outside
    rgb_val = jnp.asarray([0.8, 0.4, 0.2])

    def sigma_of(t):
        return jnp.where((t >= a) & (t < b), c, 0.0)

    # dense uniform reference: 4096 samples over [near, far]
    s_ref = 4096
    ts_ref = (near + (jnp.arange(s_ref) + 0.5) * (far - near) / s_ref)[None, :]
    deltas_ref = vr_ref.uniform_deltas(ts_ref, far - near)
    rgb_ref = jnp.broadcast_to(rgb_val, (1, s_ref, 3))
    out_ref = vr_ref.composite(sigma_of(ts_ref), rgb_ref, deltas_ref, ts_ref)

    # adaptive: 12 samples, all inside [a, b], quadratically clustered toward
    # `a` — a deliberately non-uniform partition with per-sample widths
    n = 12
    edges = a + (b - a) * (jnp.linspace(0.0, 1.0, n + 1) ** 2)
    ts_ad = ((edges[:-1] + edges[1:]) / 2)[None, :]
    deltas_ad = (edges[1:] - edges[:-1])[None, :]
    rgb_ad = jnp.broadcast_to(rgb_val, (1, n, 3))
    out_ad = vr_ref.composite(sigma_of(ts_ad), rgb_ad, deltas_ad, ts_ad)

    analytic_opacity = 1.0 - np.exp(-c * (b - a))
    np.testing.assert_allclose(float(out_ad.opacity[0]), analytic_opacity, rtol=2e-3)
    np.testing.assert_allclose(float(out_ref.opacity[0]), analytic_opacity, rtol=2e-3)
    np.testing.assert_allclose(
        np.asarray(out_ad.color), np.asarray(out_ref.color), rtol=5e-3
    )
    # depth: same weight mass, placed inside [a, b]
    np.testing.assert_allclose(
        float(out_ad.depth[0]), float(out_ref.depth[0]), rtol=5e-3
    )


def test_pipeline_redistribute_budget_and_telemetry(rng):
    field = Field(FIELD_CFG)
    params = field.init(jax.random.PRNGKey(0))
    b = 32
    origins, dirs = _rays(rng, b)
    ts = sample_ts(jax.random.PRNGKey(1), b, RCFG)
    bits = _half_occupied()
    pipe = RenderPipeline(field, RCFG, redistribute=True)

    # budget below n_rays: redistribution needs >= 1 sample/ray, so it must
    # fall back to uniform compaction and honor the ceiling by truncation
    tiny = pipe(params, origins, dirs, ts, bitfield=bits, budget=b // 2)
    assert int(tiny["points_queried"]) == b // 2

    budget = 200  # not ray-divisible: S' = 200 // 32 = 6, points = 192
    out = pipe(params, origins, dirs, ts, bitfield=bits, budget=budget)
    assert int(out["points_queried"]) == (budget // b) * b
    assert int(out["points_queried"]) <= budget
    assert int(out["overflow"]) == 0
    # live_fraction reports the uniform candidates' liveness (what the
    # budget controller calibrates against), not the ~1.0 liveness of the
    # redistributed samples — it must match the dense path's number exactly
    dense = RenderPipeline(field, RCFG)(params, origins, dirs, ts, bitfield=bits)
    np.testing.assert_allclose(
        float(out["live_fraction"]), float(dense["live_fraction"]), atol=0,
    )
    assert out["rgb"].shape == (b, 3)
    assert bool(jnp.all(jnp.isfinite(out["rgb"])))

    # differentiable end to end
    def loss(p):
        o = pipe(p, origins, dirs, ts, bitfield=bits, budget=budget)
        return jnp.mean(o["rgb"] ** 2)

    grads = jax.grad(loss)(params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree_util.tree_leaves(grads))


def test_suggest_budget_max_ceiling():
    n = 4096
    assert suggest_budget(0.5, n, max_budget=1024) == 1024
    assert suggest_budget(0.05, n, max_budget=1024) == 512  # cap not binding
    assert suggest_budget(1.0, n) == n                      # no cap: unchanged


def test_autotune_max_budget():
    """Device-envelope -> pow2 ceiling: memory and latency caps bind
    independently, the smaller wins, the floor holds, and no constraint
    means no ceiling."""
    from repro.core.trainer import autotune_max_budget
    assert autotune_max_budget(FIELD_CFG, RCFG) is None
    mem = autotune_max_budget(FIELD_CFG, RCFG, memory_bytes=2 << 20)
    assert mem is not None and mem >= 512
    assert mem & (mem - 1) == 0, "ceiling must be a power of two"
    # a tighter memory envelope can only shrink the ceiling
    assert autotune_max_budget(FIELD_CFG, RCFG, memory_bytes=1 << 20) <= mem
    # latency cap: 2 ms at 1 us/point -> 2000 points, bucketed DOWN to 1024
    lat = autotune_max_budget(FIELD_CFG, RCFG, latency_ms=2.0, us_per_point=1.0)
    assert lat == 1024
    # the binding (smaller) constraint wins
    both = autotune_max_budget(FIELD_CFG, RCFG, memory_bytes=2 << 30,
                               latency_ms=2.0, us_per_point=1.0)
    assert both == 1024
    # the floor is a floor even under a starved envelope
    assert autotune_max_budget(FIELD_CFG, RCFG, memory_bytes=1024) == 512


def _short_train(redistribute: bool, forbid_stage: bool = False, **cfg_kw):
    ds = build_dataset(seed=0, n_views=4, h=16, w=16, cfg=RCFG, gt_samples=48)[1]
    tcfg = TrainerConfig(
        n_rays=128, iters=24, render=RCFG, min_budget=128,
        occ=occupancy.OccupancyConfig(resolution=8, update_interval=8, warmup_steps=8),
        redistribute=redistribute, **cfg_kw,
    )
    tr = Instant3DTrainer(Field(FIELD_CFG), tcfg)
    if forbid_stage:
        def _boom(*a, **k):
            raise AssertionError("redistribute stage traced with the knob off")
        tr.pipeline.redistribute = _boom
    state = tr.init(jax.random.PRNGKey(0))
    state, hist = tr.train(state, RaySampler(ds), iters=tcfg.iters, log_every=8)
    return state, hist


def test_redistribute_off_is_bit_identical_uniform_fallback():
    """Knob off => the stage is never traced (the uniform path is untouched
    code) and two identical runs produce bit-identical parameters."""
    s1, h1 = _short_train(False, forbid_stage=True)
    s2, h2 = _short_train(False)
    for (p, a), b in zip(jax.tree_util.tree_leaves_with_path(s1.params),
                         jax.tree_util.tree_leaves(s2.params)):
        assert bool(np.array_equal(np.asarray(a), np.asarray(b))), f"param drift at {p}"
    assert h1["loss"] == h2["loss"]


def test_trainer_redistribute_end_to_end():
    """Training with the knob on engages after occupancy warmup, never
    spends more points than the uniform-compacted budget would, and honors
    a hard budget ceiling with zero overflow."""
    state, hist = _short_train(True, max_budget=256)
    assert all(np.isfinite(hist["loss"]))
    assert hist["points_queried"][-1] <= 256
    assert hist["overflow_total"] == 0


def test_trainer_redistribute_matches_uniform_before_occupancy():
    """Until the first occupancy update the bitfield is inactive and both
    samplers must take the identical dense path — step-for-step bit equality
    through the warmup phase."""
    ds = build_dataset(seed=0, n_views=4, h=16, w=16, cfg=RCFG, gt_samples=48)[1]

    def warmup_train(redistribute):
        tcfg = TrainerConfig(
            n_rays=128, iters=6, render=RCFG,
            occ=occupancy.OccupancyConfig(resolution=8, update_interval=8,
                                          warmup_steps=8),
            redistribute=redistribute,
        )
        tr = Instant3DTrainer(Field(FIELD_CFG), tcfg)
        state = tr.init(jax.random.PRNGKey(0))
        state, _ = tr.train(state, RaySampler(ds), iters=6, log_every=6)
        return state

    s_off, s_on = warmup_train(False), warmup_train(True)
    for (p, a), b in zip(jax.tree_util.tree_leaves_with_path(s_off.params),
                         jax.tree_util.tree_leaves(s_on.params)):
        assert bool(np.array_equal(np.asarray(a), np.asarray(b))), f"warmup drift at {p}"
