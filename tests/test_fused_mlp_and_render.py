"""Kernel validation: fused MLP + volume render vs oracles; render invariants."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.kernels.fused_mlp import ref as mlp_ref, ops as mlp_ops
from repro.kernels.volume_render import ref as vr_ref, ops as vr_ops


@pytest.mark.parametrize("n,din,h,dout", [(700, 32, 64, 16), (512, 48, 64, 3), (33, 16, 32, 1)])
def test_fused_mlp3_matches(n, din, h, dout, rng):
    x = jnp.asarray(rng.normal(size=(n, din)).astype(np.float32))
    mk = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32) * 0.1)
    w1, b1, w2, b2, w3, b3 = mk(din, h), mk(h), mk(h, h), mk(h), mk(h, dout), mk(dout)
    p3 = mlp_ops.mlp3(x, w1, b1, w2, b2, w3, b3, backend="pallas")
    r3 = mlp_ref.mlp3(x, w1, b1, w2, b2, w3, b3)
    np.testing.assert_allclose(np.asarray(p3), np.asarray(r3), atol=1e-4, rtol=1e-4)


def test_fused_mlp2_matches(rng):
    x = jnp.asarray(rng.normal(size=(300, 32)).astype(np.float32))
    mk = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32) * 0.1)
    w1, b1, w2, b2 = mk(32, 64), mk(64), mk(64, 16), mk(16)
    np.testing.assert_allclose(
        np.asarray(mlp_ops.mlp2(x, w1, b1, w2, b2, backend="pallas")),
        np.asarray(mlp_ref.mlp2(x, w1, b1, w2, b2)), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("r,s", [(300, 64), (128, 32), (77, 48)])
def test_volume_render_matches(r, s, rng):
    sigma = jnp.asarray(rng.uniform(0, 5, size=(r, s)).astype(np.float32))
    rgb = jnp.asarray(rng.uniform(0, 1, size=(r, s, 3)).astype(np.float32))
    ts = jnp.sort(jnp.asarray(rng.uniform(0.1, 4, size=(r, s)).astype(np.float32)), axis=1)
    deltas = jnp.diff(ts, axis=1, append=ts[:, -1:] + 0.01)
    o_ref = vr_ref.composite(sigma, rgb, deltas, ts)
    o_pal = vr_ops.composite(sigma, rgb, deltas, ts, backend="pallas")
    np.testing.assert_allclose(np.asarray(o_pal.color), np.asarray(o_ref.color), atol=1e-5)
    np.testing.assert_allclose(np.asarray(o_pal.depth), np.asarray(o_ref.depth), atol=1e-4)
    np.testing.assert_allclose(np.asarray(o_pal.opacity), np.asarray(o_ref.opacity), atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.integers(2, 64), dense=st.booleans())
def test_render_invariants(seed, s, dense):
    """Physical invariants of Eq. 1 for arbitrary density fields:
    weights >= 0, sum(weights) == opacity <= 1, transmittance monotone."""
    r = np.random.default_rng(seed)
    scale = 50.0 if dense else 1.0
    sigma = jnp.asarray(r.uniform(0, scale, size=(4, s)).astype(np.float32))
    rgb = jnp.asarray(r.uniform(0, 1, size=(4, s, 3)).astype(np.float32))
    ts = jnp.sort(jnp.asarray(r.uniform(0.1, 6, size=(4, s)).astype(np.float32)), axis=1)
    deltas = jnp.diff(ts, axis=1, append=ts[:, -1:] + 0.01)
    out = vr_ref.composite(sigma, rgb, deltas, ts)
    w = np.asarray(out.weights)
    assert (w >= -1e-6).all()
    np.testing.assert_allclose(w.sum(1), np.asarray(out.opacity), atol=1e-5)
    assert (np.asarray(out.opacity) <= 1 + 1e-5).all()
    # colors bounded by max rgb
    assert (np.asarray(out.color) <= 1 + 1e-5).all()
