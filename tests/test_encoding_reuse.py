"""Encoding-reuse cache correctness (ISSUE 9 tentpole part c).

The cache's one promise: a hit is bit-identical to recomputing.  The
property test drives a random sequence of table updates (row-targeted and
whole-grid), occupancy folds, and encodes at random points, comparing every
encode against the `hash_encode.ref` oracle bitwise — if invalidation were
ever stale, some sequence here would catch the drift.  Counter-based tests
pin the other direction: reuse actually happens when tables are stable, and
NO reuse happens when every row updates each step.
"""
import numpy as np
import jax.numpy as jnp

from repro.kernels.fused_path.reuse import EncodingReuseCache, stream_reuse_mask
from repro.kernels.hash_encode import ref as he_ref

from _hypothesis_shim import given, settings, strategies as st

RES = (4, 8, 16)
T = {"density": 64, "color": 32}
F = 2


def _tables(rng, grid):
    return jnp.asarray(
        rng.standard_normal((len(RES), T[grid], F)).astype(np.float32))


def _points(rng, n=32):
    return jnp.asarray(rng.random((n, 3), dtype=np.float32) * (1 - 1e-6))


@settings(max_examples=15)
@given(seed=st.integers(0, 2**31 - 1))
def test_cached_encodings_never_stale(seed):
    """Any sequence of {row update, grid update, fold, encode} keeps cached
    encodings bit-identical to a fresh oracle computation."""
    rng = np.random.default_rng(seed)
    cache = EncodingReuseCache(RES, T)
    tabs = {g: _tables(rng, g) for g in T}
    for _ in range(12):
        op = rng.choice(["encode", "rows", "grid", "fold"])
        g = str(rng.choice(list(T)))
        if op == "rows":
            # touch a random row subset and tell the cache exactly which
            n = int(rng.integers(1, 16))
            rows = rng.integers(0, len(RES) * T[g], n)
            l, idx = rows // T[g], rows % T[g]
            tabs[g] = tabs[g].at[l, idx].add(1.0)
            cache.note_table_update(g, touched_rows=rows)
        elif op == "grid":
            tabs[g] = tabs[g] * np.float32(1.01)
            cache.note_table_update(g)          # conservative: whole grid
        elif op == "fold":
            cache.note_fold()
        else:
            pts = _points(rng, int(rng.integers(8, 48)))
            for gg in T:
                out = cache.encode(gg, pts, tabs[gg])
                ref = he_ref.hash_encode(pts, tabs[gg], RES)
                assert np.array_equal(np.asarray(out), np.asarray(ref)), \
                    f"stale cache for grid {gg}"


def test_reuse_happens_when_tables_stable():
    """Stable tables + overlapping point sets => hits on the second encode,
    and the hit path returns the identical bits (not just close values)."""
    rng = np.random.default_rng(0)
    cache = EncodingReuseCache(RES, {"density": T["density"]})
    tabs = _tables(rng, "density")
    pts = _points(rng, 64)
    ref = np.asarray(he_ref.hash_encode(pts, tabs, RES))
    out1 = cache.encode("density", pts, tabs)
    assert cache.hits == 0 and cache.misses > 0
    out2 = cache.encode("density", pts, tabs)
    assert cache.hits > 0, "no reuse despite bit-stable tables"
    assert np.array_equal(np.asarray(out1), ref)
    assert np.array_equal(np.asarray(out2), ref)
    assert cache.stats()["corner_reads_saved"] == cache.hits * 8


def test_zero_reuse_when_every_row_updates_each_step():
    """Counter test: a whole-grid update between every encode keeps the hit
    counter at exactly zero — the cache can never serve across an update it
    was told about."""
    rng = np.random.default_rng(1)
    cache = EncodingReuseCache(RES, {"density": T["density"]})
    tabs = _tables(rng, "density")
    pts = _points(rng, 64)
    for step in range(5):
        out = cache.encode("density", pts, tabs)
        assert np.array_equal(
            np.asarray(out), np.asarray(he_ref.hash_encode(pts, tabs, RES)))
        tabs = tabs + np.float32(0.1)           # every row changes
        cache.note_table_update("density")
    assert cache.hits == 0
    assert cache.hit_rate() == 0.0


def test_fold_drops_entries():
    """A fold starts a new epoch: the same points re-miss even though the
    tables never changed (the live cell set may have moved)."""
    rng = np.random.default_rng(2)
    cache = EncodingReuseCache(RES, {"color": T["color"]})
    tabs = _tables(rng, "color")
    pts = _points(rng, 16)
    cache.encode("color", pts, tabs)
    cache.note_fold()
    h0 = cache.hits
    cache.encode("color", pts, tabs)
    assert cache.hits == h0, "entries survived a fold"
    assert cache.fold == 1


def test_cohort_members_share_entries():
    """Cohort sharing: members with bit-identical tables (the cohort
    training guarantee) hit each other's entries — the second member's
    encode is served entirely from cache, bit-identical to the oracle."""
    rng = np.random.default_rng(3)
    cache = EncodingReuseCache(RES, {"density": T["density"]})
    tabs = _tables(rng, "density")
    pts = _points(rng, 40)
    cache.encode("density", pts, tabs)          # member A warms the cache
    m0 = cache.misses
    out_b = cache.encode("density", pts, tabs)  # member B, same scene
    assert cache.misses == m0, "member B re-gathered despite shared tables"
    assert np.array_equal(np.asarray(out_b),
                          np.asarray(he_ref.hash_encode(pts, tabs, RES)))


def test_stream_reuse_mask_names_stable_rows():
    """The reuse-aware address-stream view: rows untouched since a version
    are reusable, touched rows are not."""
    stamp = np.zeros(8, np.int64)
    stamp[[2, 5]] = 3                            # rows 2 and 5 changed at v3
    addrs = np.array([0, 2, 4, 5, 7])
    np.testing.assert_array_equal(
        stream_reuse_mask(addrs, stamp, since=2),
        np.array([True, False, True, False, True]))
    assert stream_reuse_mask(addrs, stamp, since=3).all()
