"""One-kernel training step: forward/grad bit-identity vs the PR 3 fused
path, residual-policy equivalence, segment-sum dedup oracle, Pallas
(interpret) validation, field/pipeline/trainer wiring."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import Field, FieldConfig, Instant3DTrainer, TrainerConfig, occupancy
from repro.core import encoding as enc
from repro.core.pipeline import RenderPipeline
from repro.core.rendering import RenderConfig, sample_ts
from repro.kernels.hash_encode import ref as he_ref
from repro.kernels.fused_path import ref as fp_ref, ops as fp_ops
from repro.kernels.fused_step import ref as fs_ref, ops as fs_ops

L, F = 4, 2
TD, TC = 1 << 12, 1 << 10
RES = he_ref.level_resolutions(L, 8, 64)
SH = 16
HID = 16
GEO = 4


def _points(rng, n=400):
    pts = jnp.asarray(rng.uniform(0, 0.999, (n, 3)).astype(np.float32))
    return pts[jnp.argsort(fp_ref.morton_key(pts))]


def _tables(rng):
    td = jnp.asarray(rng.normal(size=(L, TD, F)).astype(np.float32) * 0.1)
    tc = jnp.asarray(rng.normal(size=(L, TC, F)).astype(np.float32) * 0.1)
    return td, tc


def _mlps(rng):
    def lin(d_in, d_out):
        w = rng.normal(size=(d_in, d_out)).astype(np.float32) * (1.0 / d_in) ** 0.5
        return jnp.asarray(w), jnp.asarray(rng.normal(size=(d_out,)).astype(np.float32) * 0.01)

    w1, b1 = lin(L * F, HID)
    w2, b2 = lin(HID, 1 + GEO)
    mlp_d = {"w1": w1, "b1": b1, "w2": w2, "b2": b2}
    w1, b1 = lin(L * F + SH, HID)
    w2, b2 = lin(HID, HID)
    w3, b3 = lin(HID, 3)
    mlp_c = {"w1": w1, "b1": b1, "w2": w2, "b2": b2, "w3": w3, "b3": b3}
    return mlp_d, mlp_c


def _sh(rng, n):
    return jnp.asarray(rng.normal(size=(n, SH)).astype(np.float32) * 0.3)


def _pr3_chain(points, sh, td, tc, mlp_d, mlp_c):
    """The PR 3 fused path: fused encode op + separate ref MLP heads —
    exactly what `Field.query_fused` runs on the ref backend."""
    enc_op = fp_ops.make_fused_encode(RES, (TD, TC), F, backend="ref")
    hd, hc = enc_op(points, td, tc)
    return fs_ref.mlp_heads(hd, hc, sh, mlp_d, mlp_c)


def _loss(outs):
    out_d, raw_c = outs
    return jnp.sum(out_d ** 2) + jnp.sum(raw_c * 1.7)


# ---- ref-backend bit-identity vs the PR 3 fused path (acceptance) ----

def test_fused_step_forward_bit_matches_pr3(rng):
    pts, sh = _points(rng), _sh(rng, 400)
    td, tc = _tables(rng)
    mlp_d, mlp_c = _mlps(rng)
    step = fs_ops.make_fused_step(RES, (TD, TC), F, backend="ref")
    got = jax.jit(step)(pts, sh, td, tc, mlp_d, mlp_c)
    want = jax.jit(_pr3_chain)(pts, sh, td, tc, mlp_d, mlp_c)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


@pytest.mark.parametrize("policy", fs_ops.RESIDUAL_POLICIES)
def test_fused_step_grads_bit_match_pr3(policy, rng):
    """Table grads AND MLP grads bit-identical to the PR 3 chain, under
    either residual policy (the recompute backward replays the forward's
    deterministic ops, so the residual quantities are bit-equal)."""
    pts, sh = _points(rng), _sh(rng, 400)
    td, tc = _tables(rng)
    mlp_d, mlp_c = _mlps(rng)
    step = fs_ops.make_fused_step(RES, (TD, TC), F, backend="ref",
                                  residual_policy=policy)
    gf = jax.jit(jax.grad(lambda *a: _loss(step(*a)), argnums=(1, 2, 3, 4, 5)))(
        pts, sh, td, tc, mlp_d, mlp_c
    )
    gu = jax.jit(jax.grad(lambda *a: _loss(_pr3_chain(*a)), argnums=(1, 2, 3, 4, 5)))(
        pts, sh, td, tc, mlp_d, mlp_c
    )
    for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(gf),
                            jax.tree_util.tree_leaves(gu)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"grad mismatch at {path}")


@pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
def test_residual_policies_bit_identical(backend, rng):
    """stash vs recompute: same values, bitwise, on both backends (the
    satellite contract — the knob moves work, never numbers)."""
    pts, sh = _points(rng, 256), _sh(rng, 256)
    td, tc = _tables(rng)
    mlp_d, mlp_c = _mlps(rng)
    mk = lambda p: fs_ops.make_fused_step(RES, (TD, TC), F, backend=backend,
                                          residual_policy=p, block_points=64)
    args = (pts, sh, td, tc, mlp_d, mlp_c)
    outs = {p: jax.jit(mk(p))(*args) for p in fs_ops.RESIDUAL_POLICIES}
    np.testing.assert_array_equal(np.asarray(outs["stash"][0]),
                                  np.asarray(outs["recompute"][0]))
    grads = {
        p: jax.jit(jax.grad(lambda *a, _p=p: _loss(mk(_p)(*a)),
                            argnums=(1, 2, 3, 4, 5)))(*args)
        for p in fs_ops.RESIDUAL_POLICIES
    }
    for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(grads["stash"]),
                            jax.tree_util.tree_leaves(grads["recompute"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"policy grad mismatch at {path}")


# ---- segment-sum dedup oracle ----

def test_encode_block_dedup_matches_gather_form(rng):
    """out = W @ T[uniq] (the kernel's compute structure) vs the per-corner
    gather encode: allclose — summing duplicate weights before the multiply
    reassociates float adds, never changes the math."""
    pts = _points(rng, 512)
    td, _ = _tables(rng)
    dense = tuple(bool(x) for x in he_ref.level_is_dense(np.asarray(RES), TD))
    got = fs_ref.encode_block_dedup(pts, td, RES, TD, dense, block_points=128)
    corners, weights = fp_ref.corner_geometry(pts, RES)
    idx = fp_ref.level_indices(corners, RES, TD, dense)
    want = fp_ref.encode_from_indices(td, idx, weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5)


def test_dedup_weight_matrix_sums_duplicates(rng):
    """A block of identical points: every corner address collapses into 8
    runs and each point's W row sums to 1 (partition of unity)."""
    pts = jnp.broadcast_to(jnp.asarray([[0.31, 0.42, 0.53]], jnp.float32), (32, 3))
    corners, weights = fp_ref.corner_geometry(pts, RES)
    dense = tuple(bool(x) for x in he_ref.level_is_dense(np.asarray(RES), TD))
    idx = fp_ref.level_indices(corners, RES, TD, dense)
    w_mat, uniq = fs_ref.dedup_weight_matrix(idx[0], weights[0])
    assert len(np.unique(np.asarray(uniq))) <= 8
    np.testing.assert_allclose(np.asarray(w_mat.sum(axis=1)), 1.0, atol=1e-6)


# ---- Pallas (interpret) forward + hand-written backward ----

def test_fused_step_pallas_matches_ref(rng):
    """Interpret-mode kernel vs the ref chain: forward and every gradient
    allclose; N=200 is a non-multiple of the 64-point block, so sentinel
    padding is exercised in both directions."""
    pts, sh = _points(rng, 200), _sh(rng, 200)
    td, tc = _tables(rng)
    mlp_d, mlp_c = _mlps(rng)
    args = (pts, sh, td, tc, mlp_d, mlp_c)
    step_p = fs_ops.make_fused_step(RES, (TD, TC), F, backend="pallas-interpret",
                                    block_points=64)
    step_r = fs_ops.make_fused_step(RES, (TD, TC), F, backend="ref")
    fp, fr = jax.jit(step_p)(*args), jax.jit(step_r)(*args)
    np.testing.assert_allclose(np.asarray(fp[0]), np.asarray(fr[0]), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(fp[1]), np.asarray(fr[1]), atol=1e-4, rtol=1e-4)
    gp = jax.jit(jax.grad(lambda *a: _loss(step_p(*a)), argnums=(1, 2, 3, 4, 5)))(*args)
    gr = jax.jit(jax.grad(lambda *a: _loss(step_r(*a)), argnums=(1, 2, 3, 4, 5)))(*args)
    for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(gp),
                            jax.tree_util.tree_leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3, rtol=2e-3,
                                   err_msg=f"pallas grad mismatch at {path}")


# ---- residual accounting ----

def test_residual_bytes_recompute_at_least_2x_smaller():
    """At the benchmark scale the acceptance criterion is enforced at
    (N=2048, L=6, F=2), recompute must be >= 2x below stash."""
    kw = dict(n_points=2048, n_levels=6, n_features=2,
              table_sizes=(1 << 13, 1 << 11), sh_dim=16,
              mlp_d_params=12 * 64 + 64 + 64 * 16 + 16,
              mlp_c_params=28 * 64 + 64 + 64 * 64 + 64 + 64 * 3 + 3)
    stash = fs_ref.residual_bytes("stash", **kw)
    rec = fs_ref.residual_bytes("recompute", **kw)
    assert rec * 2 <= stash, (rec, stash)
    # and the gap must WIDEN with batch size (stash scales with N, the
    # recompute set is dominated by the static tables)
    kw_big = dict(kw, n_points=100_000, n_levels=16)
    assert (fs_ref.residual_bytes("stash", **kw_big)
            / fs_ref.residual_bytes("recompute", **kw_big)) > (stash / rec)
    with pytest.raises(ValueError):
        fs_ref.residual_bytes("neither", **kw)


# ---- field / pipeline / trainer wiring ----

FCFG = FieldConfig(n_levels=L, max_resolution=64, log2_table_density=12,
                   log2_table_color=10)


def test_field_query_step_matches_query_fused(rng):
    """`query_step` (one-kernel) vs `query_fused` (PR 3): forward and every
    parameter gradient bitwise equal on the ref backend."""
    field = Field(FCFG)
    params = field.init(jax.random.PRNGKey(0))
    pts = _points(rng, 300)
    dirs = jnp.asarray(rng.normal(size=(300, 3)).astype(np.float32))
    dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
    target = jnp.asarray(rng.uniform(0, 1, (300, 3)).astype(np.float32))

    s1, r1 = jax.jit(field.query_step)(params, pts, dirs)
    s2, r2 = jax.jit(field.query_fused)(params, pts, dirs)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))

    def loss(p, q):
        sigma, rgb = q(p, pts, dirs)
        return jnp.mean((rgb - target) ** 2) + jnp.mean(sigma) * 1e-3

    g1 = jax.jit(lambda p: jax.grad(loss)(p, field.query_step))(params)
    g2 = jax.jit(lambda p: jax.grad(loss)(p, field.query_fused))(params)
    for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(g1),
                            jax.tree_util.tree_leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"grad mismatch at {path}")


def test_field_query_step_ngp_fallback(rng):
    """Non-decomposed fields fall back to query_fused (single grid has no
    one-kernel step; the color MLP needs the density head's geo features)."""
    cfg = dataclasses.replace(FCFG, decomposed=False)
    field = Field(cfg)
    assert field._fused_step is None
    params = field.init(jax.random.PRNGKey(0))
    pts = _points(rng, 64)
    dirs = jnp.ones((64, 3)) / np.sqrt(3)
    s1, r1 = field.query_step(params, pts, dirs)
    s2, r2 = field.query_fused(params, pts, dirs)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


def test_pipeline_fused_step_matches_fused_path(rng):
    """Budgeted render + grads identical whether the shade stage is the
    one-kernel step or the PR 3 encode-then-MLP split."""
    rcfg = RenderConfig(n_samples=16)
    field = Field(FCFG)
    params = field.init(jax.random.PRNGKey(0))
    b = 32
    origins = jnp.asarray(rng.uniform(-0.5, 0.5, (b, 3)).astype(np.float32))
    origins = origins.at[:, 2].set(4.0)
    dirs = jnp.asarray(rng.normal(size=(b, 3)).astype(np.float32))
    dirs = dirs.at[:, 2].set(-jnp.abs(dirs[:, 2]) - 1.0)
    dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
    ts = sample_ts(jax.random.PRNGKey(1), b, rcfg)
    bits = jnp.ones((occupancy.OccupancyConfig().resolution ** 3,), bool)
    target = jnp.asarray(rng.uniform(0, 1, (b, 3)).astype(np.float32))

    pipe_s = RenderPipeline(field, rcfg, fused_step=True)
    pipe_f = RenderPipeline(field, rcfg, fused_step=False)
    assert pipe_s.fused_step and not pipe_f.fused_step

    def loss(p, pipe):
        out = pipe(p, origins, dirs, ts, bitfield=bits, budget=256)
        return jnp.mean((out["rgb"] - target) ** 2)

    os_ = pipe_s(params, origins, dirs, ts, bitfield=bits, budget=256)
    of = pipe_f(params, origins, dirs, ts, bitfield=bits, budget=256)
    np.testing.assert_array_equal(np.asarray(os_["rgb"]), np.asarray(of["rgb"]))
    gs = jax.grad(loss)(params, pipe_s)
    gf = jax.grad(loss)(params, pipe_f)
    for (path, a), b_ in zip(jax.tree_util.tree_leaves_with_path(gs),
                             jax.tree_util.tree_leaves(gf)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_),
                                      err_msg=f"grad mismatch at {path}")


def test_trainer_fused_step_training_run_bit_identical():
    """Short real training run (occupancy, compaction, F_D:F_C freeze
    schedule all active): params, optimizer moments and occupancy EMA are
    bitwise equal with the one-kernel step on vs off."""
    from repro.data import build_dataset, RaySampler

    rcfg = RenderConfig(n_samples=8)
    _, ds = build_dataset(seed=0, n_views=3, h=16, w=16, cfg=rcfg, gt_samples=16)
    base = TrainerConfig(n_rays=128, iters=16, render=rcfg,
                         occ=occupancy.OccupancyConfig(update_interval=4,
                                                       warmup_steps=4))

    def run(fused_step):
        tr = Instant3DTrainer(Field(FCFG), dataclasses.replace(base, fused_step=fused_step))
        state = tr.init(jax.random.PRNGKey(0))
        state, _ = tr.train(state, RaySampler(ds), iters=16, log_every=16)
        return state

    s1, s2 = run(True), run(False)
    for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(s1.params),
                            jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"param mismatch at {path}")
    for a, b in zip(jax.tree_util.tree_leaves(s1.opt_state),
                    jax.tree_util.tree_leaves(s2.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(s1.occ_state.density_ema),
                                  np.asarray(s2.occ_state.density_ema))
