"""Attention: chunked online-softmax == dense; GQA; MLA absorbed decode; RoPE."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import attention, layers
from repro.models.config import ModelConfig, MLAConfig


@pytest.mark.parametrize("sq,sk,kh,rep,causal", [
    (64, 64, 2, 2, True),
    (64, 96, 2, 1, False),    # cross-attn shape, non-multiple handled by pad
    (128, 50, 1, 4, False),   # sk not a chunk multiple
])
def test_chunked_matches_dense(sq, sk, kh, rep, causal, rng, monkeypatch):
    monkeypatch.setattr(attention, "_Q_CHUNK", 32)
    monkeypatch.setattr(attention, "_K_CHUNK", 32)
    h, hd = kh * rep, 16
    q = jnp.asarray(rng.normal(size=(2, sq, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, sk, kh, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, sk, kh, hd)).astype(np.float32))
    dense = attention._sdpa_dense(q, k, v, causal)
    chunked = attention._sdpa_chunked(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), atol=2e-5, rtol=2e-5)


def test_rope_is_rotation_and_relative(rng):
    """RoPE preserves norms and q.k depends only on relative positions."""
    x = jnp.asarray(rng.normal(size=(1, 4, 1, 32)).astype(np.float32))
    pos = jnp.array([[0, 1, 5, 9]], jnp.int32)
    out = layers.apply_rope(x, pos)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(out, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), atol=1e-5)
    # relative property: <R(p)q, R(p+d)k> constant over p
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 32)).astype(np.float32))
    dots = []
    for p in (0, 3, 11):
        qr = layers.apply_rope(q, jnp.array([[p]]))
        kr = layers.apply_rope(k, jnp.array([[p + 4]]))
        dots.append(float(jnp.sum(qr * kr)))
    np.testing.assert_allclose(dots[0], dots[1], atol=1e-4)
    np.testing.assert_allclose(dots[0], dots[2], atol=1e-4)


def test_mrope_sections_cover_head_dim(rng):
    x = jnp.asarray(rng.normal(size=(2, 6, 2, 32)).astype(np.float32))
    pos3 = jnp.tile(jnp.arange(6, dtype=jnp.int32)[None, None], (3, 2, 1))
    out = layers.apply_mrope(x, pos3, sections=(6, 5, 5))
    assert out.shape == x.shape
    # with equal t/h/w position streams, mrope == standard rope at that theta
    std = layers.apply_rope(x, pos3[0], theta=1e6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(std), atol=1e-5)


def _mla_cfg():
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab=64, dtype="float32",
        mla=MLAConfig(kv_lora=32, q_lora=48, d_nope=16, d_rope=8, d_v=16),
    )


def test_mla_absorbed_decode_matches_full_attention(rng):
    """The latent-space (absorbed) decode must equal materializing per-head
    K/V — the correctness proof of the MLA cache-compression trick."""
    cfg = _mla_cfg()
    params = attention.init_mla(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 10
    x = jnp.asarray(rng.normal(size=(b, s, 64)).astype(np.float32))
    pos = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
    full = attention.mla_attention(params, cfg, x, pos, causal=True)

    cache = attention.init_mla_cache(cfg, b, s, jnp.float32)
    outs = []
    for t in range(s):
        o, cache = attention.mla_decode_attention(
            params, cfg, x[:, t : t + 1], cache, jnp.full((b, 1), t, jnp.int32))
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4, rtol=2e-4)


def test_gqa_repetition_equivalence(rng):
    """GQA with kh<h must equal MHA with kv heads explicitly repeated."""
    b, s, kh, rep, hd = 1, 8, 2, 3, 8
    q = jnp.asarray(rng.normal(size=(b, s, kh * rep, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kh, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kh, hd)).astype(np.float32))
    gqa = attention._sdpa_dense(q, k, v, causal=True)
    k_rep = jnp.repeat(k, rep, axis=2)
    v_rep = jnp.repeat(v, rep, axis=2)
    mha = attention._sdpa_dense(q, k_rep, v_rep, causal=True)
    np.testing.assert_allclose(np.asarray(gqa), np.asarray(mha), atol=1e-5)
