"""Per-arch reduced-config smoke tests: one forward/train step on CPU,
asserting output shapes + no NaNs (assignment deliverable f)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, get_smoke_config, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.models.lm import LM


def _batch(cfg, rng, b=2, s=16):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["embeds"] = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
        batch["positions"] = jnp.tile(jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, 1))
    if cfg.frontend == "audio_stub":
        batch["encoder_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_grad(arch, rng):
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_consistency(arch, rng):
    """Greedy logits from (prefill then one decode) must match teacher-forced
    forward at the same position — the KV-cache correctness invariant."""
    cfg = get_smoke_config(arch)
    if cfg.frontend == "vision_stub":
        pytest.skip("vlm decode starts from text tokens; covered in test below")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 8
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (b, s + 1)), jnp.int32)
    enc = None
    kw = {}
    if cfg.frontend == "audio_stub":
        kw["encoder_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    # teacher-forced logits at position s-1 (predicting token s)
    logits_full, _ = model.forward(params, tokens=toks, **kw)
    want = logits_full[:, s - 1]
    # prefill s tokens, then compare decode at position s-1... decode writes
    # position s's token; instead compare prefill's last-position logits
    logits_pre, caches, enc_out = model.prefill(params, tokens=toks[:, :s], max_seq=s + 4, **kw)
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(want), atol=2e-2, rtol=2e-2)
    # one decode step at position s must match teacher-forced position s
    logits_dec, _ = model.decode_step(
        params, caches, toks[:, s : s + 1], jnp.full((b, 1), s, jnp.int32),
        encoder_out=enc_out,
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full[:, s]), atol=2e-2, rtol=2e-2)


def test_vlm_decode_runs(rng):
    cfg = get_smoke_config("qwen2-vl-2b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 8
    caches = model.init_caches(b, s + 4)
    logits, caches = model.decode_step(
        params, caches,
        jnp.asarray(rng.integers(0, cfg.vocab, (b, 1)), jnp.int32),
        jnp.zeros((b, 1), jnp.int32),
    )
    assert logits.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the assigned hyperparameters."""
    spec = {
        "qwen1_5-0_5b": dict(n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816, vocab=151936),
        "qwen3-8b": dict(n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12288, vocab=151936),
        "yi-9b": dict(n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008, vocab=64000),
        "chatglm3-6b": dict(n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696, vocab=65024),
        "deepseek-v2-lite-16b": dict(n_layers=27, d_model=2048, n_heads=16, vocab=102400),
        "deepseek-v3-671b": dict(n_layers=61, d_model=7168, n_heads=128, vocab=129280),
        "whisper-medium": dict(n_layers=24, d_model=1024, n_heads=16, d_ff=4096, vocab=51865),
        "qwen2-vl-2b": dict(n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960, vocab=151936),
        "zamba2-7b": dict(n_layers=81, d_model=3584, n_heads=32, d_ff=14336, vocab=32000),
        "falcon-mamba-7b": dict(n_layers=64, d_model=4096, vocab=65024),
    }
    for arch, fields in spec.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert get_config("deepseek-v2-lite-16b").moe.n_routed == 64
    assert get_config("deepseek-v2-lite-16b").moe.top_k == 6
    assert get_config("deepseek-v3-671b").moe.n_routed == 256
    assert get_config("deepseek-v3-671b").moe.top_k == 8
    assert get_config("deepseek-v3-671b").mla.kv_lora == 512
    assert get_config("zamba2-7b").ssm.d_state == 64
    assert get_config("falcon-mamba-7b").ssm.d_state == 16


def test_long_context_applicability():
    runs = [a for a in list_archs() if applicable(get_config(a), "long_500k")[0]]
    assert sorted(runs) == ["falcon-mamba-7b", "zamba2-7b"]
