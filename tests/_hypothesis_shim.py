"""Fallback property-testing shim for containers without `hypothesis`.

Test modules import `given`, `settings`, and `strategies as st` from here.
When the real hypothesis is installed it is used verbatim; otherwise a
minimal deterministic re-implementation runs each property against
`max_examples` pseudo-random samples (seeded, so failures reproduce).  Only
the strategy surface this repo uses is provided: integers, booleans,
sampled_from.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
except ImportError:
    import random

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rnd: random.Random):
            return self._draw(rnd)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rnd: rnd.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rnd: seq[rnd.randrange(len(seq))])

    def given(**strats):
        def deco(fn):
            # NOTE: no functools.wraps — pytest would follow __wrapped__ to
            # the original signature and try to resolve the strategy args as
            # fixtures.  The wrapper must present a zero-arg signature.
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                rnd = random.Random(0xC0FFEE)
                for i in range(n):
                    drawn = {k: s.example(rnd) for k, s in strats.items()}
                    try:
                        fn(**drawn)
                    except Exception as e:  # noqa: BLE001 - re-raise with repro info
                        raise AssertionError(
                            f"property failed on example {i}: {drawn!r}"
                        ) from e

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco
