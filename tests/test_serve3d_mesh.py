"""Device-mesh serve3d: placement, cohort device axis, snapshot levels,
async serving, and the bit-identity contracts of the sharded service.

Single-device hosts run everything except the tests marked
``needs 4 devices`` — those run in-process on the CI multi-device leg
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``) and are covered
here by one subprocess test that forces the device count itself, so the
tier-1 suite exercises the mesh path everywhere.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import FieldConfig, TrainerConfig, occupancy
from repro.core.rendering import RenderConfig
from repro.data import build_dataset
from repro.launch.mesh import session_devices
from repro.serve3d import (
    DevicePlacement, ReconstructionService, SceneSession, SnapshotStore,
)

RCFG = RenderConfig(n_samples=8)
FIELD_CFG = FieldConfig(n_levels=2, max_resolution=32, log2_table_density=10,
                        log2_table_color=8, hidden=16)
OCFG = occupancy.OccupancyConfig(resolution=16, update_interval=4,
                                 warmup_steps=2)
TRAIN_CFG = TrainerConfig(n_rays=64, render=RCFG, occ=OCFG, eval_chunk=144)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def make_ds(seed=0):
    _scene, ds = build_dataset(seed=seed, n_views=2, h=12, w=12, cfg=RCFG,
                               gt_samples=24)
    return ds


def _leaves_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ---- placement policy (pure bookkeeping: fake devices are fine) ----


def test_placement_least_loaded_sticky_deterministic():
    p = DevicePlacement(["d0", "d1", "d2", "d3"])
    slots = [p.assign(f"s{i}") for i in range(6)]
    # least-loaded with ties toward the lowest slot: round-robin spread
    assert slots == [0, 1, 2, 3, 0, 1]
    # sticky: re-assigning returns the existing slot, no load double-count
    assert p.assign("s0") == 0
    assert p.loads() == [2, 2, 1, 1]
    assert p.device("s2") == "d2"
    assert p.device_for_slot(3) == "d3"
    assert p.device("unplaced") is None and p.slot("unplaced") is None


def test_placement_release_keeps_routing():
    p = DevicePlacement(["d0", "d1"])
    p.assign("a"), p.assign("b")
    p.release("a")
    # capacity returns to the pool, the mapping survives for render routing
    assert p.loads() == [0, 1]
    assert p.slot("a") == 0 and p.device("a") == "d0"
    p.release("a")                       # idempotent
    assert p.loads() == [0, 1]
    # the freed slot is the least-loaded target again
    assert p.assign("c") == 0


def test_placement_move():
    p = DevicePlacement(["d0", "d1", "d2"])
    for sid in ("a", "b", "c"):
        p.assign(sid)
    # rebalance move: least-loaded *other* slot
    assert p.move("a") in (1, 2)
    # explicit move updates loads
    p.move("b", 0)
    assert p.slot("b") == 0
    with pytest.raises(KeyError):
        p.move("nope")
    with pytest.raises(ValueError):
        p.move("a", 7)


def test_placement_validation():
    with pytest.raises(ValueError):
        DevicePlacement([])
    with pytest.raises(ValueError):
        session_devices(jax.device_count() + 1)
    with pytest.raises(ValueError):
        session_devices(0)
    assert DevicePlacement(1).n == 1
    assert len(session_devices()) == jax.device_count()


# ---- cohort keys carry the device axis ----


def test_cohort_key_device_axis():
    a = SceneSession("a", make_ds(0), FIELD_CFG, TRAIN_CFG, 16, seed=0)
    b = SceneSession("b", make_ds(1), FIELD_CFG, TRAIN_CFG, 16, seed=1)
    dev = jax.devices()[0]
    assert a.cohort_key() == b.cohort_key()       # both unplaced
    a.place(dev, 0), b.place(dev, 1)
    assert a.cohort_key() != b.cohort_key()       # split across slots
    b.place(dev, 0)
    assert a.cohort_key() == b.cohort_key()       # co-located: batch again


# ---- snapshot levels ----


def test_snapshot_levels_versions_and_gc():
    store = SnapshotStore()
    params = {"w": np.ones(3, np.float32)}
    s1 = store.publish("s", params, step=4, level=2)
    assert s1.version == 1 and s1.level == 2
    # no full snapshot yet: latest() falls back to the best preview,
    # latest(level=0) insists on full
    assert store.latest("s").level == 2
    assert store.latest("s", level=0) is None
    s2 = store.publish("s", params, step=8, level=0)
    assert s2.version == 2                        # monotone across levels
    assert store.latest("s").level == 0
    assert store.latest("s", level=2).version == 1
    assert store.levels("s") == [0, 2]
    assert store.gc_previews("s") == 1
    assert store.levels("s") == [0]
    assert store.latest("s").version == 2         # full snapshot survives
    assert store.gc_previews("s") == 0
    assert store.gc_previews("ghost") == 0


def test_preview_serving_resolution_and_gc():
    svc = ReconstructionService(slice_iters=4, snapshot_every=4,
                                snapshot_levels=2)
    ds = make_ds(0)
    sid = svc.submit_scene(ds, FIELD_CFG, TRAIN_CFG, target_iters=16, seed=0)
    svc.request_render(sid, ds.poses[0], level=2)
    svc.request_render(sid, ds.poses[0], level=0)
    got = []
    preview_first = []

    def hook(s, ev):
        got.extend(ev["results"])
        if not preview_first and ev["results"]:
            preview_first.extend(r.level for r in ev["results"])

    svc.run(hook=hook)
    by_level = {r.level: r for r in got}
    assert set(by_level) == {0, 2}
    # previews render at h>>k, full requests at full resolution
    assert by_level[2].rgb.shape == (ds.h >> 2, ds.w >> 2, 3)
    assert by_level[0].rgb.shape == (ds.h, ds.w, 3)
    # the preview was answerable before the first snapshot_every-gated full
    # publish, which is the point of progressive streaming
    assert preview_first == [2]
    assert by_level[2].snapshot_step < by_level[0].snapshot_step
    # finished sessions keep exactly their full snapshot
    assert svc.store.levels(sid) == [0]


# ---- bit-identity contracts ----


def test_devices_1_bit_identical_to_placement_free():
    results = {}
    for devices in (None, 1):
        svc = ReconstructionService(slice_iters=8, max_cohort=4,
                                    devices=devices)
        sids = [svc.submit_scene(make_ds(s), FIELD_CFG, TRAIN_CFG,
                                 target_iters=16, seed=s) for s in range(2)]
        svc.run()
        rid = svc.request_render(sids[0], make_ds(0).poses[0])
        out = {r.request_id: r for r in svc.renderer.drain()}
        results[devices] = (svc, sids, out[rid])
    svc_a, sids_a, render_a = results[None]
    svc_b, sids_b, render_b = results[1]
    for a, b in zip(sids_a, sids_b):
        assert _leaves_equal(svc_a.store.latest(a).params,
                             svc_b.store.latest(b).params)
    assert np.array_equal(render_a.rgb, render_b.rgb)
    assert np.array_equal(render_a.depth, render_b.depth)


def test_eval_matches_served_bitwise():
    """The trainer-side offline `evaluate` and the service's render path
    march the same redistributed quadrature on the same snapshot — the
    eval == served regression contract."""
    svc = ReconstructionService(slice_iters=8)
    ds = make_ds(0)
    sid = svc.submit_scene(ds, FIELD_CFG, TRAIN_CFG, target_iters=16, seed=0)
    svc.run()
    rid = svc.request_render(sid, ds.poses[0])
    served = {r.request_id: r for r in svc.renderer.drain()}[rid]
    sess = svc.sessions[sid]
    assert sess.render_spr is not None
    snap = svc.store.latest(sid)
    rgb, dep = sess.trainer.render_image(snap.params, ds.poses[0], ds,
                                         occ=snap.occ,
                                         samples_per_ray=sess.render_spr)
    assert np.array_equal(np.asarray(rgb), served.rgb)
    assert np.array_equal(np.asarray(dep), served.depth)
    # and the aggregate evaluate() runs the same path without error
    ev = sess.evaluate(views=[0])
    assert np.isfinite(ev["psnr_rgb"])


def test_async_serving_completes_and_matches_sync():
    ds = make_ds(0)
    finals = {}
    for async_mode in (False, True):
        svc = ReconstructionService(slice_iters=8, async_serving=async_mode)
        sid = svc.submit_scene(ds, FIELD_CFG, TRAIN_CFG, target_iters=16,
                               seed=0)
        svc.request_render(sid, ds.poses[0])
        got = []
        svc.run(hook=lambda s, ev: got.extend(ev["results"]))
        assert not svc.renderer.async_active
        assert len(got) == 1 and svc.renderer.pending == 0
        # post-run renders use the (now synchronous) drain on both services
        rid = svc.request_render(sid, ds.poses[1])
        finals[async_mode] = {r.request_id: r for r in
                              svc.renderer.drain()}[rid]
    # same snapshot, same compiled entry -> same pixels regardless of which
    # plane served the in-flight requests
    assert np.array_equal(finals[False].rgb, finals[True].rgb)
    assert np.array_equal(finals[False].depth, finals[True].depth)


# ---- multi-device (in-process on the CI mesh leg) ----


@needs_mesh
def test_mesh_spreads_and_matches_single_device():
    n_scenes = 6
    svc = ReconstructionService(slice_iters=8, devices=4, max_cohort=4)
    sids = [svc.submit_scene(make_ds(s), FIELD_CFG, TRAIN_CFG,
                             target_iters=16, seed=s) for s in range(n_scenes)]
    tel = svc.run()
    assert tel["scenes_done"] == n_scenes
    placed = tel["placement"]["placed"]
    assert set(placed.values()) == {0, 1, 2, 3}
    # released on completion: capacity returned, routing retained
    assert tel["placement"]["loads"] == [0, 0, 0, 0]

    ref = ReconstructionService(slice_iters=8, max_cohort=4)
    ref_sids = [ref.submit_scene(make_ds(s), FIELD_CFG, TRAIN_CFG,
                                 target_iters=16, seed=s)
                for s in range(n_scenes)]
    ref.run()
    for a, b in zip(sids, ref_sids):
        assert _leaves_equal(svc.store.latest(a).params,
                             ref.store.latest(b).params)


@needs_mesh
def test_per_device_residency_cap():
    # max_resident=1 per device, 4 devices -> 4 resident sessions at once
    svc = ReconstructionService(slice_iters=4, devices=4, max_resident=1)
    for s in range(6):
        svc.submit_scene(make_ds(s), FIELD_CFG, TRAIN_CFG, target_iters=8,
                         seed=s)
    resident_high = [0]

    def hook(service, _ev):
        resident_high[0] = max(resident_high[0],
                               service.scheduler._resident_count())

    tel = svc.run(hook=hook)
    assert tel["scenes_done"] == 6
    assert resident_high[0] <= 4


@needs_mesh
def test_device_move_suspend_resume_bit_identity():
    devs = jax.devices()
    ds = make_ds(0)

    moved = SceneSession("m", ds, FIELD_CFG, TRAIN_CFG, 16, seed=0)
    moved.place(devs[0], 0)
    moved.start()
    moved.run_slice(8)
    moved.suspend()
    moved.place(devs[1], 1)      # the device move: host round-trip, new slot
    moved.resume()
    moved.run_slice(8)

    ref = SceneSession("r", make_ds(0), FIELD_CFG, TRAIN_CFG, 16, seed=0)
    ref.start()
    ref.run_slice(8)
    ref.run_slice(8)

    assert moved.status == ref.status == "done"
    assert _leaves_equal(moved.state.params, ref.state.params)
    assert _leaves_equal(moved.state.opt_state, ref.state.opt_state)
    assert np.array_equal(np.asarray(moved.state.occ_state.density_ema),
                          np.asarray(ref.state.occ_state.density_ema))


# ---- forced-device-count subprocess (tier-1 coverage on any host) ----


_CHILD = textwrap.dedent("""
    import jax, numpy as np
    assert jax.device_count() == 4, jax.devices()
    from repro.core import FieldConfig, TrainerConfig, occupancy
    from repro.core.rendering import RenderConfig
    from repro.data import build_dataset
    from repro.serve3d import ReconstructionService

    RCFG = RenderConfig(n_samples=8)
    FIELD = FieldConfig(n_levels=2, max_resolution=32, log2_table_density=10,
                        log2_table_color=8, hidden=16)
    OCFG = occupancy.OccupancyConfig(resolution=16, update_interval=4,
                                     warmup_steps=2)
    TCFG = TrainerConfig(n_rays=64, render=RCFG, occ=OCFG, eval_chunk=144)

    def mk(seed):
        return build_dataset(seed=seed, n_views=2, h=12, w=12, cfg=RCFG,
                             gt_samples=24)[1]

    svc = ReconstructionService(slice_iters=8, devices=4, max_cohort=4,
                                async_serving=True)
    sids = [svc.submit_scene(mk(s), FIELD, TCFG, target_iters=16, seed=s)
            for s in range(4)]
    for sid in sids:
        svc.request_render(sid, mk(0).poses[0])
    got = []
    tel = svc.run(hook=lambda s, ev: got.extend(ev["results"]))
    assert tel["scenes_done"] == 4, tel
    assert len(got) == 4, got
    assert set(tel["placement"]["placed"].values()) == {0, 1, 2, 3}

    ref = ReconstructionService(slice_iters=8, max_cohort=4)
    rids = [ref.submit_scene(mk(s), FIELD, TCFG, target_iters=16, seed=s)
            for s in range(4)]
    ref.run()
    for a, b in zip(sids, rids):
        la = jax.tree.leaves(svc.store.latest(a).params)
        lb = jax.tree.leaves(ref.store.latest(b).params)
        assert all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(la, lb))
    print("MESH_CHILD_OK")
""")


def test_forced_host_device_count_subprocess():
    """End-to-end mesh run under a forced 4-device host topology: placement
    spread, async serving, and N=4 == N=1 params bit-identity."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    env.pop("REPRO_OBS", None)
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "MESH_CHILD_OK" in proc.stdout
