"""repro.obs: span semantics, Chrome-trace schema, metrics, disabled no-ops."""
import importlib.util
import json
import threading
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.obs import export, metrics, trace

REPO = Path(__file__).resolve().parent.parent


def _load_check_trace():
    spec = importlib.util.spec_from_file_location(
        "check_trace", REPO / "tools" / "check_trace.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture
def obs_on():
    """Enable obs with clean buffers; restore the prior state afterwards."""
    was = trace.enabled()
    trace.set_enabled(True)
    obs.reset()
    yield
    obs.reset()
    trace.set_enabled(was)


# ---- spans ----


def test_span_nesting_depth_and_timing(obs_on):
    with trace.span("outer", cat="t"):
        with trace.span("inner", cat="t"):
            pass
    evs = trace.events()
    assert [e.name for e in evs] == ["inner", "outer"]  # exit order
    inner, outer = evs
    assert (inner.depth, outer.depth) == (1, 0)
    # inner is contained in outer on the shared timeline
    assert outer.ts_us <= inner.ts_us
    assert inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us + 1e-3


def test_span_depth_restored_on_exception(obs_on):
    with pytest.raises(ValueError):
        with trace.span("boom"):
            raise ValueError("x")
    with trace.span("after"):
        pass
    assert trace.events()[-1].depth == 0


def test_span_thread_attribution(obs_on):
    def worker():
        with trace.span("in-thread"):
            pass

    t = threading.Thread(target=worker, name="obs-worker")
    t.start()
    t.join()
    with trace.span("in-main"):
        pass
    by_name = {e.name: e for e in trace.events()}
    assert by_name["in-thread"].thread_name == "obs-worker"
    assert by_name["in-thread"].tid != by_name["in-main"].tid


def test_traced_decorator_and_instant(obs_on):
    @trace.traced("deco/fn", cat="t")
    def fn(x):
        return x + 1

    assert fn(1) == 2
    trace.instant("marker", args={"k": 1})
    names = [e.name for e in trace.events()]
    assert names == ["deco/fn", "marker"]
    assert trace.events()[1].dur_us is None


def test_ring_buffer_bounded(obs_on):
    trace.configure(buffer_size=8)
    for i in range(20):
        with trace.span(f"s{i}"):
            pass
    evs = trace.events()
    assert len(evs) == 8 and evs[0].name == "s12"
    trace.configure(buffer_size=262144)


# ---- disabled-mode guarantees ----


def test_disabled_span_is_shared_noop_singleton():
    was = trace.enabled()
    trace.set_enabled(False)
    try:
        n0 = len(trace.events())
        s1 = trace.span("a")
        s2 = trace.span("b", cat="x", args={"big": 1})
        assert s1 is trace.NULL and s2 is trace.NULL  # no allocation
        with s1:
            pass
        trace.instant("nope")
        trace.record("nope", 0.0, 1.0)
        assert len(trace.events()) == n0  # nothing recorded
    finally:
        trace.set_enabled(was)


def test_env_knob_parsing():
    for off in ("", "0", "off", "false", "no", "NO", " Off "):
        assert trace._env_enabled(off) is False
    for on in ("1", "on", "true", "jax", "yes"):
        assert trace._env_enabled(on) is True


# ---- metrics ----


def test_histogram_quantiles_match_numpy(rng):
    h = metrics.Histogram(window=512)
    vals = rng.standard_normal(257).tolist()
    for v in vals:
        h.observe(v)
    for q in (0.0, 0.25, 0.5, 0.95, 0.99, 1.0):
        np.testing.assert_allclose(h.quantile(q), np.quantile(vals, q),
                                   rtol=1e-12, atol=1e-12)


def test_histogram_window_bounded_lifetime_counts():
    h = metrics.Histogram(window=4)
    for v in range(10):
        h.observe(v)
    assert h.count == 10 and h.total == sum(range(10))
    assert h.values() == [6.0, 7.0, 8.0, 9.0]  # window keeps the recent tail


def test_registry_typed_and_deterministic():
    reg = metrics.Registry()
    reg.counter("a.count").inc(3)
    reg.gauge("a.gauge").set(1.5)
    reg.histogram("a.hist").observe(2.0)
    with pytest.raises(TypeError):
        reg.gauge("a.count")  # name keeps its kind
    s1, s2 = reg.snapshot(), reg.snapshot()
    assert s1 == s2
    assert json.dumps(s1, sort_keys=True) == json.dumps(s2, sort_keys=True)
    assert list(s1) == sorted(s1)
    assert s1["a.count"] == {"type": "counter", "value": 3}
    reg.reset()
    assert reg.snapshot() == {}


# ---- chrome-trace export ----


def test_chrome_trace_schema_roundtrip(obs_on, tmp_path):
    ct = _load_check_trace()
    with trace.span("outer", cat="t", args={"k": "v"}):
        with trace.span("inner", cat="t"):
            pass
    trace.instant("mark")
    path = tmp_path / "trace.json"
    export.dump_trace(str(path))
    doc = json.loads(path.read_text())
    assert ct.check(doc, require=["outer", "inner", "mark"]) == []
    # spot-check the event grammar the validator enforces
    X = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in X} == {"outer", "inner"}
    assert all(e["dur"] >= 0 and e["ts"] > 0 for e in X)
    args = {e["name"]: e["args"] for e in X}
    assert args["outer"]["k"] == "v" and args["inner"]["depth"] == 1

    # and the validator actually rejects malformed documents
    assert ct.check({"traceEvents": [{"name": "x"}]}) != []
    assert ct.check(doc, require=["absent/span"]) != []


def test_metrics_export_and_formatting(obs_on, tmp_path):
    metrics.counter("x.count").inc(2)
    metrics.histogram("x.lat").observe(5.0)
    path = tmp_path / "metrics.json"
    export.dump_metrics(str(path), extra={"run": "test"})
    doc = json.loads(path.read_text())
    assert doc["meta"] == {"run": "test"}
    assert doc["metrics"]["x.count"]["value"] == 2
    text = export.format_metrics(doc)
    assert "x.count" in text and "x.lat" in text and "count=1" in text
    assert export.format_metrics(doc, prefix="x.lat").count("\n") == 0


# ---- instrumented surfaces ----


def test_fused_step_spans_at_trace_time(obs_on, rng):
    import jax
    import jax.numpy as jnp
    from repro.core import Field, FieldConfig

    field = Field(FieldConfig(n_levels=2, max_resolution=16,
                              log2_table_density=8, log2_table_color=6,
                              hidden=16))
    params = field.init(jax.random.PRNGKey(0))
    pts = jnp.asarray(rng.random((32, 3)), jnp.float32)
    dirs = jnp.asarray(rng.standard_normal((32, 3)), jnp.float32)

    def loss(p):
        sigma, rgb = field.query_step(p, pts, dirs)
        return jnp.mean(sigma) + jnp.mean(rgb)

    jax.grad(loss)(params)
    names = [e.name for e in trace.events()]
    assert "kernels/fused_step/fwd" in names
    assert "kernels/fused_step/bwd" in names


def test_dedup_stats_folds_into_registry(obs_on, rng):
    from repro.kernels.fused_path import ref as fp_ref

    pts = rng.random((64, 3)).astype(np.float32)
    stats = fp_ref.dedup_stats(pts, (4, 8), (True, True), 512, block_points=32)
    g = metrics.REGISTRY.get("fused_path.dedup.unique_ratio_block")
    assert g is not None and g.value == pytest.approx(stats["unique_ratio_block"])


def test_serve3d_service_metrics_and_trace(obs_on, tmp_path):
    from repro.core import FieldConfig, TrainerConfig, occupancy
    from repro.core.rendering import RenderConfig
    from repro.data import build_dataset
    from repro.serve3d import ReconstructionService

    rcfg = RenderConfig(n_samples=8)
    fcfg = FieldConfig(n_levels=2, max_resolution=32, log2_table_density=10,
                       log2_table_color=8, hidden=16)
    ocfg = occupancy.OccupancyConfig(resolution=16, update_interval=4,
                                     warmup_steps=2)
    tcfg = TrainerConfig(n_rays=64, render=rcfg, occ=ocfg, eval_chunk=144)

    svc = ReconstructionService(slice_iters=8, max_cohort=None)
    for seed in range(2):
        _scene, ds = build_dataset(seed=seed, n_views=2, h=12, w=12,
                                   cfg=rcfg, gt_samples=24)
        sid = svc.submit_scene(ds, fcfg, tcfg, target_iters=8, seed=seed)
        svc.request_render(sid, ds.poses[0])
    svc.run(max_quanta=20)

    doc = svc.metrics()
    snap = doc["metrics"]
    lat = snap["serve3d.render.latency_ms"]
    assert lat["count"] == 2
    assert all(lat[q] is not None for q in ("p50", "p95", "p99"))
    assert snap["serve3d.snapshots_published"]["value"] >= 2
    assert snap["serve3d.render.ttfuv_s.scene-000"]["value"] > 0
    render = doc["meta"]["service"]["telemetry"]["render"]
    assert render["count"] == 2 and render["p99_ms"] >= render["p50_ms"]
    assert set(render["ttfuv_s"]) == {"scene-000", "scene-001"}
    assert doc["meta"]["service"]["snapshots"]["scene-000"] >= 1

    ct = _load_check_trace()
    path = svc.dump_trace(str(tmp_path / "serve.json"))
    trace_doc = json.loads(Path(path).read_text())
    assert ct.check(trace_doc, require=[
        "serve3d/quantum", "serve3d/slice", "serve3d/snapshot_publish",
        "serve3d/render_drain", "serve3d/render_group",
        "trainer/step_compile", "trainer/occ_update",
        "pipeline/sample", "pipeline/shade", "pipeline/composite",
    ]) == []
