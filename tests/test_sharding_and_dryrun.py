"""Sharding rules + a miniature end-to-end dry-run on a small host mesh.

The production 512-device dry-run runs via `python -m repro.launch.dryrun`;
this test exercises the same code path at (2, 2) so it runs in CI seconds.
Device count is per-process, so the multi-device cells run in a subprocess
with XLA_FLAGS (the suite itself must keep seeing 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _jax_compat import requires_new_sharding_api

from repro.configs import get_smoke_config
from repro.models.lm import LM
from repro.parallel import sharding as shd


@requires_new_sharding_api
def test_param_specs_cover_tree():
    cfg = get_smoke_config("qwen3-8b")
    model = LM(cfg)
    ap = model.init_abstract()
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    specs = shd.param_specs(cfg, ap, mesh, shd.ShardingPolicy())
    n_specs = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
    n_params = len(jax.tree_util.tree_leaves(ap))
    assert n_specs == n_params


@requires_new_sharding_api
def test_tp_rules_shard_heads_and_ffn():
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), n_kv_heads=4)
    model = LM(cfg)
    ap = model.init_abstract()
    # AbstractMesh: rule evaluation needs only axis sizes, not real devices
    mesh = jax.sharding.AbstractMesh((1, 2), ("data", "model"))
    specs = shd.param_specs(cfg, ap, mesh, shd.ShardingPolicy(tp=True))
    seg = specs["seg0_attn_dense"]
    assert seg["attn"]["wq"] == jax.sharding.PartitionSpec(None, None, "model", None)
    assert seg["ffn"]["w_gate"] == jax.sharding.PartitionSpec(None, None, "model")
    assert seg["ffn"]["w_down"] == jax.sharding.PartitionSpec(None, "model", None)
    assert specs["embed"] == jax.sharding.PartitionSpec("model", None)


@requires_new_sharding_api
def test_indivisible_heads_stay_replicated():
    cfg = get_smoke_config("qwen2-vl-2b")  # 4 q heads, 2 kv heads
    model = LM(cfg)
    ap = model.init_abstract()
    mesh = jax.sharding.AbstractMesh((1, 8), ("data", "model"))
    specs = shd.param_specs(cfg, ap, mesh, shd.ShardingPolicy(tp=True))
    # 4 heads % 8 != 0 -> replicated, but ffn 128 % 8 == 0 -> sharded
    assert specs["seg0_attn_dense"]["attn"]["wq"] == jax.sharding.PartitionSpec(None, None, None, None)
    assert specs["seg0_attn_dense"]["ffn"]["w_gate"] == jax.sharding.PartitionSpec(None, None, "model")


_SUBPROCESS_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json, dataclasses
    sys.path.insert(0, {src!r})
    import jax
    from repro.launch.steps import build_step_cfg
    from repro.launch.roofline import collective_stats
    from repro.configs import get_smoke_config
    from repro.configs.shapes import SHAPES, Shape

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    cfg = get_smoke_config({arch!r})
    shape = Shape("t", 32, 8, {kind!r})
    import repro.configs.shapes as shp
    shp.SHAPES["t"] = shape
    with jax.set_mesh(mesh):
        (fn, args), cfg, shape = build_step_cfg(cfg, "t", mesh)
        compiled = fn.lower(*args).compile()
        coll = collective_stats(compiled.as_text(), default_group=2)
        mem = compiled.memory_analysis()
    print(json.dumps({{
        "ok": True,
        "collective_kinds": sorted(coll["ops"].keys()),
        "wire": coll["wire_bytes_per_device"],
        "args_bytes": mem.argument_size_in_bytes,
    }}))
""")


@requires_new_sharding_api
@pytest.mark.parametrize("arch,kind,expect_coll", [
    ("qwen3-8b", "train", "all-reduce"),          # DP gradient sync
    ("deepseek-v2-lite-16b", "train", "all-to-all"),  # EP dispatch
    ("falcon-mamba-7b", "decode", None),
])
def test_mini_dryrun_multipod(arch, kind, expect_coll, tmp_path):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = _SUBPROCESS_DRYRUN.format(src=os.path.abspath(src), arch=arch, kind=kind)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"]
    if expect_coll is not None:
        assert expect_coll in out["collective_kinds"], out
