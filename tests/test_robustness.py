"""Fault tolerance: injection harness, checkpoint integrity, guard
rollback/quarantine, and graceful render degradation.

Chaos scenarios run the real service with `repro.testing.faults` armed and
assert the recovery contract: every session finishes, at least one rollback
happened, and — because training streams are keyed by absolute step —
recovered runs are *bit-identical* to fault-free runs."""
import functools

import numpy as np
import jax
import pytest

from _hypothesis_shim import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.core import FieldConfig, TrainerConfig, occupancy
from repro.core.rendering import RenderConfig
from repro.core.trainer import tree_all_finite
from repro.data import build_dataset
from repro.serve3d import (
    DONE, QUARANTINED, GuardConfig, ReconstructionService, RenderError,
    RenderService, SceneSession, SnapshotStore,
)
from repro.testing import faults

RCFG = RenderConfig(n_samples=8)
FIELD_CFG = FieldConfig(n_levels=2, max_resolution=32, log2_table_density=10,
                        log2_table_color=8, hidden=16)
OCFG = occupancy.OccupancyConfig(resolution=16, update_interval=4, warmup_steps=2)
TRAIN_CFG = TrainerConfig(n_rays=64, render=RCFG, occ=OCFG, eval_chunk=144)


@functools.lru_cache(maxsize=None)
def _ds(seed: int = 0):
    # cached builder instead of a pytest fixture so the shim-based property
    # tests (zero-arg wrappers) can use the same datasets
    _scene, ds = build_dataset(seed=seed, n_views=2, h=12, w=12,
                               cfg=RCFG, gt_samples=24)
    return ds


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.reset()
    faults.configure(enabled=False)
    yield
    faults.reset()
    faults.configure(enabled=False)


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def _run_service(n_scenes=2, target_iters=16, slice_iters=4, guard=True,
                 **svc_kwargs):
    svc = ReconstructionService(slice_iters=slice_iters, guard=guard,
                                **svc_kwargs)
    for seed in range(n_scenes):
        svc.submit_scene(_ds(seed), FIELD_CFG, TRAIN_CFG,
                         target_iters=target_iters, seed=seed)
    tel = svc.run()
    return svc, tel


def _final_params(svc):
    return {sid: jax.device_get(s._current_params())
            for sid, s in svc.sessions.items()}


# ---- the injection harness itself ----


def test_faults_disabled_is_noop():
    assert not faults.enabled()
    assert faults.check("serve3d.slice", session="x", step=0) is None
    assert faults.fired() == []


def test_fault_matching_semantics():
    faults.configure(enabled=True)
    inj = faults.inject("serve3d.slice", "nan_params", session="a",
                        at_step=10, skip=1, times=2)
    # wrong session / early step never match
    assert faults.check("serve3d.slice", session="b", step=50) is None
    assert faults.check("serve3d.slice", session="a", step=5) is None
    # first matching call is skipped, the next two fire, then exhausted
    assert faults.check("serve3d.slice", session="a", step=10) is None
    assert faults.check("serve3d.slice", session="a", step=12) is inj
    assert faults.check("serve3d.slice", session="a", step=14) is inj
    assert faults.check("serve3d.slice", session="a", step=16) is None
    assert faults.fired_count("nan_params") == 2
    # non-match keys ride along as call-site params
    inj2 = faults.inject("serve3d.slice", "slow", seconds=0.5)
    assert inj2.params == {"seconds": 0.5}
    assert inj2.match == {}


def test_arming_enables_and_reset_clears():
    assert not faults.enabled()
    faults.inject("checkpoint.write", "corrupt")
    assert faults.enabled()
    assert faults.check("checkpoint.write", step=1) is not None
    faults.reset()
    assert faults.check("checkpoint.write", step=2) is None
    assert faults.fired() == []


def test_poison_tree_and_finiteness():
    tree = {"w": np.ones((3, 2), np.float32), "n": np.arange(4)}
    bad = faults.poison_tree(tree, float("nan"))
    assert np.isnan(np.asarray(bad["w"])).all()
    np.testing.assert_array_equal(np.asarray(bad["n"]), tree["n"])  # int kept
    assert tree_all_finite(tree)
    assert not tree_all_finite(bad)
    assert tree_all_finite(bad["n"])  # integer-only tree is trivially finite


# ---- checkpoint integrity (per-file checksums + atomicity) ----


def test_checkpoint_meta_carries_per_file_checksums(tmp_path):
    ckpt = CheckpointManager(tmp_path, async_save=False)
    ckpt.save(1, {"w": np.ones(4, np.float32)})
    _tree, meta = ckpt.restore({"w": np.zeros(4, np.float32)})
    assert "files" in meta and set(meta["files"]) == {"arrays.npz"}
    assert meta["sha256"] == meta["files"]["arrays.npz"]


def test_checkpoint_rejects_corruption_falls_back(tmp_path):
    ckpt = CheckpointManager(tmp_path, async_save=False)
    ckpt.save(1, {"w": np.full(8, 1.0, np.float32)})
    ckpt.save(2, {"w": np.full(8, 2.0, np.float32)})
    faults.corrupt_file(tmp_path / "step_00000002" / "arrays.npz")
    assert not ckpt._verify(2)
    tree, meta = ckpt.restore({"w": np.zeros(8, np.float32)})
    assert meta["step"] == 1
    np.testing.assert_array_equal(tree["w"], np.full(8, 1.0, np.float32))


def test_checkpoint_corrupt_injection_detected(tmp_path):
    faults.configure(enabled=True)
    ckpt = CheckpointManager(tmp_path, async_save=False)
    ckpt.save(1, {"w": np.full(8, 1.0, np.float32)})
    faults.inject("checkpoint.write", "corrupt", at_step=2)
    ckpt.save(2, {"w": np.full(8, 2.0, np.float32)})
    assert faults.fired_count("corrupt") == 1
    # the corrupted step committed but verification rejects it
    assert 2 in ckpt.all_steps() and not ckpt._verify(2)
    _tree, meta = ckpt.restore({"w": np.zeros(8, np.float32)})
    assert meta["step"] == 1


def test_checkpoint_kill_mid_write_is_atomic(tmp_path):
    """A crash between data write and rename must leave the previous
    checkpoint as the latest valid one — the torn tmp dir never shadows it."""
    faults.configure(enabled=True)
    ckpt = CheckpointManager(tmp_path, async_save=False)
    ckpt.save(10, {"w": np.full(8, 10.0, np.float32)})
    faults.inject("checkpoint.write", "kill_mid_write", at_step=20)
    with pytest.raises(faults.InjectedFault):
        ckpt.save(20, {"w": np.full(8, 20.0, np.float32)})
    assert (tmp_path / "tmp_step_00000020").exists()   # torn write left behind
    assert ckpt.all_steps() == [10]                    # never committed
    tree, meta = ckpt.restore({"w": np.zeros(8, np.float32)})
    assert meta["step"] == 10
    # the same step saves cleanly after the "restart" (tmp dir is reused)
    ckpt.save(20, {"w": np.full(8, 20.0, np.float32)})
    assert ckpt.all_steps() == [10, 20]
    _tree, meta = ckpt.restore({"w": np.zeros(8, np.float32)})
    assert meta["step"] == 20


# ---- guard: detection, rollback, quarantine ----


def test_nan_params_rollback_bit_identical():
    """The acceptance scenario at 2 scenes: NaN params in one cohort member
    -> rollback; both sessions finish; final params bit-identical to the
    fault-free run (including the faulted session — rollback + step-keyed
    retraining reproduces the stream exactly)."""
    faults.configure(enabled=True)
    faults.inject("serve3d.slice", "nan_params", session="scene-001", at_step=8)
    svc_f, tel_f = _run_service(target_iters=16)
    assert faults.fired_count("nan_params") == 1
    assert tel_f["guard"]["rollbacks"] >= 1
    assert all(s.status == DONE for s in svc_f.sessions.values())
    params_f = _final_params(svc_f)

    faults.configure(enabled=False)
    svc_c, tel_c = _run_service(target_iters=16)
    assert tel_c["guard"]["rollbacks"] == 0
    params_c = _final_params(svc_c)
    for sid in params_c:
        assert _leaves_equal(params_f[sid], params_c[sid]), sid


def test_nan_loss_detected_by_cheap_check():
    faults.configure(enabled=True)
    faults.inject("serve3d.slice", "nan_loss", session="scene-000", at_step=4)
    svc, tel = _run_service(n_scenes=1, target_iters=16)
    assert tel["guard"]["divergences"].get("nan_loss", 0) >= 1
    assert svc.sessions["scene-000"].status == DONE


def test_loss_spike_trips_collapse_heuristic():
    faults.configure(enabled=True)
    faults.inject("serve3d.slice", "loss_spike", session="scene-000",
                  at_step=20, factor=1e8)
    svc, tel = _run_service(n_scenes=1, target_iters=32)
    assert tel["guard"]["divergences"].get("collapse", 0) >= 1
    assert svc.sessions["scene-000"].status == DONE


def test_slice_exception_rolls_back_with_guard():
    faults.configure(enabled=True)
    faults.inject("serve3d.slice", "exception", session="scene-000", at_step=8)
    svc, tel = _run_service(n_scenes=1, target_iters=16)
    assert tel["guard"]["divergences"].get("exception", 0) == 1
    assert svc.sessions["scene-000"].status == DONE


def test_slice_exception_unwinds_without_guard():
    faults.configure(enabled=True)
    faults.inject("serve3d.slice", "exception", session="scene-000", at_step=8)
    with pytest.raises(faults.InjectedFault):
        _run_service(n_scenes=1, target_iters=16, guard=None)


def test_quarantine_after_max_retries_keeps_service_alive():
    """A persistently-sick scene is ejected after max_retries consecutive
    failures; the other session finishes untouched, the service terminates,
    and the quarantined scene keeps serving its last-good snapshot, stale."""
    faults.configure(enabled=True)
    faults.inject("serve3d.slice", "nan_params", session="scene-000",
                  at_step=8, times=None)
    svc, tel = _run_service(
        target_iters=16, guard=GuardConfig(checkpoint_every=2, max_retries=2))
    sick, healthy = svc.sessions["scene-000"], svc.sessions["scene-001"]
    assert sick.status == QUARANTINED
    assert healthy.status == DONE and healthy.step == 16
    assert svc.scheduler.all_done          # quarantine is terminal
    assert tel["guard"]["quarantined"] == ["scene-000"]
    assert tel["guard"]["rollbacks"] == 2  # max_retries, then ejected

    # the quarantined scene still serves: last-good snapshot, marked stale
    snap = svc.store.latest("scene-000")
    assert snap is not None and snap.step <= 8
    assert tree_all_finite(snap.params)
    svc.request_render("scene-000", _ds(0).poses[0])
    (res,) = svc.renderer.drain()
    assert res.stale and res.snapshot_step == snap.step

    # healthy session's result is bit-identical to a fault-free run
    faults.configure(enabled=False)
    svc_c, _ = _run_service(target_iters=16)
    assert _leaves_equal(svc_c.sessions["scene-001"]._current_params(),
                         healthy._current_params())


def test_straggler_slice_flagged_not_blocked():
    faults.configure(enabled=True)
    faults.inject("serve3d.slice", "slow", session="scene-000",
                  at_step=8, seconds=1.0)
    svc, tel = _run_service(target_iters=16)
    assert faults.fired_count("slow") == 1
    assert tel["stragglers_flagged"] >= 1
    # flagged means deprioritized, never starved: everyone still finishes
    assert all(s.status == DONE and s.step == 16
               for s in svc.sessions.values())


def test_guard_event_log_and_step_verdicts():
    faults.configure(enabled=True)
    faults.inject("serve3d.slice", "nan_params", session="scene-000", at_step=8)
    svc = ReconstructionService(slice_iters=4)
    svc.submit_scene(_ds(0), FIELD_CFG, TRAIN_CFG, target_iters=16)
    verdicts = []
    svc.run(hook=lambda _svc, ev: verdicts.extend(ev["guard"].values()))
    assert "rolled_back" in verdicts
    events = svc.guard.session_events("scene-000")
    assert events and events[0]["event"] == "rollback"
    assert events[0]["to_step"] < events[0]["from_step"]


# ---- snapshot publish retry ----


def test_publish_failure_retains_last_good_and_retries():
    faults.configure(enabled=True)
    faults.inject("serve3d.snapshot_publish", "snapshot_fail",
                  session="scene-000", at_step=8)
    svc, tel = _run_service(n_scenes=1, target_iters=16, snapshot_every=1)
    assert faults.fired_count("snapshot_fail") == 1
    assert svc.publish_failures == 1
    snap = svc.store.latest("scene-000")
    # the retry landed: the final publish reflects the finished session
    assert snap is not None and snap.step == 16
    assert svc.sessions["scene-000"].status == DONE


# ---- render degradation ladder ----


def test_render_deadline_expires_as_typed_error():
    store = SnapshotStore()   # never publishes -> requests can only expire
    rs = RenderService(store, default_deadline_s=0.0)
    rs.register_session("s0", FIELD_CFG, RCFG, 12, 12, 30.0)
    rid = rs.submit("s0", np.eye(4))
    (err,) = rs.drain()
    assert isinstance(err, RenderError)
    assert err.request_id == rid and err.error == "deadline_expired"
    assert rs.pending == 0 and rs.expired == 1


def test_render_group_failure_retries_then_succeeds():
    faults.configure(enabled=True)
    svc, _ = _run_service(n_scenes=1, target_iters=8)
    faults.inject("serve3d.render_group", "render_fail", times=1)
    svc.request_render("scene-000", _ds(0).poses[0])
    assert svc.renderer.drain() == []          # attempt 1 fails, re-queued
    (res,) = svc.renderer.drain()              # attempt 2 succeeds
    assert not isinstance(res, RenderError) and res.rgb.shape == (12, 12, 3)


def test_render_group_failure_exhausts_to_typed_error():
    faults.configure(enabled=True)
    svc, _ = _run_service(n_scenes=1, target_iters=8)
    faults.inject("serve3d.render_group", "render_fail", times=None)
    rid = svc.request_render("scene-000", _ds(0).poses[0])
    svc.renderer.drain()
    (err,) = svc.renderer.drain()
    assert isinstance(err, RenderError)
    assert err.request_id == rid and err.error == "render_failed"
    assert svc.renderer.failed == 1 and svc.renderer.pending == 0


def test_overload_shedding_degrades_before_dropping():
    svc, _ = _run_service(n_scenes=2, target_iters=8, shed_threshold=1)
    for sid in ("scene-000", "scene-001"):
        svc.request_render(sid, _ds(0).poses[0])
    results = svc.renderer.drain()
    assert len(results) == 2                    # nothing dropped
    assert all(r.rgb.shape == (12, 12, 3) for r in results)
    assert svc.renderer.shed_drains >= 1
    stats = svc.renderer.latency_stats()
    assert stats["degraded"]["shed_fraction"] > 0


def test_stale_annotation_round_trip():
    svc, _ = _run_service(n_scenes=1, target_iters=8)
    svc.renderer.mark_stale("scene-000")
    svc.request_render("scene-000", _ds(0).poses[0])
    (res,) = svc.renderer.drain()
    assert res.stale
    svc.renderer.mark_stale("scene-000", False)
    svc.request_render("scene-000", _ds(0).poses[0])
    (res,) = svc.renderer.drain()
    assert not res.stale


# ---- suspend -> crash -> resume ----


def test_crash_resume_from_periodic_checkpoint_bit_identical(tmp_path):
    """Kill a session mid-training (its object is simply abandoned), restore
    a fresh process from the latest valid on-disk periodic checkpoint, train
    to target: the result must be bit-identical to an uninterrupted run —
    even when the newest checkpoint on disk is corrupt (fall-back path)."""
    ds = _ds(0)
    sess = SceneSession("s0", ds, FIELD_CFG, TRAIN_CFG, target_iters=32,
                        ckpt_dir=str(tmp_path / "ckpt"))
    sess.start()
    for _ in range(3):
        sess.run_slice(4)
        sess.ckpt.save(sess.step, sess.trainer.suspend(sess.state), block=True)
    # "crash": poison the newest checkpoint too — restore must fall back
    faults.corrupt_file(tmp_path / "ckpt" / "step_00000012" / "arrays.npz")

    fresh = SceneSession("s0", ds, FIELD_CFG, TRAIN_CFG, target_iters=32,
                         ckpt_dir=str(tmp_path / "ckpt"))
    fresh.resume()
    assert fresh.step == 8      # step 12 rejected, step 8 restored
    while fresh.status != DONE:
        fresh.run_slice(4)

    ref = SceneSession("s0-ref", ds, FIELD_CFG, TRAIN_CFG, target_iters=32)
    ref.start()
    while ref.status != DONE:
        ref.run_slice(4)
    assert _leaves_equal(fresh._current_params(), ref._current_params())


@settings(max_examples=4, deadline=None)
@given(fault_step=st.integers(4, 12),
       kind=st.sampled_from(["nan_params", "inf_params", "exception",
                             "nan_loss"]))
def test_recovery_bit_identity_property(fault_step, kind):
    """For any fault kind at any step: the guarded service converges to the
    exact params of a fault-free run (rollback never changes results)."""
    faults.reset()
    faults.configure(enabled=True)
    faults.inject("serve3d.slice", kind, session="scene-000",
                  at_step=fault_step)
    svc_f, tel_f = _run_service(n_scenes=1, target_iters=16)
    assert tel_f["guard"]["rollbacks"] >= 1
    assert svc_f.sessions["scene-000"].status == DONE

    faults.reset()
    faults.configure(enabled=False)
    svc_c, _ = _run_service(n_scenes=1, target_iters=16)
    assert _leaves_equal(svc_f.sessions["scene-000"]._current_params(),
                         svc_c.sessions["scene-000"]._current_params())
