"""NeRF core: field, occupancy, trainer semantics (update frequencies), e2e fit."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import Field, FieldConfig, Instant3DTrainer, TrainerConfig, occupancy
from repro.core.rendering import RenderConfig, sample_ts, render_rays
from repro.core.trainer import _branch_update
from repro.data import build_dataset, RaySampler

SMALL_FIELD = FieldConfig(n_levels=4, max_resolution=64, log2_table_density=12,
                          log2_table_color=10)


def test_field_shapes(rng):
    field = Field(SMALL_FIELD)
    params = field.init(jax.random.PRNGKey(0))
    assert params["density_grid"].shape == (4, 1 << 12, 2)
    assert params["color_grid"].shape == (4, 1 << 10, 2)  # S_D > S_C (paper §3.2)
    pts = jnp.asarray(rng.uniform(0, 1, (100, 3)).astype(np.float32))
    dirs = jnp.asarray(rng.normal(size=(100, 3)).astype(np.float32))
    dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
    sigma, rgb = field.query(params, pts, dirs)
    assert sigma.shape == (100,) and rgb.shape == (100, 3)
    assert (np.asarray(sigma) >= 0).all()
    assert ((np.asarray(rgb) >= 0) & (np.asarray(rgb) <= 1)).all()


def test_ngp_baseline_field(rng):
    """decomposed=False is the Instant-NGP baseline (single grid)."""
    cfg = FieldConfig(n_levels=4, max_resolution=64, log2_table_density=12,
                      decomposed=False)
    field = Field(cfg)
    params = field.init(jax.random.PRNGKey(0))
    assert "color_grid" not in params
    pts = jnp.asarray(rng.uniform(0, 1, (50, 3)).astype(np.float32))
    dirs = jnp.ones((50, 3)) / np.sqrt(3)
    sigma, rgb = field.query(params, pts, dirs)
    assert sigma.shape == (50,)


def test_update_frequency_schedule():
    """F_D:F_C = 1:0.5 -> color updates on every 2nd iteration (paper §5.1)."""
    updates = [_branch_update(i, 0.5) for i in range(8)]
    assert sum(updates) == 4
    assert all(_branch_update(i, 1.0) for i in range(8))
    third = [_branch_update(i, 1 / 3) for i in range(9)]
    assert sum(third) == 3


def test_freeze_step_keeps_color_grid_fixed(rng):
    scene, ds = build_dataset(seed=1, n_views=3, h=16, w=16,
                              cfg=RenderConfig(n_samples=8), gt_samples=16)
    field = Field(SMALL_FIELD)
    tcfg = TrainerConfig(n_rays=64, render=RenderConfig(n_samples=8), use_occupancy=False)
    tr = Instant3DTrainer(field, tcfg)
    state = tr.init(jax.random.PRNGKey(0))
    sampler = RaySampler(ds)
    batch = sampler.sample(jax.random.PRNGKey(1), 64)
    ts = sample_ts(jax.random.PRNGKey(2), 64, tcfg.render)
    occ = occupancy.init_state(tcfg.occ).density_ema

    step = tr.step_fn(freeze_color=True)
    # snapshot BEFORE the call: the step donates params/opt buffers
    before_color = np.asarray(state.params["color_grid"]).copy()
    before_density = np.asarray(state.params["density_grid"]).copy()
    params, opt_state, loss, _ = step(state.params, state.opt_state, batch, ts, occ)
    np.testing.assert_array_equal(np.asarray(params["color_grid"]), before_color)
    # density grid must have moved
    assert not np.array_equal(np.asarray(params["density_grid"]), before_density)


def test_e2e_reconstruction_quality():
    """Short CPU training must reach a sane PSNR on a procedural scene."""
    rcfg = RenderConfig(n_samples=24)
    scene, ds = build_dataset(seed=0, n_views=8, h=32, w=32, cfg=rcfg, gt_samples=96)
    field = Field(FieldConfig(n_levels=6, max_resolution=96, log2_table_density=13,
                              log2_table_color=11))
    tcfg = TrainerConfig(n_rays=512, iters=120, render=rcfg,
                         occ=occupancy.OccupancyConfig(update_interval=16, warmup_steps=32))
    tr = Instant3DTrainer(field, tcfg)
    state = tr.init(jax.random.PRNGKey(0))
    state, hist = tr.train(state, RaySampler(ds), log_every=60)
    ev = tr.evaluate(state.params, ds, views=[0])
    assert ev["psnr_rgb"] > 20.0, ev
    assert hist["loss"][-1] < hist["loss"][0]


def test_occupancy_grid_culls_empty_space(rng):
    field = Field(SMALL_FIELD)
    params = field.init(jax.random.PRNGKey(0))
    ocfg = occupancy.OccupancyConfig(resolution=8, density_threshold=1e9)  # cull all
    state = occupancy.init_state(ocfg)
    state = occupancy.update(field, params, state, ocfg, jax.random.PRNGKey(1))
    mask = occupancy.occupied_mask_fn(state, ocfg)
    pts = jnp.asarray(rng.uniform(0, 1, (64, 3)).astype(np.float32))
    assert not np.asarray(mask(pts)).any()
    assert float(occupancy.occupancy_fraction(state, ocfg)) == 0.0
