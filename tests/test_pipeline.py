"""Staged RenderPipeline: compaction correctness, gradients, backend registry."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import kernels
from repro.core import Field, FieldConfig, occupancy
from repro.core.pipeline import RenderPipeline, suggest_budget, _cube_root
from repro.core.rendering import RenderConfig, sample_ts, render_rays

FIELD_CFG = FieldConfig(n_levels=4, max_resolution=64, log2_table_density=12,
                        log2_table_color=10)
RCFG = RenderConfig(n_samples=16)
OCFG = occupancy.OccupancyConfig(resolution=8)


def _rays(rng, b):
    origins = jnp.asarray(rng.uniform(-0.5, 0.5, (b, 3)).astype(np.float32))
    origins = origins.at[:, 2].set(4.0)  # look down at the box from above
    dirs = jnp.asarray(rng.normal(size=(b, 3)).astype(np.float32))
    dirs = dirs.at[:, 2].set(-jnp.abs(dirs[:, 2]) - 1.0)
    return origins, dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)


def _setup(rng, b=32):
    field = Field(FIELD_CFG)
    params = field.init(jax.random.PRNGKey(0))
    origins, dirs = _rays(rng, b)
    ts = sample_ts(jax.random.PRNGKey(1), b, RCFG)
    return field, params, origins, dirs, ts


def _all_occupied():
    return jnp.ones((OCFG.resolution ** 3,), bool)


def _half_occupied():
    """Occupy only cells with z in the lower half of the unit cube."""
    r = OCFG.resolution
    centers = occupancy.cell_centers(OCFG)
    return (centers[:, 2] < 0.5).reshape(-1)


@pytest.mark.parametrize("bits_fn", [_all_occupied, _half_occupied])
def test_compacted_matches_dense(bits_fn, rng):
    """Compacted outputs == dense-masked outputs whenever budget >= n_live."""
    field, params, origins, dirs, ts = _setup(rng)
    pipe = RenderPipeline(field, RCFG)
    bits = bits_fn()
    n = ts.size

    dense = pipe(params, origins, dirs, ts, bitfield=bits)
    compacted = pipe(params, origins, dirs, ts, bitfield=bits, budget=n)
    assert int(compacted["overflow"]) == 0
    for k in ("rgb", "depth", "opacity"):
        np.testing.assert_allclose(
            np.asarray(compacted[k]), np.asarray(dense[k]), atol=1e-5,
            err_msg=f"{k} mismatch (bits={bits_fn.__name__})",
        )
    np.testing.assert_allclose(
        float(compacted["live_fraction"]), float(dense["live_fraction"]), atol=1e-6
    )


def test_compacted_matches_dense_tight_budget(rng):
    """With culled cells, a budget between n_live and n must still be exact."""
    field, params, origins, dirs, ts = _setup(rng)
    pipe = RenderPipeline(field, RCFG)
    bits = _half_occupied()
    n = ts.size

    dense = pipe(params, origins, dirs, ts, bitfield=bits)
    n_live = int(dense["n_live"])
    assert 0 < n_live < n, "test scene should cull some but not all samples"
    budget = 1 << (n_live - 1).bit_length()  # next pow2 >= n_live, < n
    assert budget < n

    compacted = pipe(params, origins, dirs, ts, bitfield=bits, budget=budget)
    assert int(compacted["overflow"]) == 0
    assert int(compacted["points_queried"]) == budget
    for k in ("rgb", "depth", "opacity"):
        np.testing.assert_allclose(
            np.asarray(compacted[k]), np.asarray(dense[k]), atol=1e-5,
            err_msg=f"{k} mismatch at budget {budget} (n_live {n_live})",
        )


def test_compaction_gradients_match_dense(rng):
    """Gather/scatter must be differentiable and gradient-equivalent."""
    field, params, origins, dirs, ts = _setup(rng)
    pipe = RenderPipeline(field, RCFG)
    bits = _half_occupied()
    n = ts.size
    target = jnp.asarray(rng.uniform(0, 1, (origins.shape[0], 3)).astype(np.float32))

    def loss(p, budget):
        out = pipe(p, origins, dirs, ts, bitfield=bits, budget=budget)
        return jnp.mean((out["rgb"] - target) ** 2)

    g_dense = jax.grad(loss)(params, None)
    g_comp = jax.grad(loss)(params, n)
    leaves_d = jax.tree_util.tree_leaves_with_path(g_dense)
    leaves_c = jax.tree_util.tree_leaves(g_comp)
    max_abs = max(float(jnp.abs(x).max()) for _, x in leaves_d)
    assert max_abs > 0, "degenerate test: zero gradient"
    for (path, d), c in zip(leaves_d, leaves_c):
        np.testing.assert_allclose(np.asarray(c), np.asarray(d), atol=1e-5,
                                   err_msg=f"grad mismatch at {path}")


def test_overflow_accounting(rng):
    """A budget below n_live must report the dropped live points."""
    field, params, origins, dirs, ts = _setup(rng)
    pipe = RenderPipeline(field, RCFG)
    out_dense = pipe(params, origins, dirs, ts, bitfield=_all_occupied())
    n_live = int(out_dense["n_live"])
    budget = max(1, n_live // 2)
    out = pipe(params, origins, dirs, ts, bitfield=_all_occupied(), budget=budget)
    assert int(out["overflow"]) == n_live - budget
    assert int(out["points_queried"]) == budget


def test_render_rays_wrapper_matches_pipeline(rng):
    """The legacy render_rays signature is a thin wrapper over the dense path."""
    field, params, origins, dirs, ts = _setup(rng)
    pipe = RenderPipeline(field, RCFG)
    bits = _half_occupied()
    mask_fn = lambda unit: occupancy.point_liveness(bits, unit, OCFG.resolution)
    legacy = render_rays(field, params, origins, dirs, ts, RCFG, mask_fn)
    staged = pipe(params, origins, dirs, ts, bitfield=bits)
    np.testing.assert_allclose(np.asarray(legacy["rgb"]), np.asarray(staged["rgb"]),
                               atol=1e-6)


def test_suggest_budget_buckets():
    n = 4096
    assert suggest_budget(1.0, n) == n
    assert suggest_budget(0.0, n) == 512
    b = suggest_budget(0.2, n)
    assert b >= int(0.2 * 1.3 * n) and b & (b - 1) == 0  # pow2, has headroom
    # bucketing: nearby fractions share a bucket (bounded recompiles)
    assert suggest_budget(0.15, n) == suggest_budget(0.18, n)


def test_cube_root():
    assert _cube_root(8 ** 3) == 8
    assert _cube_root(32 ** 3) == 32
    with pytest.raises(ValueError):
        _cube_root(100)


def test_backend_registry():
    assert "ref" in kernels.available_backends()
    ref = kernels.resolve_backend("ref")
    assert not ref.use_pallas
    pal = kernels.resolve_backend("pallas")  # alias: best flavor for platform
    assert pal.use_pallas
    with pytest.raises(ValueError):
        kernels.resolve_backend("cuda")
    # the one user-facing knob: process default; explicit names still override
    prev = kernels.get_backend()
    try:
        assert kernels.set_backend("ref") == ref
        assert kernels.resolve_backend(None) == ref
    finally:
        kernels.set_backend(prev)


def test_configs_have_no_backend_knob():
    """The registry is the single user-facing backend knob (ISSUE 1)."""
    from repro.core.encoding import HashGridConfig
    for cfg_cls in (FieldConfig, HashGridConfig, RenderConfig):
        assert "backend" not in cfg_cls.__dataclass_fields__, cfg_cls
