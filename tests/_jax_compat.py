"""Capability gates for tests written against newer jax APIs.

The container pins jax 0.4.37; a few substrate tests use the newer sharding
API generation (`jax.sharding.AxisType`, the positional `AbstractMesh`
signature, `jax.set_mesh`).  Gate them on a feature probe instead of a
version compare so they re-enable automatically when jax is upgraded.
"""
import jax
import pytest

HAS_NEW_SHARDING_API = hasattr(jax.sharding, "AxisType")

requires_new_sharding_api = pytest.mark.skipif(
    not HAS_NEW_SHARDING_API,
    reason="needs the jax.sharding AxisType-era API (newer jax)",
)
