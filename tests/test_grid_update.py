"""Kernel validation: BUM merged scatter — merged == naive, Pallas == naive."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.kernels.grid_update import ref, ops, kernel


@pytest.mark.parametrize("t,f,m", [(64, 2, 300), (512, 2, 3000), (128, 4, 999), (16, 1, 64)])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_merged_matches_naive(t, f, m, use_pallas, rng):
    table = jnp.asarray(rng.normal(size=(t, f)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, t, size=m).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(m, f)).astype(np.float32))
    naive = ref.scatter_add(table, idx, vals)
    merged = ops.merged_scatter_add(table, idx, vals, use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(naive), atol=1e-4, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([16, 64, 256]),
    m=st.integers(1, 400),
    seed=st.integers(0, 2**31 - 1),
    heavy_collisions=st.booleans(),
)
def test_merge_property(t, m, seed, heavy_collisions):
    """Property: for ANY update stream, merged result == naive scatter-add."""
    r = np.random.default_rng(seed)
    hi = max(t // 16, 1) if heavy_collisions else t
    idx = jnp.asarray(r.integers(0, hi, size=m).astype(np.int32))
    vals = jnp.asarray(r.normal(size=(m, 2)).astype(np.float32))
    table = jnp.zeros((t, 2), jnp.float32)
    naive = ref.scatter_add(table, idx, vals)
    merged = ops.merged_scatter_add(table, idx, vals)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(naive), atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([16, 64, 256]),
    m=st.integers(1, 400),
    seed=st.integers(0, 2**31 - 1),
    heavy_collisions=st.booleans(),
)
def test_presorted_property(t, m, seed, heavy_collisions):
    """Property: on an address-sorted stream, presorted=True (skip argsort)
    is BIT-identical to the unsorted path — stable argsort of sorted input
    is the identity, so both run the same segment merge."""
    r = np.random.default_rng(seed)
    hi = max(t // 16, 1) if heavy_collisions else t
    idx = np.sort(r.integers(0, hi, size=m).astype(np.int32))
    vals = jnp.asarray(r.normal(size=(m, 2)).astype(np.float32))
    table = jnp.asarray(r.normal(size=(t, 2)).astype(np.float32))
    idx = jnp.asarray(idx)
    fast = ops.merged_scatter_add(table, idx, vals, presorted=True)
    slow = ops.merged_scatter_add(table, idx, vals, presorted=False)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(slow))


def test_presorted_pallas_matches(rng):
    """presorted routing also reaches the Pallas commit kernel unchanged."""
    t, m = 128, 500
    idx = jnp.asarray(np.sort(rng.integers(0, t, size=m)).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(m, 2)).astype(np.float32))
    table = jnp.zeros((t, 2), jnp.float32)
    naive = ref.scatter_add(table, idx, vals)
    fast = ops.merged_scatter_add(table, idx, vals, use_pallas=True, presorted=True)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(naive), atol=1e-4, rtol=1e-5)


def test_unique_counting(rng):
    idx = jnp.asarray(np.array([1, 1, 2, 5, 5, 5, 9], np.int32))
    assert int(ops.num_unique_addresses(idx)) == 4


def test_merge_reduces_writes(rng):
    """The architectural claim (paper Fig. 10): backward streams have ~5x
    address duplication, so the merged stream is much shorter."""
    m = 8000
    idx = jnp.asarray(rng.integers(0, 1000, size=m).astype(np.int32))  # duplicates
    uniq = int(ops.num_unique_addresses(idx))
    assert uniq < m / 5


@pytest.mark.parametrize("freq,expected_w", [(1.0, 1), (0.5, 2), (0.25, 4)])
@pytest.mark.parametrize("presorted", [True, False])
def test_windowed_stacked_commits_match_per_step_across_schedules(
        freq, expected_w, presorted, rng):
    """BUM across iterations: gradient streams accumulated over an F_D:F_C
    update-frequency window ({1:1, 1:0.5, 1:0.25}) and committed as ONE
    stacked windowed call are BIT-identical to committing every step's
    stream sequentially — additivity buys merging, not reassociation.  The
    window boundaries come from the trainer's real schedule predicate."""
    from repro.core.trainer import _branch_update

    t, f, m = 96, 2, 200
    table_seq = jnp.asarray(rng.normal(size=(t, f)).astype(np.float32))
    table_win = table_seq
    pending_idx, pending_vals = [], []
    for i in range(8):
        idx = rng.integers(0, t, size=m).astype(np.int32)
        if presorted:
            idx = np.sort(idx)
        idx = jnp.asarray(idx)
        vals = jnp.asarray(rng.normal(size=(m, f)).astype(np.float32))
        pending_idx.append(idx)
        pending_vals.append(vals)
        table_seq = ops.merged_scatter_add(table_seq, idx, vals,
                                           presorted=presorted)
        if _branch_update(i, freq):
            assert len(pending_idx) == expected_w
            table_win = ops.windowed_scatter_add(
                table_win, jnp.stack(pending_idx), jnp.stack(pending_vals),
                presorted=presorted,
            )
            pending_idx, pending_vals = [], []
    assert not pending_idx  # every stream committed (schedule flushed)
    np.testing.assert_array_equal(np.asarray(table_win), np.asarray(table_seq))


def test_windowed_stacked_pallas_matches_xla(rng):
    """The stacked form's per-window Pallas commit stays allclose to the
    XLA segment merge (same contract as merged_scatter_add)."""
    t, f, w, m = 64, 2, 3, 150
    table = jnp.asarray(rng.normal(size=(t, f)).astype(np.float32))
    idx = jnp.asarray(np.sort(rng.integers(0, t, size=(w, m)).astype(np.int32), axis=1))
    vals = jnp.asarray(rng.normal(size=(w, m, f)).astype(np.float32))
    got = ops.windowed_scatter_add(table, idx, vals, presorted=True, use_pallas=True)
    want = ops.windowed_scatter_add(table, idx, vals, presorted=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("m,window", [(100, 32), (1000, 256), (64, 64), (10, 16)])
def test_windowed_merge_matches_naive(m, window, rng):
    """The sliding-window BUM (paper-faithful bounded merge) is exact too —
    merging within windows then scattering each window accumulates to the
    same table as the naive duplicate scatter."""
    t, f = 128, 3
    table = jnp.asarray(rng.normal(size=(t, f)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, t, size=m).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(m, f)).astype(np.float32))
    naive = ref.scatter_add(table, idx, vals)
    windowed = ops.windowed_scatter_add(table, idx, vals, window=window)
    np.testing.assert_allclose(np.asarray(windowed), np.asarray(naive), atol=1e-4, rtol=1e-4)
