"""Property-test suite for redistribute v3 (pipeline stage 2b).

The ISSUE 9 invariants, asserted over random occupancy/EMA/budget draws
(hypothesis when installed, the deterministic shim otherwise):

1. budget conservation — `sum(S'_i) <= budget` ALWAYS, with a floor of 1
   per ray (the allocation telescopes a floor'd CDF, so this is checked as
   a property, not proved only on the happy path);
2. per-ray CDF monotone non-decreasing and normalized (last entry ~ 1);
3. quadrature deltas per ray sum to the ray's total live segment length
   (dead rays: the full near-far span, the uniform-fallback convention);
4. every placed (valid-lane) sample falls in a live stratum;
5. knob-off path is bit-identical to v2 / uniform via the never-traced
   monkeypatch-raiser pattern from PR 4.

Draws are integer seeds expanded through numpy's PRNG on the host — the
shim's strategy surface (integers/booleans/sampled_from) is all that's
needed, and every failing example reproduces from its printed seed.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Field, FieldConfig, Instant3DTrainer, TrainerConfig, occupancy
from repro.core.pipeline import RenderPipeline
from repro.core.rendering import RenderConfig
from repro.data import build_dataset, RaySampler

from _hypothesis_shim import given, settings, strategies as st

FIELD_CFG = FieldConfig(n_levels=4, max_resolution=64, log2_table_density=12,
                        log2_table_color=10)
RCFG = RenderConfig(n_samples=16)


def _draw_case(seed: int, use_ema: bool):
    """Random (pipe, ts, live, ema, budget) from one integer seed."""
    rng = np.random.default_rng(seed)
    b = int(rng.integers(2, 24))
    s = int(rng.integers(4, 33))
    budget = int(rng.integers(b, 4 * b * s + 1))
    cfg = RenderConfig(n_samples=s)
    pipe = RenderPipeline(None, cfg, redistribute_v3=True,
                          v3_oversub=int(rng.integers(2, 7)))
    h = (cfg.far - cfg.near) / s
    jit = rng.random((b, s), dtype=np.float32)
    ts = (cfg.near + (np.arange(s)[None, :] + jit) * h).astype(np.float32)
    # occupancy per row: anything from fully dead to fully live
    live = rng.random((b, s)) < rng.random((b, 1)) * 1.2
    # trunc_exp densities span orders of magnitude; mimic that spread
    ema = (rng.random((b, s), dtype=np.float32) ** 4 * 50.0) if use_ema else None
    return pipe, jnp.asarray(ts), jnp.asarray(live), \
        None if ema is None else jnp.asarray(ema), budget


@settings(max_examples=30)
@given(seed=st.integers(0, 2**31 - 1), use_ema=st.booleans())
def test_budget_conservation_and_floor(seed, use_ema):
    """(1) sum(S'_i) <= budget by construction, every ray's floor of 1
    honored, and the validity mask agrees with the allocation."""
    pipe, ts, live, ema, budget = _draw_case(seed, use_ema)
    plan = pipe.v3_plan(ts, live, ema, budget)
    _, _, valid = pipe.redistribute_v3(ts, live, ema, budget)
    s_ray = np.asarray(plan["s_ray"])
    assert int(s_ray.sum()) <= budget
    assert (s_ray >= 1).all()
    assert (s_ray <= plan["s_cap"]).all()
    assert (np.asarray(valid).sum(axis=1) == s_ray).all()


@settings(max_examples=30)
@given(seed=st.integers(0, 2**31 - 1), use_ema=st.booleans())
def test_cdf_monotone_and_normalized(seed, use_ema):
    """(2) each ray's weighted CDF is monotone non-decreasing and ends at
    ~1 (f32 cumsum rounding is the only slack)."""
    pipe, ts, live, ema, budget = _draw_case(seed, use_ema)
    plan = pipe.v3_plan(ts, live, ema, budget)
    cdf = np.asarray(plan["cdf"], np.float64)
    pdf = np.asarray(plan["pdf"], np.float64)
    assert (pdf >= 0.0).all()
    assert (np.diff(cdf, axis=1) >= -1e-7).all()
    np.testing.assert_allclose(cdf[:, -1], 1.0, rtol=1e-5)


@settings(max_examples=30)
@given(seed=st.integers(0, 2**31 - 1), use_ema=st.booleans())
def test_deltas_sum_to_live_length(seed, use_ema):
    """(3) valid-lane quadrature deltas sum per ray to the live segment
    length (dead rays: the full span); invalid lanes carry exactly 0."""
    pipe, ts, live, ema, budget = _draw_case(seed, use_ema)
    _, deltas, valid = pipe.redistribute_v3(ts, live, ema, budget)
    plan = pipe.v3_plan(ts, live, ema, budget)
    s = ts.shape[1]
    h = (pipe.cfg.far - pipe.cfg.near) / s
    live_len = np.asarray(live).sum(axis=1) * h
    target = np.where(np.asarray(plan["dead"]),
                      pipe.cfg.far - pipe.cfg.near, live_len)
    d = np.asarray(deltas, np.float64)
    assert (d[~np.asarray(valid)] == 0.0).all()
    np.testing.assert_allclose(d.sum(axis=1), target, rtol=1e-5, atol=1e-6)


@settings(max_examples=30)
@given(seed=st.integers(0, 2**31 - 1), use_ema=st.booleans())
def test_samples_land_in_live_strata(seed, use_ema):
    """(4) every valid placed sample falls in a live stratum (rays with no
    live stratum are exempt: they take the uniform fallback), and ts stays
    monotone non-decreasing per ray with invalid lanes parked at far."""
    pipe, ts, live, ema, budget = _draw_case(seed, use_ema)
    ts_new, _, valid = pipe.redistribute_v3(ts, live, ema, budget)
    plan = pipe.v3_plan(ts, live, ema, budget)
    s = ts.shape[1]
    near, far = pipe.cfg.near, pipe.cfg.far
    h = (far - near) / s
    tsn = np.asarray(ts_new)
    live_np = np.asarray(live)
    dead = np.asarray(plan["dead"])
    stratum = np.clip(((tsn - near) / h).astype(np.int64), 0, s - 1)
    for i in range(tsn.shape[0]):
        assert (np.diff(tsn[i]) >= -1e-6).all()
        assert (tsn[i][~np.asarray(valid)[i]] == np.float32(far)).all()
        if dead[i]:
            continue
        ks = np.asarray(valid)[i]
        assert live_np[i][stratum[i][ks]].all(), \
            f"ray {i}: sample outside live strata"


# ---- (5) knob-off bit-identity (never-traced raiser pattern) ----


def _short_train(forbid_v3: bool = False, forbid_v2: bool = False, **cfg_kw):
    ds = build_dataset(seed=0, n_views=4, h=16, w=16, cfg=RCFG, gt_samples=48)[1]
    tcfg = TrainerConfig(
        n_rays=128, iters=24, render=RCFG, min_budget=128,
        occ=occupancy.OccupancyConfig(resolution=8, update_interval=8,
                                      warmup_steps=8),
        **cfg_kw,
    )
    tr = Instant3DTrainer(Field(FIELD_CFG), tcfg)
    if forbid_v3:
        def _boom_v3(*a, **k):
            raise AssertionError("redistribute_v3 traced with the knob off")
        tr.pipeline.redistribute_v3 = _boom_v3
    if forbid_v2:
        def _boom_v2(*a, **k):
            raise AssertionError("redistribute (v2) traced with the knob off")
        tr.pipeline.redistribute = _boom_v2
    state = tr.init(jax.random.PRNGKey(0))
    state, hist = tr.train(state, RaySampler(ds), iters=tcfg.iters, log_every=8)
    return state, hist


def _assert_states_equal(sa, sb):
    za = jax.tree_util.tree_leaves_with_path((sa.params, sa.opt_state,
                                              sa.occ_state))
    zb = jax.tree_util.tree_leaves((sb.params, sb.opt_state, sb.occ_state))
    for (p, a), b in zip(za, zb):
        assert bool(np.array_equal(np.asarray(a), np.asarray(b))), \
            f"state drift at {p}"


def test_v3_off_never_traced_and_bit_identical():
    """(5) with redistribute_v3 off the v3 stage is never traced (raiser on
    the method survives a full training run) and the whole train state —
    params, optimizer moments, occupancy EMA — is bit-identical to a run
    without the raiser."""
    s1, h1 = _short_train(forbid_v3=True, forbid_v2=True, max_budget=256)
    s2, h2 = _short_train(max_budget=256)
    _assert_states_equal(s1, s2)
    assert h1["loss"] == h2["loss"]


def test_v2_path_untouched_by_v3_code():
    """(5b) the v2 knob still runs the PR 4 stage with the v3 method never
    traced — v3's presence cannot perturb the committed v2 numbers."""
    s1, h1 = _short_train(forbid_v3=True, redistribute=True, max_budget=256)
    s2, h2 = _short_train(redistribute=True, max_budget=256)
    _assert_states_equal(s1, s2)
    assert h1["loss"] == h2["loss"]


def test_v3_on_trains_within_budget():
    """v3 end-to-end: finite losses, ceiling honored, zero overflow by
    construction (ragged packing never exceeds the compact budget)."""
    state, hist = _short_train(redistribute_v3=True, max_budget=256)
    assert all(np.isfinite(hist["loss"]))
    assert hist["points_queried"][-1] <= 256
    assert hist["overflow_total"] == 0


def test_v3_equals_v2_under_uniform_weights_allocation():
    """With ema=None and every stratum live, the weighted CDF degenerates
    to v2's uniform live CDF (pdf rows exactly 1/S) and the allocation
    splits the budget evenly — the even split is the stratified-CDF fixed
    point for equal masses, so v3 contains v2's S' = budget // B as its
    homogeneous special case."""
    b, s, budget = 8, 16, 64
    cfg = RenderConfig(n_samples=s)
    pipe = RenderPipeline(None, cfg, redistribute_v3=True)
    rng = np.random.default_rng(3)
    h = (cfg.far - cfg.near) / s
    ts = jnp.asarray((cfg.near + (np.arange(s)[None, :]
                                  + rng.random((b, s), dtype=np.float32)) * h)
                     .astype(np.float32))
    live = jnp.ones((b, s), bool)
    plan = pipe.v3_plan(ts, live, None, budget)
    np.testing.assert_array_equal(np.asarray(plan["s_ray"]),
                                  np.full(b, budget // b))
    # uniform weights: pdf rows are exactly 1/S
    np.testing.assert_allclose(np.asarray(plan["pdf"]), 1.0 / s, rtol=1e-6)


# ---- occupancy mass/mask degeneration (ISSUE 9 small fix) ----


@settings(max_examples=20)
@given(seed=st.integers(0, 2**31 - 1))
def test_ray_segment_mass_degrades_to_mask(seed):
    """Thresholding the EMA-weighted mass recovers the binary mask exactly:
    `ray_segment_mass(...) > 0 == ray_segment_mask(bits, ...)` whenever
    bits = ema > threshold (the folded-state bitfield)."""
    rng = np.random.default_rng(seed)
    r = int(rng.choice([4, 8]))
    thr = 0.05
    ema = jnp.asarray((rng.random(r ** 3, dtype=np.float32) ** 2) * 0.5)
    bits = ema > thr
    mids = jnp.asarray(rng.random((6, 12, 3), dtype=np.float32) * (1 - 1e-6))
    mass = occupancy.ray_segment_mass(ema, mids, r, thr)
    mask = occupancy.ray_segment_mask(bits, mids, r)
    np.testing.assert_array_equal(np.asarray(mass) > 0, np.asarray(mask))
    # where live, the mass is the cell's EMA itself
    d = occupancy.point_density(ema, mids, r)
    np.testing.assert_array_equal(
        np.asarray(mass), np.where(np.asarray(mask), np.asarray(d), 0.0))
