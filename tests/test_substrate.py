"""Substrate: optimizer, checkpoint (atomic/elastic), driver, data, collectives."""
import os
import signal
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_shim import given, settings, strategies as st  # noqa: F401
from _jax_compat import requires_new_sharding_api

from repro.optim import AdamW, schedule, clip_by_global_norm
from repro.checkpoint import CheckpointManager
from repro.runtime import TrainDriver, DriverConfig, StragglerStats, resume_or_init
from repro.data import SyntheticLMStream, LMStreamConfig
from repro.parallel.collectives import compressed_psum_mean, _quantize, _dequantize


# ---- optimizer ----

def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.apply(params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_mask_freezes_moments_and_params():
    opt = AdamW(lr=0.1)
    params = {"a": jnp.ones(3), "b": jnp.ones(3)}
    state = opt.init(params)
    grads = {"a": jnp.ones(3), "b": jnp.ones(3)}
    mask = {"a": True, "b": False}
    p1, s1 = opt.apply(params, grads, state, mask=mask)
    np.testing.assert_array_equal(np.asarray(p1["b"]), np.asarray(params["b"]))
    np.testing.assert_array_equal(np.asarray(s1.m["b"]), 0.0)
    assert not np.array_equal(np.asarray(p1["a"]), np.asarray(params["a"]))


def test_lr_schedule_shapes():
    fn = schedule.warmup_cosine(1.0, 10, 100, floor=0.1)
    assert float(fn(0)) == 0.0
    assert abs(float(fn(10)) - 1.0) < 1e-6
    assert abs(float(fn(100)) - 0.1) < 1e-6
    assert float(fn(55)) > 0.1


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


# ---- checkpointing ----

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2, async_save=False)
    tree = {"w": jnp.arange(10, dtype=jnp.float32), "nested": {"b": jnp.ones((2, 3))}}
    for step in (10, 20, 30):
        mgr.save(step, jax.tree.map(lambda t: t + step, tree))
    assert mgr.all_steps() == [20, 30]  # keep_last=2 GC'd step 10
    restored, meta = mgr.restore(tree)
    assert meta["step"] == 30
    np.testing.assert_allclose(np.asarray(restored["w"]), np.arange(10) + 30)


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, {"w": jnp.ones(4)})
    mgr.save(2, {"w": jnp.ones(4) * 2})
    # corrupt the latest
    npz = tmp_path / "step_00000002" / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[:-8] + b"deadbeef")
    restored, meta = mgr.restore({"w": jnp.ones(4)})
    assert meta["step"] == 1  # fell back to the previous valid snapshot


@requires_new_sharding_api
def test_checkpoint_elastic_mesh_change(tmp_path):
    """Save on one layout, restore sharded onto another (elastic scaling)."""
    from jax.sharding import PartitionSpec as P, NamedSharding
    n = jax.device_count()
    mesh_a = jax.make_mesh((1, 1), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(5, tree)
    sh = {"w": NamedSharding(mesh_a, P("data", None))}
    restored, _ = mgr.restore(tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(tree["w"]))


# ---- driver ----

def _fake_step(state, batch):
    return state + 1, {"loss": float(batch["x"])}


def test_driver_runs_and_checkpoints(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", async_save=False)
    drv = TrainDriver(DriverConfig(total_steps=7, checkpoint_every=3, log_every=2,
                                   metrics_path=str(tmp_path / "m.jsonl")), mgr)
    batches = iter([{"x": i} for i in range(100)])
    state, summary = drv.run(jnp.zeros(()), _fake_step, batches)
    assert int(state) == 7 and not summary["preempted"]
    assert mgr.latest_step() == 7


def test_driver_preemption(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", async_save=False)
    drv = TrainDriver(DriverConfig(total_steps=1000, checkpoint_every=10**6), mgr)

    calls = {"n": 0}
    def step(state, batch):
        calls["n"] += 1
        if calls["n"] == 5:
            drv._preempted = True  # simulate SIGTERM mid-training
        return state + 1, {}

    batches = iter([{"x": i} for i in range(100)])
    state, summary = drv.run(jnp.zeros(()), step, batches)
    assert summary["preempted"] and int(state) == 5
    assert mgr.latest_step() == 5  # checkpoint written on the way out


def test_resume_or_init(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    tmpl = {"w": jnp.zeros(3)}
    state, cursor = resume_or_init(mgr, tmpl, lambda: {"w": jnp.ones(3)})
    assert cursor == 0 and float(state["w"][0]) == 1.0
    mgr.save(42, {"w": jnp.full(3, 7.0)}, extra={"data_cursor": 42})
    state, cursor = resume_or_init(mgr, tmpl, lambda: {"w": jnp.ones(3)})
    assert cursor == 42 and float(state["w"][0]) == 7.0


def test_straggler_detector():
    s = StragglerStats()
    flags = [s.update(1.0, sigma=4.0, alpha=0.1) for _ in range(20)]
    assert not any(flags)
    assert s.update(10.0, sigma=4.0, alpha=0.1)  # 10x outlier flagged
    assert s.n_flagged == 1


# ---- data ----

def test_lm_stream_determinism_and_sharding():
    cfg = LMStreamConfig(vocab=128, seq=16, global_batch=8, seed=3)
    ds = SyntheticLMStream(cfg)
    a = ds.batch(step=5, dp_rank=0, dp_size=2)
    b = ds.batch(step=5, dp_rank=0, dp_size=2)
    np.testing.assert_array_equal(a, b)  # deterministic restart
    c = ds.batch(step=5, dp_rank=1, dp_size=2)
    assert not np.array_equal(a, c)  # shards differ
    assert a.shape == (4, 16)
    # learnable structure: bigrams come from the fixed successor table
    succ = ds.successors
    for row in a:
        for t in range(len(row) - 1):
            assert row[t + 1] in succ[row[t]]


# ---- compressed collectives ----

def test_quantize_roundtrip_error_bounded(rng):
    g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = _quantize(g)
    err = np.abs(np.asarray(_dequantize(q, s) - g))
    assert err.max() <= float(s) * 0.5 + 1e-6


@requires_new_sharding_api
def test_compressed_psum_matches_exact_mean():
    """Single-device axis: compressed psum == quantized identity; multi-step
    error feedback drives the accumulated bias to zero."""
    mesh = jax.make_mesh((1,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import PartitionSpec as P
    g = jnp.asarray(np.linspace(-1, 1, 64, dtype=np.float32))
    err = jnp.zeros_like(g)
    fn = jax.shard_map(lambda gg, ee: compressed_psum_mean(gg, ee, "pod"),
                       mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                       check_vma=False)
    total = jnp.zeros_like(g)
    exact = jnp.zeros_like(g)
    for _ in range(50):  # error feedback: accumulated sums converge
        out, err = fn(g, err)
        total = total + out
        exact = exact + g
    rel = float(jnp.linalg.norm(total - exact) / jnp.linalg.norm(exact))
    assert rel < 0.01, rel
