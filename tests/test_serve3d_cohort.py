"""Cohort stacking: member-axis train steps vs sequential, scheduling, serving.

The contract under test is the PR's tentpole: stacking sessions into a train
cohort (one compiled member-axis step per iteration) must be a pure
throughput change — params, optimizer moments and occupancy EMA stay
bit-identical to sequential time-slicing, across cohort sizes, member
orders, budget splits, and suspend/resume boundaries.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    Field, FieldConfig, Instant3DTrainer, TrainerConfig, occupancy, train_cohort,
)
from repro.core.rendering import RenderConfig
from repro.data import build_dataset, RaySampler
from repro.serve3d import ReconstructionService, RenderService, SceneSession

RCFG = RenderConfig(n_samples=8)
FIELD_CFG = FieldConfig(n_levels=2, max_resolution=32, log2_table_density=10,
                        log2_table_color=8, hidden=16)
OCFG = occupancy.OccupancyConfig(resolution=16, update_interval=4, warmup_steps=2)
# min_budget below n_rays * n_samples so compaction budgets actually engage
TRAIN_CFG = TrainerConfig(n_rays=64, render=RCFG, occ=OCFG, eval_chunk=144,
                          min_budget=64)
M = 3


@pytest.fixture(scope="module")
def datasets():
    out = []
    for seed in range(M):
        _scene, ds = build_dataset(seed=seed, n_views=2, h=12, w=12,
                                   cfg=RCFG, gt_samples=24)
        out.append(ds)
    return out


def _fresh(datasets, k, cfg=TRAIN_CFG):
    tr = Instant3DTrainer(Field(FIELD_CFG), cfg)
    return tr, tr.init(jax.random.PRNGKey(k)), RaySampler(datasets[k])


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def _state_equal(a, b):
    return (_leaves_equal(a.params, b.params)
            and _leaves_equal(a.opt_state, b.opt_state)
            and np.array_equal(np.asarray(a.occ_state.density_ema),
                               np.asarray(b.occ_state.density_ema))
            and int(a.occ_state.step) == int(b.occ_state.step))


# ---- core: cohort == sequential, bit for bit ----


def test_cohort_matches_sequential_bit_identical(datasets):
    """One member-axis step over M stacked sessions == M sequential train()
    runs: params, optimizer moments AND occupancy EMA, plus the trainers'
    budget bookkeeping (live fraction, overflow window)."""
    seq = [_fresh(datasets, k) for k in range(M)]
    seq_states, seq_hists = [], []
    for tr, st, sa in seq:
        st, hist = tr.train(st, sa, iters=16, log_every=16)
        seq_states.append(st)
        seq_hists.append(hist)

    trs, sts, sas = zip(*[_fresh(datasets, k) for k in range(M)])
    coh_states, hists = train_cohort(list(trs), list(sts), list(sas),
                                     iters=16, log_every=16)
    for k in range(M):
        assert _state_equal(seq_states[k], coh_states[k]), f"member {k}"
        assert trs[k]._live_frac == seq[k][0]._live_frac
        assert ([int(v) for v in trs[k]._overflow_window]
                == [int(v) for v in seq[k][0]._overflow_window])
        assert hists[k]["loss"] == seq_hists[k]["loss"]
        assert hists[k]["live_fraction"] == seq_hists[k]["live_fraction"]
        assert hists[k]["overflow_total"] == seq_hists[k]["overflow_total"]


def test_cohort_m_and_order_invariance(datasets):
    """A member's stream does not depend on cohort size or its slot: the
    scan-batched member axis is trip-count- and order-invariant (this is the
    property that makes lax.map the right batching choice over vmap, which
    reassociates CPU reductions)."""
    def run(members):
        trs, sts, sas = zip(*[_fresh(datasets, k) for k in members])
        states, _ = train_cohort(list(trs), list(sts), list(sas),
                                 iters=12, log_every=12)
        return dict(zip(members, states))

    solo = run([1])
    pair = run([0, 1])
    rev = run([1, 0])
    trio = run([0, 1, 2])
    for out in (pair, rev, trio):
        assert _state_equal(solo[1], out[1])


def test_budget_split_cohort_stays_bit_identical(datasets):
    """Members whose measured live fractions diverge split into sub-cohorts
    with different compiled budgets mid-run — still bit-identical to
    sequential, including the interleaved membership ([0,2] vs [1])."""
    forced = [0.05, 0.3, 0.05]

    seq_states = []
    for k in range(M):
        tr, st, sa = _fresh(datasets, k)
        st, _ = tr.train(st, sa, iters=12, log_every=12)
        tr._live_frac = forced[k]
        st, _ = tr.train(st, sa, iters=8, log_every=8)
        seq_states.append(st)

    trs, sts, sas = zip(*[_fresh(datasets, k) for k in range(M)])
    mids, _ = train_cohort(list(trs), list(sts), list(sas), iters=12, log_every=12)
    for k in range(M):
        trs[k]._live_frac = forced[k]
    budgets = {trs[k]._current_budget(True) for k in range(M)}
    assert len(budgets) > 1, "forced live fractions must split the partition"
    news, _ = train_cohort(list(trs), list(mids), list(sas), iters=8, log_every=8)
    for k in range(M):
        assert _state_equal(seq_states[k], news[k]), f"member {k}"


def test_cohort_rejects_mismatched_members(datasets):
    tr0, st0, sa0 = _fresh(datasets, 0)
    other_cfg = TrainerConfig(n_rays=32, render=RCFG, occ=OCFG, eval_chunk=144)
    tr1, st1, sa1 = _fresh(datasets, 1, cfg=other_cfg)
    with pytest.raises(ValueError, match="configs"):
        train_cohort([tr0, tr1], [st0, st1], [sa0, sa1], iters=4)
    tr2, st2, sa2 = _fresh(datasets, 1)
    st2b, _ = tr2.train(st2, sa2, iters=4, log_every=4)
    with pytest.raises(ValueError, match="same training step"):
        train_cohort([tr0, tr2], [st0, st2b], [sa0, sa2], iters=4)


# ---- scheduling: mixed configs, fairness, suspend/resume ----


def test_service_mixed_config_scheduling(datasets):
    """Cohort + singleton sessions interleave in one service: the two
    config-matched scenes ride one cohort, the odd-config scene trains
    solo, and everyone still matches its sequential reference exactly."""
    other_cfg = TrainerConfig(n_rays=32, render=RCFG, occ=OCFG, eval_chunk=144)
    svc = ReconstructionService(slice_iters=4)
    svc.submit_scene(datasets[0], FIELD_CFG, TRAIN_CFG, target_iters=12, seed=0,
                     session_id="a0")
    svc.submit_scene(datasets[1], FIELD_CFG, TRAIN_CFG, target_iters=12, seed=1,
                     session_id="a1")
    svc.submit_scene(datasets[2], FIELD_CFG, other_cfg, target_iters=12, seed=2,
                     session_id="solo")
    cohort_sizes = {}

    first = svc.step()
    cohort_sizes[first["trained"]] = len(first["cohort"])
    assert sorted(first["cohort"]) == ["a0", "a1"]  # config-matched pair
    svc.run()

    for sid, seed, cfg in (("a0", 0, TRAIN_CFG), ("a1", 1, TRAIN_CFG),
                           ("solo", 2, other_cfg)):
        tr = Instant3DTrainer(Field(FIELD_CFG), cfg)
        st = tr.init(jax.random.PRNGKey(seed))
        st, _ = tr.train(st, RaySampler(datasets[seed]), iters=12, log_every=12)
        sess = svc.sessions[sid]
        assert sess.step == 12
        assert _leaves_equal(st.params, sess._current_params()), sid


def test_rr_fairness_with_cohorts(datasets):
    """Slice credits: a session advanced inside someone else's cohort gives
    up its own next turn, so cohort pairs don't starve singleton sessions —
    every session finishes the same iteration count."""
    other_cfg = TrainerConfig(n_rays=32, render=RCFG, occ=OCFG, eval_chunk=144)
    svc = ReconstructionService(slice_iters=4)
    svc.submit_scene(datasets[0], FIELD_CFG, TRAIN_CFG, target_iters=16, seed=0,
                     session_id="a0")
    svc.submit_scene(datasets[1], FIELD_CFG, TRAIN_CFG, target_iters=16, seed=1,
                     session_id="a1")
    svc.submit_scene(datasets[2], FIELD_CFG, other_cfg, target_iters=16, seed=2,
                     session_id="solo")
    trained_per_quantum = []

    def hook(s, event):
        trained_per_quantum.append(sorted(event["cohort"]))

    svc.run(hook=hook)
    assert all(s.step == 16 for s in svc.sessions.values())
    # the pair advances together; solo gets a quantum in between (credits),
    # so by completion both groups consumed the same number of quanta
    pair_quanta = sum(1 for c in trained_per_quantum if c == ["a0", "a1"])
    solo_quanta = sum(1 for c in trained_per_quantum if c == ["solo"])
    assert pair_quanta == solo_quanta == 4


def test_cohort_membership_survives_suspend_resume(datasets):
    """Suspend every cohort member mid-run, resume, finish: the cohort
    re-forms (same key: configs + lockstep step) and the final params are
    bit-identical to an uninterrupted cohort run AND to sequential."""
    def build():
        svc = ReconstructionService(slice_iters=4)
        for k in range(2):
            svc.submit_scene(datasets[k], FIELD_CFG, TRAIN_CFG,
                             target_iters=16, seed=k, session_id=f"s{k}")
        return svc

    plain = build()
    plain.run()

    svc = build()
    ev = svc.step()
    assert sorted(ev["cohort"]) == ["s0", "s1"]
    for sess in svc.sessions.values():       # host round-trip mid-run
        sess.suspend()
        assert not sess.resident
    ev = svc.step()                          # scheduler resumes + re-cohorts
    assert sorted(ev["cohort"]) == ["s0", "s1"]
    svc.run()

    for sid in ("s0", "s1"):
        a, b = plain.sessions[sid], svc.sessions[sid]
        assert a.step == b.step == 16
        assert _leaves_equal(a._current_params(), b._current_params()), sid


# ---- serving: snapshots carry occupancy, redistributed render path ----


def test_snapshot_carries_occ_and_redistributed_render(datasets):
    """Published snapshots carry the occupancy EMA; the redistributed render
    path serves from them within 0.1 dB of the dense render at a fraction
    of the shaded points, and a dense-registered service is untouched."""
    svc = ReconstructionService(slice_iters=4)  # redistributed by default
    sid = svc.submit_scene(datasets[0], FIELD_CFG, TRAIN_CFG,
                           target_iters=16, seed=0)
    svc.run()
    snap = svc.store.latest(sid)
    assert snap.occ is not None
    ema, folds = snap.occ
    assert ema.shape == (OCFG.resolution ** 3,) and folds > 0

    ds = datasets[0]
    svc.request_render(sid, ds.poses[1])
    redist = svc.renderer.drain()[0]

    dense_rs = RenderService(svc.store)
    dense_rs.register_session(sid, FIELD_CFG, RCFG, ds.h, ds.w, ds.focal,
                              eval_chunk=144)
    dense_rs.submit(sid, ds.poses[1])
    dense = dense_rs.drain()[0]

    from repro.core import losses
    gt = ds.images[1]
    p_redist = float(losses.psnr(jnp.asarray(redist.rgb), jnp.asarray(gt)))
    p_dense = float(losses.psnr(jnp.asarray(dense.rgb), jnp.asarray(gt)))
    assert abs(p_dense - p_redist) <= 0.1, (p_dense, p_redist)
    # and the dense fallback really rendered the dense path
    assert not np.array_equal(redist.rgb, dense.rgb)


def test_redistributed_render_requires_occ_cfg(datasets):
    rs = RenderService(ReconstructionService().store)
    with pytest.raises(ValueError, match="occ_cfg"):
        rs.register_session("x", FIELD_CFG, RCFG, 12, 12, 30.0,
                            samples_per_ray=2)


def test_occupancy_less_session_serves_dense(datasets):
    """A trainer with use_occupancy=False publishes an all-zero EMA forever;
    redistributed serving would degrade every view to a uniform S' preview,
    so the service must register such sessions on the dense path."""
    no_occ = TrainerConfig(n_rays=64, render=RCFG, occ=OCFG, eval_chunk=144,
                           use_occupancy=False)
    svc = ReconstructionService(slice_iters=4)  # redistributed default on
    sid = svc.submit_scene(datasets[0], FIELD_CFG, no_occ, target_iters=4)
    assert svc.renderer._geom[sid].samples_per_ray is None
    occ_sid = svc.submit_scene(datasets[1], FIELD_CFG, TRAIN_CFG, target_iters=4)
    assert svc.renderer._geom[occ_sid].samples_per_ray == 4
