"""Kernel validation: hash-grid encoding — Pallas vs jnp oracle, VJP vs autodiff."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.hash_encode import ref, ops, kernel


SWEEP = [
    # (L, log2_T, F, n_points, base_res, max_res)
    (2, 10, 2, 128, 4, 32),
    (4, 12, 2, 1000, 16, 256),
    (3, 8, 4, 513, 8, 64),     # F=4, non-multiple-of-block points
    (1, 6, 2, 64, 4, 4),       # single dense level
]


@pytest.mark.parametrize("L,log2_t,F,n,rmin,rmax", SWEEP)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_pallas_matches_ref(L, log2_t, F, n, rmin, rmax, dtype, rng):
    t = 1 << log2_t
    res = ref.level_resolutions(L, rmin, rmax)
    dense = ref.level_is_dense(res, t)
    tables = jnp.asarray(rng.normal(size=(L, t, F)).astype(np.float32) * 0.1, dtype=dtype)
    pts = jnp.asarray(rng.uniform(0, 0.999, size=(n, 3)).astype(np.float32))
    out_ref = ref.hash_encode(pts, tables, res)
    out_pal = ops._forward(pts, tables, tuple(res), tuple(dense), "pallas", 256)
    tol = 1e-6 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out_ref), atol=tol, rtol=tol)


@pytest.mark.parametrize("merged", [True, False])
def test_custom_vjp_matches_autodiff(merged, rng):
    L, t, F = 3, 1 << 10, 2
    res = ref.level_resolutions(L, 8, 64)
    tables = jnp.asarray(rng.normal(size=(L, t, F)).astype(np.float32) * 0.1)
    pts = jnp.asarray(rng.uniform(0, 0.999, size=(400, 3)).astype(np.float32))
    enc = ops.make_hash_encode(res, t, F, backend="ref", merged_backward=merged)
    g_custom = jax.grad(lambda tb: (enc(pts, tb) ** 2).sum())(tables)
    g_auto = jax.grad(lambda tb: (ref.hash_encode(pts, tb, res) ** 2).sum())(tables)
    np.testing.assert_allclose(np.asarray(g_custom), np.asarray(g_auto), atol=1e-4, rtol=1e-4)


def test_pallas_padding_is_inert(rng):
    """Regression: block padding must not hash into live table cells.  The
    sentinel-padded batch encodes identically to the unpadded batch, and the
    sentinel rows themselves produce exactly zero."""
    L, t, F = 3, 1 << 10, 2
    res = ref.level_resolutions(L, 8, 64)
    dense = ref.level_is_dense(res, t)
    tables = jnp.asarray(rng.normal(size=(L, t, F)).astype(np.float32) * 0.1)
    pts = jnp.asarray(rng.uniform(0, 0.999, size=(300, 3)).astype(np.float32))

    # 300 pads to 512 internally; the first 256 must match a pad-free call
    out_padded = ops._forward(pts, tables, tuple(res), tuple(dense), "pallas", 256)
    out_nopad = ops._forward(pts[:256], tables, tuple(res), tuple(dense), "pallas", 256)
    np.testing.assert_array_equal(np.asarray(out_padded[:256]), np.asarray(out_nopad))

    # sentinel rows fed straight to the kernel: zero output, row-0 reads only
    sent = jnp.full((256, 3), ops.PAD_SENTINEL, jnp.float32)
    out_sent = kernel.hash_encode_pallas(
        sent, tables, jnp.asarray(res, jnp.int32),
        jnp.asarray(dense, jnp.int32), block_points=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_sent), np.zeros((256, L * F), np.float32))


def test_dense_levels_have_no_collisions():
    res = np.array([4])  # (4+1)^3 = 125 <= 256
    t = 256
    assert ref.level_is_dense(res, t)[0]
    coords = np.stack(np.meshgrid(*[np.arange(5)] * 3, indexing="ij"), -1).reshape(-1, 3)
    idx = np.asarray(ref.corner_index(jnp.asarray(coords), 4, t, True))
    assert len(np.unique(idx)) == len(idx)


def test_hash_matches_paper_constants():
    # Eq. 3: pi1=1, pi2=2654435761, pi3=805459861, xor-mod
    got = ref.spatial_hash(jnp.array([3]), jnp.array([7]), jnp.array([11]), 1 << 16)
    expect = ((3 * 1) ^ (7 * 2654435761) ^ (11 * 805459861)) % (1 << 16)
    assert int(got[0]) == expect


def test_encoding_is_trilinear_exact_on_dense_level(rng):
    """On a dense level, encoding at a vertex == that vertex's table row."""
    t, res_v = 512, 4
    table = jnp.asarray(rng.normal(size=(1, t, 2)).astype(np.float32))
    # query exactly at grid vertex (2,3,1)/4
    p = jnp.asarray(np.array([[2 / 4, 3 / 4, 1 / 4]], np.float32))
    out = ref.encode_level(p, table[0], res_v)
    idx = int(np.asarray(ref.corner_index(jnp.array([[2, 3, 1]]), res_v, t, True))[0])
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(table[0, idx]), atol=1e-5)
